"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Batched deterministic decoding with preordered slot commits
(serve/session.py).  --replica-check runs two replicas with different
request interleavings and verifies bitwise-identical output — the
paper's fault-tolerance-by-replication property, live.
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--replica-check", action="store_true")
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.serve.session import Session

    cfg = get_smoke_config(args.arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    requests = [(s, 3 + 7 * s) for s in range(args.slots)]

    def run(order):
        sess = Session(cfg, params, n_slots=args.slots,
                       max_seq=args.max_seq)
        for slot, tok in order:
            sess.add_request(slot, tok)
        return sess.generate(args.steps), sess.fingerprint()

    toks, fp = run(requests)
    print(f"arch={cfg.name} slots={args.slots} fingerprint=0x{fp:08x}")
    for s in range(args.slots):
        print(f"  slot {s}: {toks[s].tolist()}")
    if args.replica_check:
        toks2, fp2 = run(requests[::-1])
        same = np.array_equal(toks, toks2) and fp == fp2
        print(f"replica (reversed arrivals) identical: {same}")
        assert same


if __name__ == "__main__":
    main()
