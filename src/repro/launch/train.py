"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

Host-scale entry point: builds the selected architecture (full or smoke
config), the deterministic data pipeline, the Pot train step, and runs
with periodic atomic checkpoints + deterministic resume.  On a real
multi-host fleet the same code runs under ``jax.distributed.initialize``
with the production mesh (launch/mesh.py); on this container it runs the
smoke config over simulated host devices.
"""

from __future__ import annotations

import argparse
import sys
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--mode", choices=["pot", "baseline"], default="pot")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/pot_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--xla-devices", type=int, default=0)
    args = ap.parse_args()

    if args.xla_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.xla_devices}")

    import jax
    import numpy as np

    from repro.ckpt import checkpoint as ck
    from repro.configs import get_config, get_smoke_config
    from repro.data.pipeline import DataConfig, batch_at
    from repro.models import lm
    from repro.runtime.shardings import SMOKE
    from repro.train import make_train_step
    from repro.train.train_step import init_state

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    if not args.smoke and cfg.param_count() > 2e9:
        print(f"WARNING: {cfg.name} has {cfg.param_count()/1e9:.1f}B "
              "params — full-size training needs the production mesh; "
              "use --smoke on this host.", file=sys.stderr)

    print(f"arch={cfg.name} params={cfg.param_count():,} mode={args.mode}")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    state = init_state(params)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    step_fn = jax.jit(make_train_step(
        cfg, SMOKE, mode=args.mode, n_microbatches=args.microbatches,
        remat=False, lr=args.lr))

    start = 0
    if args.resume and (last := ck.latest_step(args.ckpt_dir)) is not None:
        state, extra = ck.restore(args.ckpt_dir, last, state)
        start = extra["data_step"]
        print(f"resumed at step {start} (gv={int(state.gv)})")

    for i in range(start, args.steps):
        # whisper/internvl stub frontends: synthesize embeddings
        batch = dict(batch_at(dcfg, i))
        if cfg.encoder_layers:
            batch["frames"] = jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(7), i),
                (args.batch, cfg.n_frames, cfg.d_model))
        if cfg.n_patches:
            batch["patches"] = jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(8), i),
                (args.batch, cfg.n_patches, cfg.d_model))
        state, loss = step_fn(state, batch)
        if (i + 1) % 10 == 0 or i == start:
            print(f"step {i+1:4d}  loss {float(loss):.4f}  "
                  f"gv {int(state.gv)}", flush=True)
        if (i + 1) % args.ckpt_every == 0:
            ck.save(args.ckpt_dir, i + 1, state,
                    extra={"data_step": i + 1})
            ck.prune(args.ckpt_dir)
    print("done")


if __name__ == "__main__":
    main()
