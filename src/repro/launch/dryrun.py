import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run (DESIGN.md §6, the brief's deliverable (e)).
#
# For every (architecture × input shape) cell this lowers + compiles the
# real step function on the production mesh — (16, 16) single-pod and
# (2, 16, 16) multi-pod — recording memory_analysis() (fit proof),
# cost_analysis() (FLOPs/bytes) and the collective schedule parsed from
# the compiled HLO.
#
# Because XLA's cost analysis counts loop bodies ONCE (scan-over-layers
# would hide (L-1)/L of the FLOPs), each cell additionally lowers an
# UNROLLED analysis pair at trunk depths g and 2g (g = pattern-group
# size); the delta is the exact marginal cost of one group, and
#     total = cost(g) + (n_groups - 1 + tail/g) * delta
# extrapolates FLOPs / bytes / collective bytes for the full depth.
# The full-depth scanned compile remains the memory-fit proof.

import argparse
import dataclasses
import json
import re
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, LONG_OK, SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.models.config import ModelConfig
from repro.runtime.shardings import Profile
from repro.train import make_train_step
from repro.train.train_step import TrainState

# ---- TPU v5e-class hardware constants (roofline) ----
PEAK_FLOPS = 197e12     # bf16 FLOP/s per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

BF16 = jnp.bfloat16
F32 = jnp.float32


# --------------------------------------------------------------- helpers
def _norm_spec(spec, ndim):
    t = tuple(spec) + (None,) * (ndim - len(tuple(spec)))
    return t


def opt_specs(pspecs, pshapes, optimizer):
    if optimizer == "adamw":
        return {"m": pspecs, "v": pspecs, "step": P()}

    def leaf(spec, shape):
        nd = len(shape.shape)
        t = _norm_spec(spec, nd)
        if nd >= 2:
            return {"vr": P(*t[:-1]), "vc": P(*(t[:-2] + t[-1:]))}
        return {"v": spec}

    return {"stats": jax.tree.map(
        leaf, pspecs, pshapes,
        is_leaf=lambda s: isinstance(s, P)), "step": P()}


def profile_for(mesh, shape_spec) -> Profile:
    axes = mesh.axis_names
    data_axes = ("pod", "data") if "pod" in axes else ("data",)
    n_data = int(np.prod([mesh.shape[a] for a in data_axes]))
    replicated = shape_spec.global_batch % n_data != 0
    return Profile(data_axes=data_axes, model_axis="model",
                   replicated_batch=replicated, mesh=mesh)


def choose_optimizer(cfg: ModelConfig) -> str:
    return "adafactor" if cfg.param_count() > 100e9 else "adamw"


def choose_chunk(cfg: ModelConfig, seq_len: int) -> int:
    # q-chunked attention for long global-attention sequences
    return 2048 if seq_len > 8192 and any(
        k == "attn" for k in cfg.pattern + cfg.tail_pattern) else 0


def choose_microbatches(cfg: ModelConfig, shape, n_data: int = 16) -> int:
    if shape.mode != "train":
        return 1
    n = cfg.param_count()
    cap = max(1, shape.global_batch // n_data)  # keep B_mb >= data shards
    if n > 100e9:
        return min(8, cap)
    if n > 18e9:
        return min(8, cap)
    if n > 8e9:
        return min(4, cap)
    return 1


# ------------------------------------------------------- cell functions
def make_inputs(cfg: ModelConfig, shape, mesh, prof, *, mode,
                n_groups=None):
    """Abstract (ShapeDtypeStruct) inputs + their NamedShardings."""
    b, s = shape.global_batch, shape.seq_len
    da = prof.da
    ns = lambda spec: NamedSharding(mesh, spec)
    model_size = mesh.shape["model"]

    s_text = s - (cfg.n_patches or 0)
    batch_specs, batch_abs = {}, {}

    def add(name, shp, dtype, spec):
        batch_abs[name] = jax.ShapeDtypeStruct(shp, dtype)
        batch_specs[name] = ns(spec)

    if mode == "train":
        add("tokens", (b, s_text), jnp.int32, P(da, None))
        add("labels", (b, s_text), jnp.int32, P(da, None))
        if cfg.encoder_layers:
            add("frames", (b, cfg.n_frames, cfg.d_model), BF16,
                P(da, None, None))
        if cfg.n_patches:
            add("patches", (b, cfg.n_patches, cfg.d_model), BF16,
                P(da, None, None))
        return batch_abs, batch_specs
    if mode == "prefill":
        add("tokens", (b, s_text), jnp.int32, P(da, None))
        if cfg.encoder_layers:
            add("frames", (b, cfg.n_frames, cfg.d_model), BF16,
                P(da, None, None))
        if cfg.n_patches:
            add("patches", (b, cfg.n_patches, cfg.d_model), BF16,
                P(da, None, None))
        return batch_abs, batch_specs
    # decode: tokens + pos + cache
    add("tokens", (b, 1), jnp.int32, P(da, None))
    add("pos", (b,), jnp.int32, P(da))
    cache_abs = jax.eval_shape(
        lambda: lm.init_cache(cfg, b, s, prof, n_groups=n_groups,
                              dtype=_cache_dtype(cfg)))
    cspecs = lm.cache_specs(cfg, prof, model_size)
    if n_groups is not None and "tail" in cspecs:
        del cspecs["tail"]
    cache_shardings = jax.tree.map(ns, cspecs,
                                   is_leaf=lambda x: isinstance(x, P))
    return batch_abs, batch_specs, cache_abs, cache_shardings


def input_specs(arch: str, shape_name: str = "train_4k",
                multi_pod: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of a cell (the
    brief's input_specs(): weak-type-correct, shardable, no allocation).
    Returns (abstract_inputs, shardings[, cache_abstract, cache_shardings
    for decode])."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    prof = profile_for(mesh, shape)
    return make_inputs(cfg, shape, mesh, prof, mode=shape.mode)


def _cache_dtype(cfg: ModelConfig):
    # fp8 KV cache for MHA-at-32k archs whose bf16 cache exceeds HBM
    # (qwen1.5-32b: 40 kv heads x 64L x 32k x 128b = 21 GB/chip in bf16).
    if cfg.n_kv_heads * cfg.hd * cfg.n_layers >= 64 * 40 * 128:
        return jnp.float8_e4m3fn
    return BF16




# -------------------------------------------------------- HLO analysis
COLL_RE = re.compile(
    r"=\s+(?:\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)
SHAPE_RE = re.compile(r"(f32|bf16|f16|f8e4m3fn|f8e5m2|s32|u32|s8|u8|pred|"
                      r"f64|s64|u64|c64)\[([0-9,]*)\]")

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
               "s32": 4, "u32": 4, "s8": 1, "u8": 1, "pred": 1, "f64": 8,
               "s64": 8, "u64": 8, "c64": 8}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo: str) -> dict:
    """Wire-byte estimate per collective type: result-shape bytes of every
    collective op (×2 for all-reduce ring cost).

    ``total_bf16_wire`` additionally halves f32 collectives: XLA:CPU's
    float normalization upcasts ALL bf16 math to f32 before SPMD
    materialization (verified with a pure-bf16 minimal repro), so f32
    wire bytes measured here are bf16 on a real TPU lowering.  JAX
    cotangents of bf16 primals are bf16, so backward collectives are
    covered; our deliberately-f32 values (grad accumulator, optimizer
    state) never cross the wire themselves."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "ops": 0}
    f32_bytes = 0
    for line in hlo.splitlines():
        m = COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1).lower()
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(
            m.group(1))[0]
        nbytes = _shape_bytes(lhs)
        factor = 2 if kind == "all-reduce" else 1
        out[kind] += nbytes * factor
        shapes = SHAPE_RE.findall(lhs)
        if shapes and all(dt == "f32" for dt, _ in shapes):
            f32_bytes += nbytes * factor
        out["ops"] += 1
    out["total"] = sum(v for k, v in out.items()
                       if k not in ("ops", "total"))
    out["total_bf16_wire"] = out["total"] - f32_bytes // 2
    return out


def summarize(compiled, n_chips: int) -> dict:
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            # peak: aliased outputs share the argument buffers (donation)
            "peak_bytes": int(mem.argument_size_in_bytes
                              + mem.output_size_in_bytes
                              - mem.alias_size_in_bytes
                              + mem.temp_size_in_bytes),
        },
        "n_chips": n_chips,
    }

# ------------------------------------------------------------ cell build
def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               n_groups=None, unroll=False, train_mode="pot",
               verbose=True, profile_patch=None, n_mb_override=None,
               cfg_patch=None, force_huge=False):
    """Lower + compile one cell; return (compiled, meta)."""
    cfg = get_config(arch)
    if cfg_patch:
        cfg = dataclasses.replace(cfg, **cfg_patch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    prof = profile_for(mesh, shape)
    if profile_patch:
        prof = dataclasses.replace(prof, **profile_patch)
    ns = lambda spec: NamedSharding(mesh, spec)
    n_chips = int(np.prod(list(mesh.shape.values())))
    optimizer = choose_optimizer(cfg)
    chunk = choose_chunk(cfg, shape.seq_len)
    n_data = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                          if a != "model"]))
    n_mb = 1 if (n_groups is not None) else (
        n_mb_override or choose_microbatches(cfg, shape, n_data))
    mode_name = train_mode if n_groups is None else "baseline"

    pspecs = lm.param_specs(cfg, prof, include_tail=n_groups is None)
    params_abs = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg,
                               n_groups=n_groups))
    pshard = jax.tree.map(ns, pspecs, is_leaf=lambda x: isinstance(x, P))

    with jax.set_mesh(mesh):
        if shape.mode == "train":
            # >100B params: bf16 master params + bf16 grad accumulation
            # (f32 adafactor stats) — the standard memory budget at this
            # scale; <=100B trains f32 masters.
            huge = force_huge or cfg.param_count() > 100e9
            if huge:
                params_abs = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(
                        x.shape, BF16 if x.dtype == F32 else x.dtype),
                    params_abs)
            ospecs = opt_specs(pspecs, params_abs, optimizer)
            oshard = jax.tree.map(ns, ospecs,
                                  is_leaf=lambda x: isinstance(x, P))
            state_abs = TrainState(
                params=params_abs,
                opt=jax.eval_shape(
                    lambda p: (adamw_init(p) if optimizer == "adamw"
                               else adafactor_init(p)), params_abs),
                gv=jax.ShapeDtypeStruct((), jnp.int32),
                step=jax.ShapeDtypeStruct((), jnp.int32))
            state_shard = TrainState(params=pshard, opt=oshard,
                                     gv=ns(P()), step=ns(P()))
            batch_abs, batch_shard = make_inputs(
                cfg, shape, mesh, prof, mode="train")
            step = make_train_step(
                cfg, prof, optimizer=optimizer, mode=mode_name,
                n_microbatches=n_mb, chunk=chunk, unroll=unroll,
                remat=True, grad_specs=pspecs,
                accum_dtype=BF16 if huge else F32)
            jf = jax.jit(step,
                         in_shardings=(state_shard, batch_shard),
                         out_shardings=(state_shard, ns(P())),
                         donate_argnums=(0,))
            lowered = jf.lower(state_abs, batch_abs)

        elif shape.mode == "prefill":
            params_bf = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(
                    x.shape, BF16 if x.dtype == F32 else x.dtype),
                params_abs)
            batch_abs, batch_shard = make_inputs(
                cfg, shape, mesh, prof, mode="prefill")
            max_seq = shape.seq_len

            def prefill_fn(params, batch):
                enc = None
                if cfg.encoder_layers:
                    enc = lm.encode(params, batch["frames"], cfg, prof,
                                    unroll=unroll)
                return lm.prefill(params, batch["tokens"], cfg, prof,
                                  max_seq=max_seq,
                                  prefix_embeds=batch.get("patches"),
                                  enc=enc, chunk=chunk, unroll=unroll)

            jf = jax.jit(prefill_fn, in_shardings=(pshard, batch_shard))
            lowered = jf.lower(params_bf, batch_abs)

        else:  # decode
            params_bf = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(
                    x.shape, BF16 if x.dtype == F32 else x.dtype),
                params_abs)
            batch_abs, batch_shard, cache_abs, cache_shard = make_inputs(
                cfg, shape, mesh, prof, mode="decode", n_groups=n_groups)

            def decode_fn(params, cache, tokens, pos):
                return lm.decode_step(params, cache, tokens, pos, cfg,
                                      prof, unroll=unroll)

            jf = jax.jit(
                decode_fn,
                in_shardings=(pshard, cache_shard,
                              batch_shard["tokens"], batch_shard["pos"]),
                out_shardings=(None, cache_shard),
                donate_argnums=(1,))
            lowered = jf.lower(params_bf, cache_abs, batch_abs["tokens"],
                               batch_abs["pos"])

        t0 = time.time()
        compiled = lowered.compile()
        dt = time.time() - t0

    meta = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": shape.mode, "optimizer": optimizer,
        "n_microbatches": n_mb, "chunk": chunk,
        "n_groups": n_groups, "train_mode": mode_name,
        "compile_s": round(dt, 1), "n_chips": n_chips,
    }
    if verbose:
        print(f"  compiled {arch}/{shape_name} mesh={meta['mesh']} "
              f"groups={n_groups or 'full'} in {dt:.0f}s", flush=True)
    return compiled, meta


from repro.optim import adafactor_init, adamw_init  # noqa: E402


def depth_units(cfg: ModelConfig) -> float:
    """Number of pattern groups incl. the tail as a fraction."""
    g = len(cfg.pattern)
    return cfg.n_groups + len(cfg.tail_pattern) / g


def extrapolate(s1: dict, s2: dict, units: float) -> dict:
    """total = cost(1 group) + (units - 1) * (cost(2g) - cost(1g))."""
    out = {}
    for key in ("flops", "bytes"):
        delta = s2[key] - s1[key]
        out[key] = s1[key] + (units - 1) * delta
    coll = {}
    for k in s1["collectives"]:
        delta = s2["collectives"][k] - s1["collectives"][k]
        coll[k] = s1["collectives"][k] + (units - 1) * delta
    out["collectives"] = coll
    return out


def roofline_terms(flops: float, bytes_: float, coll_bytes: float,
                   n_chips: int) -> dict:
    t_c = flops / (n_chips * PEAK_FLOPS)
    t_m = bytes_ / (n_chips * HBM_BW)
    t_x = coll_bytes / (n_chips * ICI_BW)
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "bottleneck": dom,
            "bound_s": max(t_c, t_m, t_x),
            "roofline_fraction": (t_c / max(t_c, t_m, t_x, 1e-30))}


def run_cell(arch: str, shape_name: str, *, with_analysis=True,
             with_multipod=True, train_mode="pot", out_dir=None):
    out_dir = out_dir or RESULTS_DIR
    os.makedirs(out_dir, exist_ok=True)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mode": shape.mode}

    # full-depth fit proof, single-pod
    compiled, meta = lower_cell(arch, shape_name, multi_pod=False,
                                train_mode=train_mode)
    rec["single_pod"] = {"meta": meta, **summarize(compiled, 256)}
    print(compiled.memory_analysis())
    if shape.mode == "decode":
        # the CPU backend cannot alias the donated cache through the layer
        # loop (TPU does): temp carries ~2 unaliased cache copies.  Record
        # the TPU-equivalent adjusted peak alongside the raw number.
        cache_bytes = rec["single_pod"]["memory"]["argument_bytes"]
        for key in ("single_pod",):
            memd = rec[key]["memory"]
            memd["adjusted_peak_bytes"] = max(
                memd["peak_bytes"] - 2 * cache_bytes, 0)
    del compiled

    if with_multipod:
        compiled, meta = lower_cell(arch, shape_name, multi_pod=True,
                                    train_mode=train_mode)
        rec["multi_pod"] = {"meta": meta, **summarize(compiled, 512)}
        del compiled

    if with_analysis:
        c1, _ = lower_cell(arch, shape_name, multi_pod=False, n_groups=1,
                           unroll=True, train_mode="baseline")
        s1 = summarize(c1, 256)
        del c1
        c2, _ = lower_cell(arch, shape_name, multi_pod=False, n_groups=2,
                           unroll=True, train_mode="baseline")
        s2 = summarize(c2, 256)
        del c2
        units = depth_units(cfg)
        ex = extrapolate(s1, s2, units)
        rec["analysis"] = {"g1": s1, "g2": s2, "depth_units": units,
                           "extrapolated": ex}
        from repro.launch.roofline_model import terms_from_record
        rec["roofline"] = terms_from_record(rec)

    path = os.path.join(out_dir, f"{arch}__{shape_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"  -> {path}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--no-analysis", action="store_true")
    ap.add_argument("--no-multipod", action="store_true")
    ap.add_argument("--train-mode", default="pot",
                    choices=["pot", "baseline"])
    ap.add_argument("--out-dir", default=None)
    args = ap.parse_args()

    archs = ARCHS if args.arch == "all" else [args.arch.replace("-", "_")]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    t0 = time.time()
    done, skipped = 0, 0
    for arch in archs:
        for shape_name in shapes:
            if shape_name == "long_500k" and arch not in LONG_OK:
                print(f"SKIP {arch}/{shape_name}: full-attention arch, "
                      "500k exceeds design envelope (DESIGN.md §5)")
                skipped += 1
                continue
            print(f"[{time.time()-t0:7.0f}s] CELL {arch}/{shape_name}",
                  flush=True)
            run_cell(arch, shape_name,
                     with_analysis=not args.no_analysis,
                     with_multipod=not args.no_multipod,
                     train_mode=args.train_mode, out_dir=args.out_dir)
            done += 1
    print(f"DONE: {done} cells compiled, {skipped} documented skips, "
          f"{time.time()-t0:.0f}s total")


if __name__ == "__main__":
    main()
