"""Roofline term computation from dry-run records (per-chip basis).

``cost_analysis()`` on the SPMD-partitioned module reports PER-DEVICE
FLOPs/bytes (verified: per-device flops × 256 ≈ 6·N·D for dense train
cells), and the parsed HLO is the per-device program, so:

    compute term    = flops_per_chip / 197e12
    collective term = coll_bytes_per_chip / 50e9
    memory term     = bytes_per_chip / 819e9

Two memory-byte sources are reported:
  * ``hlo``     — XLA:CPU 'bytes accessed'.  The CPU backend fuses far
    less than the TPU backend, so this is a loose UPPER bound on HBM
    traffic (every elementwise op's operands counted at full size).
  * ``modeled`` — an analytical TPU-proxy (documented formulas below):
    optimizer state traffic + FSDP parameter gathers + remat boundary
    activations + attention score spill + logits.  Used as the primary
    memory term; the HLO number is kept alongside for transparency.
"""

from __future__ import annotations

from repro.configs import SHAPES, get_config
from repro.models.config import ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256
MODEL = 16   # model-axis size
DATA = 16    # data-axis size


def modeled_memory_bytes(cfg: ModelConfig, shape, *, optimizer: str,
                         n_mb: int, huge: bool) -> float:
    """Analytical per-chip HBM bytes for one step (TPU-fusion proxy)."""
    n = cfg.param_count()
    b, s = shape.global_batch, shape.seq_len
    tokens = b * s
    n_per_chip = n / CHIPS
    p_bytes = 2 if (huge or shape.mode != "train") else 4

    total = 0.0
    if shape.mode == "train":
        # optimizer: read p,g(,m,v) + write p(,m,v)
        opt_words = 7 if optimizer == "adamw" else 3
        total += opt_words * 4 * n_per_chip
        # FSDP gathers: per microbatch, fwd + bwd read the gathered bf16
        # params (N / model_size per chip post-gather)
        total += 2 * n_mb * 2 * (n / MODEL) / DATA * 1  # land+read amortized
        # grad accumulate: read+write acc per microbatch
        total += 2 * n_mb * (4 if not huge else 2) * n_per_chip
    else:
        # serve: read the (active) bf16 params once
        act_n = cfg.active_param_count() if cfg.n_experts else n
        total += 2 * act_n / CHIPS if shape.mode == "decode" \
            else 2 * n / CHIPS

    # activations at layer boundaries (SP-sharded), save+read (+bwd)
    tok_local = tokens / (DATA * MODEL)
    factor = 3 if shape.mode == "train" else 1
    total += factor * cfg.n_layers * tok_local * cfg.d_model * 2

    # attention score spill: dense attention materializes (S, S) scores
    # per local head; banded/window layers and chunked prefill stay in
    # VMEM-sized tiles (no spill); decode reads the cache instead.
    n_attn = sum(1 for k in cfg.pattern) and None
    n_global = (cfg.pattern.count("attn") * cfg.n_groups
                + cfg.tail_pattern.count("attn"))
    if shape.mode == "train" and n_global:
        h_local = max(1, cfg.n_heads / MODEL)
        b_local = max(1, b / (DATA * n_mb))
        total += (factor * n_global * b_local * h_local * s * s * 2)
    if shape.mode == "decode":
        # whole KV cache / state read once per step
        kv_bytes = cache_bytes_per_chip(cfg, shape)
        total += kv_bytes
    if shape.mode == "prefill":
        kv_bytes = cache_bytes_per_chip(cfg, shape)
        total += kv_bytes  # cache write-out

    # logits fwd(+bwd)
    v_local = cfg.padded_vocab / MODEL
    tok_l = tokens / DATA / (n_mb if shape.mode == "train" else 1)
    total += factor * tok_l * v_local * 4 / (
        n_mb if shape.mode == "train" else 1)
    return total


def cache_bytes_per_chip(cfg: ModelConfig, shape) -> float:
    b, s = shape.global_batch, shape.seq_len
    kv, hd = cfg.n_kv_heads, cfg.hd
    dtype = 1 if (cfg.n_kv_heads * cfg.hd * cfg.n_layers
                  >= 64 * 40 * 128) else 2
    total = 0.0
    n_local = (cfg.pattern.count("local") * cfg.n_groups
               + cfg.tail_pattern.count("local"))
    n_global = (cfg.pattern.count("attn") * cfg.n_groups
                + cfg.tail_pattern.count("attn"))
    n_rec = cfg.n_layers - n_local - n_global
    total += n_global * b * s * kv * hd * 2 * dtype
    if n_local:
        w = min(cfg.window or s, s)
        total += n_local * b * w * kv * hd * 2 * dtype
    if n_rec:  # mamba / rglru states
        if cfg.ssm_state:
            total += n_rec * b * cfg.ssm_heads * cfg.ssm_head_dim \
                * cfg.ssm_state * 4
        else:
            total += n_rec * b * (cfg.rnn_width or cfg.d_model) * 4
    return total / CHIPS


def terms_from_record(rec: dict) -> dict:
    """Recompute roofline terms (per-chip basis) from a dry-run record."""
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    a = rec["analysis"]
    ex = a["extrapolated"]
    meta = rec["single_pod"]["meta"]
    huge = cfg.param_count() > 100e9

    flops = ex["flops"]                      # per chip
    hlo_bytes = ex["bytes"]                  # per chip (loose upper bound)
    # bf16-wire-corrected when present (XLA:CPU float-normalization
    # upcasts bf16 collectives to f32 — see dryrun.collective_bytes)
    coll = ex["collectives"].get("total_bf16_wire",
                                 ex["collectives"]["total"])
    mod_bytes = modeled_memory_bytes(
        cfg, shape, optimizer=meta["optimizer"],
        n_mb=meta["n_microbatches"], huge=huge)

    t_c = flops / PEAK_FLOPS
    t_m = mod_bytes / HBM_BW
    t_m_hlo = hlo_bytes / HBM_BW
    t_x = coll / ICI_BW
    bound = max(t_c, t_m, t_x)
    dom = {t_c: "compute", t_m: "memory", t_x: "collective"}[bound]

    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.mode == "train":
        model_flops = 6 * n_active * tokens
    elif shape.mode == "prefill":
        model_flops = 2 * n_active * tokens
    else:
        model_flops = 2 * n_active * shape.global_batch
    useful = model_flops / max(flops * CHIPS, 1.0)

    # MFU-style score: model-useful FLOPs over the fleet's peak for the
    # bound duration (counts remat/dispatch waste AND the bound term)
    mfu = model_flops / (CHIPS * PEAK_FLOPS * max(bound, 1e-30))

    return {
        "compute_s": t_c, "memory_s": t_m, "memory_s_hlo_bound": t_m_hlo,
        "collective_s": t_x, "bottleneck": dom, "bound_s": bound,
        "roofline_fraction": t_c / max(bound, 1e-30),
        "mfu_proxy": mfu,
        "model_flops": model_flops, "useful_ratio": useful,
        "coll_raw_s": ex["collectives"]["total"] / ICI_BW,
        "flops_per_chip": flops, "coll_bytes_per_chip": coll,
        "modeled_bytes_per_chip": mod_bytes,
        "collective_mix": ex["collectives"],
    }
