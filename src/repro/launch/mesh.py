"""Production mesh construction (multi-pod dry-run, DESIGN.md §6).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (smoke tests and benches must see 1 device)."""

from __future__ import annotations

import jax


def _mk_mesh(shape, axes):
    # axis_types= (and jax.sharding.AxisType) only exist on newer jax;
    # older versions build the same Auto-typed mesh without the kwarg.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk_mesh(shape, axes)


def make_host_mesh(n: int | None = None, axis: str = "data"):
    """Small mesh over whatever devices exist (examples/tests)."""
    n = n or len(jax.devices())
    return _mk_mesh((n,), (axis,))
