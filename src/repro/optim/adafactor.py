"""Adafactor with factored second moments (Shazeer & Stern 2018).

Used for the largest assigned architecture (arctic-480b): the factored
row/col statistics keep optimizer state ~O(R+C) per matrix instead of
O(R·C), which is what lets a 480B-parameter MoE fit the 16 GB/chip HBM
budget on the production mesh (DESIGN.md §6)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor_init(params):
    def leaf(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

    return {"stats": jax.tree.map(leaf, params),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(params, grads, state, *, lr=1e-2, eps=1e-30,
                     decay_pow=0.8, clip_threshold=1.0, wd=0.0):
    step = state["step"] + 1
    beta2 = 1.0 - jnp.power(step.astype(jnp.float32), -decay_pow)

    def leaf_core(p, g, s):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(p.shape):
            vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(axis=-2)
            denom = vr.mean(axis=-1, keepdims=True)
            u = g * jax.lax.rsqrt(vr[..., None] / denom[..., None]) \
                * jax.lax.rsqrt(vc[..., None, :])
            new_s = {"vr": vr, "vc": vc}
        else:
            v = beta2 * s["v"] + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(v)
            new_s = {"v": v}
        # update clipping (RMS(u) <= clip_threshold)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        pf = p.astype(jnp.float32)
        p2 = pf - lr * u - lr * wd * pf
        return p2.astype(p.dtype), new_s

    def leaf(p, g, s):
        # stacked layer leaves (G, ...): apply per group via lax.map so
        # the f32 intermediates are group-sized, not stack-sized
        if p.ndim >= 3 and p.shape[0] > 1 and p.size > 2e8:
            return jax.lax.map(lambda args: leaf_core(*args), (p, g, s))
        return leaf_core(p, g, s)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_s = tdef.flatten_up_to(state["stats"])
    outs = [leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    p2 = jax.tree.unflatten(tdef, [o[0] for o in outs])
    s2 = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return p2, {"stats": s2, "step": step}
