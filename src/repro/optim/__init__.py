from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.adafactor import adafactor_init, adafactor_update
from repro.optim.compress import topk_compress, error_feedback_init
from repro.optim.ordered_reduce import ordered_ring_reduce, ordered_tree_sum

__all__ = [
    "adamw_init", "adamw_update", "adafactor_init", "adafactor_update",
    "topk_compress", "error_feedback_init", "ordered_ring_reduce",
    "ordered_tree_sum",
]
