"""Gradient compression with error feedback — write-set sparsification.

In Pot terms, compressing a gradient transaction shrinks its *write set*
before commit: fewer words cross the wire (collective term down) and, in
the speculative path, fewer words to validate.  Error feedback keeps the
residual locally so the deterministic serial semantics are preserved in
expectation; because selection (top-k by magnitude) is a pure function of
the gradient, the compressed transaction is as deterministic as the
uncompressed one.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def error_feedback_init(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                        params)


def topk_compress(grads, residual, *, ratio: float = 0.01):
    """Per-leaf magnitude top-k with error feedback.

    Returns (sparse_grads, new_residual): sparse_grads has the same dense
    shape with non-selected entries zeroed (XLA-friendly sparse analog);
    new_residual accumulates what was dropped.
    """
    def leaf(g, r):
        g = g.astype(jnp.float32) + r
        flat = g.reshape(-1)
        k = max(1, int(flat.shape[0] * ratio))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = jnp.abs(g) >= thresh
        sparse = jnp.where(mask, g, 0.0)
        return sparse, g - sparse

    out = jax.tree.map(leaf, grads, residual)
    sparse = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_r = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return sparse, new_r
