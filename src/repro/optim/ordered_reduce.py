"""Deterministic ordered gradient reduction — Pot's ordered commits
applied to the data-parallel gradient transaction (DESIGN.md §3).

Float addition is non-associative: an all-reduce whose internal schedule
varies with timing/topology yields bitwise-different sums, so replicated
trainers diverge — the exact nondeterminism Pot removes from TM programs.
Here the sequencer's order is the lane (shard) index, and the reduction
follows a FIXED ring schedule implemented with ``lax.ppermute``:
shard i adds its contribution in ring position order, so the float
summation order is a pure function of the mesh, never of timing.

- ``ordered_ring_reduce``: reduce-scatter + all-gather over a named mesh
  axis (inside shard_map), 2(n-1) unrolled ppermute steps, summation
  order = ring order (bitwise deterministic).
- ``ordered_tree_sum``: fixed-order pairwise tree over a stacked leading
  axis (microbatch lanes inside one device) — the in-chip analog.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ordered_ring_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Bitwise-deterministic all-reduce(sum) over ``axis_name``.

    Must run inside shard_map.  x: the local shard's contribution.
    Equivalent to lax.psum(x, axis_name) with a fixed summation order.
    """
    if hasattr(jax.lax, "axis_size"):
        n = jax.lax.axis_size(axis_name)
    else:
        # pre-axis_size jax: psum of a Python constant folds to the
        # static axis size (needed concretely for the unrolled ring).
        n = int(jax.lax.psum(1, axis_name))
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    perm_fwd = [(i, (i + 1) % n) for i in range(n)]

    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    chunks = flat.reshape(n, -1)

    # --- reduce-scatter: partial for chunk c starts at shard c and walks
    # the ring; the summation order of chunk c is c, c+1, ..., c-1 — a
    # fixed function of ring position (never of timing).
    acc = chunks[idx]
    for s in range(n - 1):
        acc = jax.lax.ppermute(acc, axis_name, perm_fwd)
        acc = acc + jnp.take(chunks, (idx - 1 - s) % n, axis=0)
    # shard i now holds the full sum of chunk (i + 1) % n.

    # --- all-gather the reduced chunks around the same ring
    gathered = jnp.zeros_like(chunks)
    gathered = gathered.at[(idx + 1) % n].set(acc)
    cur = acc
    for s in range(n - 1):
        cur = jax.lax.ppermute(cur, axis_name, perm_fwd)
        gathered = gathered.at[(idx - s) % n].set(cur)
    out = gathered.reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(x.shape)


def ordered_tree_sum(stacked: jax.Array) -> jax.Array:
    """Fixed-order pairwise-tree sum over axis 0 (lane order = sequence
    order).  Deterministic regardless of how XLA would schedule a plain
    ``sum``; used for microbatch-lane gradient commits inside a device."""
    n = stacked.shape[0]
    x = stacked
    while x.shape[0] > 1:
        m = x.shape[0]
        if m % 2 == 1:
            x = jnp.concatenate([x, jnp.zeros_like(x[:1])], axis=0)
            m += 1
        x = x[0::2] + x[1::2]
    return x[0]
