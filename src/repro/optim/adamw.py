"""AdamW (pure-jnp pytree implementation).

This is the XLA-visible twin of kernels/fused_adamw.py: the dry-run and
roofline use this version (cost_analysis sees its FLOPs/bytes); on real
TPU the fast-mode commit path swaps in the fused Pallas kernel
(kernels/ops.adamw_update) leaf-by-leaf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, *, lr=1e-3, b1=0.9, b2=0.999,
                 eps=1e-8, wd=0.01):
    step = state["step"] + 1
    bc1 = 1.0 - jnp.power(jnp.float32(b1), step.astype(jnp.float32))
    bc2 = 1.0 - jnp.power(jnp.float32(b2), step.astype(jnp.float32))

    def leaf_core(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1.0 - b1) * g
        v2 = b2 * v + (1.0 - b2) * g * g
        upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
        p2 = p.astype(jnp.float32) - lr * (upd + wd * p.astype(jnp.float32))
        return p2.astype(p.dtype), m2, v2

    def leaf(p, g, m, v):
        # stacked layer leaves: per-group update bounds f32 temps
        if p.ndim >= 3 and p.shape[0] > 1 and p.size > 2e8:
            return jax.lax.map(lambda args: leaf_core(*args), (p, g, m, v))
        return leaf_core(p, g, m, v)

    out = jax.tree.map(leaf, params, grads, state["m"], state["v"])
    p2 = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    m2 = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    v2 = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return p2, {"m": m2, "v": v2, "step": step}
