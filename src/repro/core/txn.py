"""Transactions with *dynamic* read/write sets, as a jittable bytecode VM.

The paper chooses OCC precisely because general TM transactions have
read/write sets that cannot be known a priori (aliasing, pointer chasing,
"the unstructured nature of the heap", §2.2).  We reproduce that property
in a dataflow runtime with a tiny bounded-length bytecode: the *indirect*
addressing mode makes an instruction's effective address depend on the
value returned by the previous read, so a transaction's footprint is only
discoverable by executing it — exactly the dynamic-set regime.

Opcodes
-------
NOP   — padding.
READ  — acc += M[eff]; logs eff in the read set.
WRITE — M[eff] := acc + operand (deferred); logs eff in the write set.
RMW   — READ then WRITE on the same address (read-modify-write).

Addressing: eff = addr                     (direct)
            eff = (addr + last_read) % O   (indirect — data dependent)

Reads observe the transaction's own deferred writes (read-your-writes, as
in Fig. 2a line 5/6 of the paper: "return the buffered value for o in the
write set, if existing").
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

NOP, READ, WRITE, RMW = 0, 1, 2, 3


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TxnBatch:
    """K transactions of up to L instructions each (stacked, masked)."""

    opcodes: jax.Array   # (K, L) int32
    addrs: jax.Array     # (K, L) int32
    indirect: jax.Array  # (K, L) bool
    operands: jax.Array  # (K, L) int32
    n_ins: jax.Array     # (K,)   int32 — live instruction count (= txn "cost")

    @property
    def n_txns(self) -> int:
        return self.opcodes.shape[0]

    @property
    def max_ins(self) -> int:
        return self.opcodes.shape[1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TxnResult:
    """One speculative execution: logged footprint + deferred writes."""

    raddrs: jax.Array  # (K, L) int32 — read-set addresses (first rn valid)
    rn: jax.Array      # (K,)   int32
    waddrs: jax.Array  # (K, L) int32 — write-set addresses (first wn valid)
    wvals: jax.Array   # (K, L, S) int32 — deferred values (write buffer)
    wn: jax.Array      # (K,)   int32


def make_batch(progs: list[list[tuple]], max_ins: int | None = None) -> TxnBatch:
    """Build a TxnBatch from python programs: each a list of
    (opcode, addr, indirect, operand) tuples."""
    k = len(progs)
    length = max_ins or max((len(p) for p in progs), default=1)
    length = max(length, 1)
    op = np.zeros((k, length), np.int32)
    ad = np.zeros((k, length), np.int32)
    ind = np.zeros((k, length), bool)
    opr = np.zeros((k, length), np.int32)
    n = np.zeros((k,), np.int32)
    for i, p in enumerate(progs):
        n[i] = len(p)
        for j, (o, a, b, v) in enumerate(p):
            op[i, j], ad[i, j], ind[i, j], opr[i, j] = o, a, b, v
    return TxnBatch(
        opcodes=jnp.asarray(op), addrs=jnp.asarray(ad),
        indirect=jnp.asarray(ind), operands=jnp.asarray(opr),
        n_ins=jnp.asarray(n),
    )


def run_txn(batch_row, values: jax.Array) -> tuple:
    """Execute ONE transaction speculatively against a store image.

    ``batch_row`` — a TxnBatch pytree sliced to one transaction (arrays of
    shape (L,) / (L,)).  ``values`` — (O, S) committed store image.  Pure:
    returns the footprint + deferred writes, never mutates ``values``
    (deferred-update OCC read phase, Fig. 2a).
    """
    n_obj, slot = values.shape
    length = batch_row.opcodes.shape[0]

    def step(carry, t):
        acc, last, rn, wn, raddrs, waddrs, wvals = carry
        op = batch_row.opcodes[t]
        active = (t < batch_row.n_ins) & (op != NOP)
        eff = jnp.where(
            batch_row.indirect[t],
            jnp.abs(batch_row.addrs[t] + last) % n_obj,
            batch_row.addrs[t] % n_obj,
        )
        is_read = active & ((op == READ) | (op == RMW))
        is_write = active & ((op == WRITE) | (op == RMW))

        # read-your-writes: latest deferred write to eff, else memory
        idx = jnp.arange(length)
        match = (waddrs == eff) & (idx < wn)
        has_match = match.any()
        last_match = (length - 1) - jnp.argmax(match[::-1])
        buf_val = wvals[last_match]
        mem_val = values[eff]
        rval = jnp.where(has_match, buf_val, mem_val)  # (S,)

        acc = jnp.where(is_read, acc + rval, acc)
        last = jnp.where(is_read, rval[0], last)
        raddrs = jnp.where(is_read, raddrs.at[rn].set(eff), raddrs)
        rn = rn + is_read.astype(jnp.int32)

        wval = acc + batch_row.operands[t]
        waddrs = jnp.where(is_write, waddrs.at[wn].set(eff), waddrs)
        wvals = jnp.where(is_write, wvals.at[wn].set(wval), wvals)
        wn = wn + is_write.astype(jnp.int32)
        return (acc, last, rn, wn, raddrs, waddrs, wvals), None

    init = (
        jnp.zeros((slot,), jnp.int32),          # acc
        jnp.zeros((), jnp.int32),               # last read word
        jnp.zeros((), jnp.int32),               # rn
        jnp.zeros((), jnp.int32),               # wn
        jnp.zeros((length,), jnp.int32),        # raddrs
        jnp.zeros((length,), jnp.int32),        # waddrs
        jnp.zeros((length, slot), jnp.int32),   # wvals
    )
    (acc, last, rn, wn, raddrs, waddrs, wvals), _ = jax.lax.scan(
        step, init, jnp.arange(length))
    return raddrs, rn, waddrs, wvals, wn


def run_all(batch: TxnBatch, values: jax.Array) -> TxnResult:
    """Speculatively execute every transaction in the batch (vmapped) against
    the same committed store image — one engine "round" of read phases."""
    raddrs, rn, waddrs, wvals, wn = jax.vmap(run_txn, in_axes=(0, None))(
        batch, values)
    return TxnResult(raddrs=raddrs, rn=rn, waddrs=waddrs, wvals=wvals, wn=wn)


def run_live(batch: TxnBatch, values: jax.Array, live: jax.Array,
             cache: TxnResult | None = None) -> TxnResult:
    """Masked re-execution: run only the *live* transactions, reuse cached
    rows for the settled ones.

    ``live`` (K,) bool selects the transactions whose speculation is stale
    (uncommitted/aborted rows that must re-read the new store image);
    settled rows keep their ``cache`` entry untouched.  Dead lanes run
    with ``n_ins`` masked to 0 so every instruction predicate is false —
    the vmapped scan still walks the (K, L) grid (shapes are static under
    jit) but a dead lane's instruction slots are inert, which is exactly
    the live-slot work model the engines account (``ExecTrace.live_slots``
    vs ``rounds * sum(n_ins)`` for a from-scratch ``run_all`` per round).

    A live row's result is bit-identical to the same row of
    ``run_all(batch, values)``: execution is per-transaction pure, so
    masking the other lanes cannot change it (asserted in
    tests/test_round_state.py).

    With ``cache=None`` dead rows come back zeroed (rn = wn = 0) — only
    valid when every consumer masks by ``live``, e.g. the first round of
    an engine loop where ``live`` is all-true.
    """
    masked = TxnBatch(
        opcodes=batch.opcodes, addrs=batch.addrs, indirect=batch.indirect,
        operands=batch.operands,
        n_ins=jnp.where(live, batch.n_ins, 0))
    fresh = run_all(masked, values)
    if cache is None:
        return fresh

    def merge(new, old):
        mask = live.reshape(live.shape + (1,) * (new.ndim - 1))
        return jnp.where(mask, new, old)

    return jax.tree.map(merge, fresh, cache)
