"""Transactions with *dynamic* read/write sets, as a jittable bytecode VM.

The paper chooses OCC precisely because general TM transactions have
read/write sets that cannot be known a priori (aliasing, pointer chasing,
"the unstructured nature of the heap", §2.2).  We reproduce that property
in a dataflow runtime with a tiny bounded-length bytecode: the *indirect*
addressing mode makes an instruction's effective address depend on the
value returned by the previous read, so a transaction's footprint is only
discoverable by executing it — exactly the dynamic-set regime.

Opcodes
-------
NOP   — padding.
READ  — acc += M[eff]; logs eff in the read set.
WRITE — M[eff] := acc + operand (deferred); logs eff in the write set.
RMW   — READ then WRITE on the same address (read-modify-write).

Addressing: eff = addr                     (direct)
            eff = (addr + last_read) % O   (indirect — data dependent)

Reads observe the transaction's own deferred writes (read-your-writes, as
in Fig. 2a line 5/6 of the paper: "return the buffered value for o in the
write set, if existing").

Two properties of this VM carry the cross-batch speculation invariant
(PR 7, ``protocol.spec_execute`` / ``seed_round_state``): a row's
execution is a pure function of the values its logged reads observed
(so a speculated row whose reads all survive validation replays
bit-identically without re-running), and read-your-writes is row-local
(a row never observes another row's deferred writes, so the logged read
set is exactly the row's store footprint).  Invalidated rows re-execute
through the same ``run_live`` / ``run_live_compact`` executors the
round loops use — there is no separate speculation VM.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

NOP, READ, WRITE, RMW = 0, 1, 2, 3


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TxnBatch:
    """K transactions of up to L instructions each (stacked, masked)."""

    opcodes: jax.Array   # (K, L) int32
    addrs: jax.Array     # (K, L) int32
    indirect: jax.Array  # (K, L) bool
    operands: jax.Array  # (K, L) int32
    n_ins: jax.Array     # (K,)   int32 — live instruction count (= txn "cost")

    @property
    def n_txns(self) -> int:
        return self.opcodes.shape[0]

    @property
    def max_ins(self) -> int:
        return self.opcodes.shape[1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TxnResult:
    """One speculative execution: logged footprint + deferred writes."""

    raddrs: jax.Array  # (K, L) int32 — read-set addresses (first rn valid)
    rn: jax.Array      # (K,)   int32
    waddrs: jax.Array  # (K, L) int32 — write-set addresses (first wn valid)
    wvals: jax.Array   # (K, L, S) int32 — deferred values (write buffer)
    wn: jax.Array      # (K,)   int32


def make_batch(progs: list[list[tuple]], max_ins: int | None = None) -> TxnBatch:
    """Build a TxnBatch from python programs: each a list of
    (opcode, addr, indirect, operand) tuples.

    NB: a row with ``n_ins == 0`` is a *vacant* row — since PR 4 the
    engines treat it as absent (never committed, no sequence position,
    no ``gv`` advance, ``commit_pos == -1``), because that is how
    ``PotSession`` encodes shape-bucket padding (:func:`pad_batch`).  An
    intentionally empty transaction should be a single NOP instruction
    (``[(NOP, 0, False, 0)]``), which commits normally with an empty
    footprint."""
    k = len(progs)
    length = max_ins or max((len(p) for p in progs), default=1)
    length = max(length, 1)
    op = np.zeros((k, length), np.int32)
    ad = np.zeros((k, length), np.int32)
    ind = np.zeros((k, length), bool)
    opr = np.zeros((k, length), np.int32)
    n = np.zeros((k,), np.int32)
    for i, p in enumerate(progs):
        n[i] = len(p)
        for j, (o, a, b, v) in enumerate(p):
            op[i, j], ad[i, j], ind[i, j], opr[i, j] = o, a, b, v
    return TxnBatch(
        opcodes=jnp.asarray(op), addrs=jnp.asarray(ad),
        indirect=jnp.asarray(ind), operands=jnp.asarray(opr),
        n_ins=jnp.asarray(n),
    )


def run_txn(batch_row, values: jax.Array,
            n_objects: int | None = None) -> tuple:
    """Execute ONE transaction speculatively against a store image.

    ``batch_row`` — a TxnBatch pytree sliced to one transaction (arrays of
    shape (L,) / (L,)).  ``values`` — (O, S) committed store image.  Pure:
    returns the footprint + deferred writes, never mutates ``values``
    (deferred-update OCC read phase, Fig. 2a).

    ``n_objects`` — the real object count when ``values`` is a *padded*
    flat view (the sharded store's stacked shards reshape to
    S*ceil(O/S) >= O rows, see ``tstore.flat_values``).  Effective
    addresses are reduced mod ``n_objects``, so execution against the
    padded view is bit-identical to the dense (O, S) image: the padding
    rows are never addressed.  Defaults to ``values.shape[0]``.
    """
    n_obj = n_objects if n_objects is not None else values.shape[0]
    slot = values.shape[1]
    length = batch_row.opcodes.shape[0]

    def step(carry, t):
        acc, last, rn, wn, raddrs, waddrs, wvals = carry
        op = batch_row.opcodes[t]
        active = (t < batch_row.n_ins) & (op != NOP)
        eff = jnp.where(
            batch_row.indirect[t],
            jnp.abs(batch_row.addrs[t] + last) % n_obj,
            batch_row.addrs[t] % n_obj,
        )
        is_read = active & ((op == READ) | (op == RMW))
        is_write = active & ((op == WRITE) | (op == RMW))

        # read-your-writes: latest deferred write to eff, else memory
        idx = jnp.arange(length)
        match = (waddrs == eff) & (idx < wn)
        has_match = match.any()
        last_match = (length - 1) - jnp.argmax(match[::-1])
        buf_val = wvals[last_match]
        mem_val = values[eff]
        rval = jnp.where(has_match, buf_val, mem_val)  # (S,)

        acc = jnp.where(is_read, acc + rval, acc)
        last = jnp.where(is_read, rval[0], last)
        raddrs = jnp.where(is_read, raddrs.at[rn].set(eff), raddrs)
        rn = rn + is_read.astype(jnp.int32)

        wval = acc + batch_row.operands[t]
        waddrs = jnp.where(is_write, waddrs.at[wn].set(eff), waddrs)
        wvals = jnp.where(is_write, wvals.at[wn].set(wval), wvals)
        wn = wn + is_write.astype(jnp.int32)
        return (acc, last, rn, wn, raddrs, waddrs, wvals), None

    init = (
        jnp.zeros((slot,), jnp.int32),          # acc
        jnp.zeros((), jnp.int32),               # last read word
        jnp.zeros((), jnp.int32),               # rn
        jnp.zeros((), jnp.int32),               # wn
        jnp.zeros((length,), jnp.int32),        # raddrs
        jnp.zeros((length,), jnp.int32),        # waddrs
        jnp.zeros((length, slot), jnp.int32),   # wvals
    )
    (acc, last, rn, wn, raddrs, waddrs, wvals), _ = jax.lax.scan(
        step, init, jnp.arange(length))
    return raddrs, rn, waddrs, wvals, wn


def run_all(batch: TxnBatch, values: jax.Array,
            n_objects: int | None = None) -> TxnResult:
    """Speculatively execute every transaction in the batch (vmapped) against
    the same committed store image — one engine "round" of read phases.
    ``n_objects`` as in :func:`run_txn` (padded flat store views)."""
    raddrs, rn, waddrs, wvals, wn = jax.vmap(
        run_txn, in_axes=(0, None, None))(batch, values, n_objects)
    return TxnResult(raddrs=raddrs, rn=rn, waddrs=waddrs, wvals=wvals, wn=wn)


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1)."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def pad_batch(batch: TxnBatch, n_txns: int, max_ins: int) -> TxnBatch:
    """Pad a batch with vacant NOP rows / inert instruction columns up to
    (n_txns, max_ins) — the shape-bucketing primitive.

    Padded rows have ``n_ins == 0`` (the *vacant row* convention: engines
    treat them as absent — never pending, never committing, no sequence
    number consumed, ``commit_pos == -1``).  Padded columns are NOP slots
    past every row's ``n_ins``, so real rows execute bit-identically: the
    executor's instruction predicate ``t < n_ins`` is false on them.
    """
    k, length = batch.opcodes.shape
    pk, pl = n_txns - k, max_ins - length
    if pk < 0 or pl < 0:
        raise ValueError(
            f"pad_batch target ({n_txns}, {max_ins}) smaller than ({k}, "
            f"{length})")
    if pk == 0 and pl == 0:
        return batch
    pad2 = lambda a: jnp.pad(a, ((0, pk), (0, pl)))
    return TxnBatch(
        opcodes=pad2(batch.opcodes), addrs=pad2(batch.addrs),
        indirect=pad2(batch.indirect), operands=pad2(batch.operands),
        n_ins=jnp.pad(batch.n_ins, (0, pk)))


def run_live(batch: TxnBatch, values: jax.Array, live: jax.Array,
             cache: TxnResult | None = None,
             n_objects: int | None = None) -> TxnResult:
    """Masked re-execution: run only the *live* transactions, reuse cached
    rows for the settled ones.

    ``live`` (K,) bool selects the transactions whose speculation is stale
    (uncommitted/aborted rows that must re-read the new store image);
    settled rows keep their ``cache`` entry untouched.  Dead lanes run
    with ``n_ins`` masked to 0 so every instruction predicate is false —
    the vmapped scan still walks the (K, L) grid (shapes are static under
    jit) but a dead lane's instruction slots are inert, which is exactly
    the live-slot work model the engines account (``ExecTrace.live_slots``
    vs ``rounds * sum(n_ins)`` for a from-scratch ``run_all`` per round).

    A live row's result is bit-identical to the same row of
    ``run_all(batch, values)``: execution is per-transaction pure, so
    masking the other lanes cannot change it (asserted in
    tests/test_round_state.py).

    With ``cache=None`` dead rows come back zeroed (rn = wn = 0) — only
    valid when every consumer masks by ``live``, e.g. the first round of
    an engine loop where ``live`` is all-true.
    """
    masked = TxnBatch(
        opcodes=batch.opcodes, addrs=batch.addrs, indirect=batch.indirect,
        operands=batch.operands,
        n_ins=jnp.where(live, batch.n_ins, 0))
    fresh = run_all(masked, values, n_objects)
    if cache is None:
        return fresh

    def merge(new, old):
        mask = live.reshape(live.shape + (1,) * (new.ndim - 1))
        return jnp.where(mask, new, old)

    return jax.tree.map(merge, fresh, cache)


# --------------------------------------------------------------------------
# Gather-compacted execution (PR 4)
# --------------------------------------------------------------------------
#
# The masked executor above still walks the full static (K, L) grid even
# when only a handful of rows are live (shapes are static under jit).
# When live_count <= width << K, the compact path gathers the live rows
# into a bounded (width, L) block, executes THAT, and scatters the
# results back — device work proportional to the live set, not the batch
# capacity.  Row purity makes it bit-identical to the masked path: a
# transaction's execution depends only on its own program and the store
# image, never on which other rows share the vmap.


def gather_live_indices(live: jax.Array, width: int
                        ) -> tuple[jax.Array, jax.Array]:
    """Pack the live row indices into the first slots of a (width,) index
    vector: returns ``(idx, valid)`` where ``idx`` holds every live row's
    index (ascending) in its leading ``live.sum()`` slots and ``valid``
    flags them.  Requires ``live.sum() <= width`` — callers guarantee it
    by choosing ``width`` from the compact ladder (protocol.compact_ladder)
    and only descending a rung once the live count fits.
    """
    idx = jnp.argsort(jnp.where(live, 0, 1), stable=True)[:width]
    idx = idx.astype(jnp.int32)
    return idx, live[idx]


def run_compact(batch: TxnBatch, values: jax.Array, idx: jax.Array,
                valid: jax.Array,
                n_objects: int | None = None) -> TxnResult:
    """Execute the gathered rows ``batch[idx]`` against ``values`` at
    compact width C = idx.shape[0].  Rows with ``~valid`` (gather padding,
    possibly duplicate indices) run inert (``n_ins`` masked to 0) and come
    back with empty footprints.  Valid rows are bit-identical to the same
    rows of ``run_all(batch, values)``."""
    cbatch = jax.tree.map(lambda a: a[idx], batch)
    cbatch = TxnBatch(
        opcodes=cbatch.opcodes, addrs=cbatch.addrs,
        indirect=cbatch.indirect, operands=cbatch.operands,
        n_ins=jnp.where(valid, cbatch.n_ins, 0))
    return run_all(cbatch, values, n_objects)


def scatter_rows(dst: jax.Array, src: jax.Array, idx: jax.Array,
                 valid: jax.Array) -> jax.Array:
    """Scatter compact rows back to full width: row ``idx[c]`` of ``dst``
    takes row c of ``src`` where ``valid[c]``; other rows are untouched.

    THE sentinel-drop idiom of the compact path, kept in one place
    because its safety argument is subtle: invalid slots of ``idx`` may
    hold DUPLICATE indices (gather padding clips to valid range), so
    they must be routed to the out-of-bounds sentinel and dropped —
    never masked by a `where` on the gathered value, which would still
    scatter the duplicate and make the result order-dependent."""
    tgt = jnp.where(valid, idx, dst.shape[0])
    return dst.at[tgt].set(src, mode="drop")


def scatter_result(cache: TxnResult, cres: TxnResult, idx: jax.Array,
                   valid: jax.Array, n_rows: int) -> TxnResult:
    """Scatter compact result rows back to their full-width positions:
    row ``idx[c]`` of the output takes row c of ``cres`` where
    ``valid[c]``; every other row keeps its ``cache`` entry."""
    del n_rows  # every leaf's leading axis is the full width
    return jax.tree.map(
        lambda old, new: scatter_rows(old, new, idx, valid), cache, cres)


def run_live_compact(batch: TxnBatch, values: jax.Array, live: jax.Array,
                     cache: TxnResult, width: int,
                     n_objects: int | None = None
                     ) -> tuple[TxnResult, TxnResult, jax.Array, jax.Array]:
    """Compact equivalent of :func:`run_live`: gather the live rows into a
    (width, L) block, execute it, scatter back over ``cache``.

    Returns ``(merged, cres, idx, valid)`` — ``merged`` is bit-identical
    to ``run_live(batch, values, live, cache)`` whenever
    ``live.sum() <= width`` (asserted in tests); ``cres``/``idx``/``valid``
    expose the compact block for callers that keep working at width C
    (the incremental conflict-strip update, DeSTM's token walk).
    """
    idx, valid = gather_live_indices(live, width)
    cres = run_compact(batch, values, idx, valid, n_objects)
    merged = scatter_result(cache, cres, idx, valid, batch.n_txns)
    return merged, cres, idx, valid
