"""Pot core: preordered transactions for deterministic execution.

Public API:
    TStore / make_store / fingerprint        — versioned object store
    TxnBatch / make_batch                    — transactions (dynamic r/w sets)
    RoundRobinSequencer / ReplaySequencer / ExplicitSequencer
    pcc_execute                              — Pot Concurrency Control
    occ_execute / pogl_execute / destm_execute — baselines
"""

from repro.core.destm import DestmTrace, destm_execute
from repro.core.occ import OccTrace, occ_execute
from repro.core.pcc import (MODE_FAST, MODE_PREFIX, MODE_SPEC, PccTrace,
                            pcc_execute)
from repro.core.pogl import pogl_execute
from repro.core.sequencer import (ExplicitSequencer, ReplaySequencer,
                                  RoundRobinSequencer, seq_to_order)
from repro.core.tstore import TStore, fingerprint, make_store
from repro.core.txn import (NOP, READ, RMW, WRITE, TxnBatch, TxnResult,
                            make_batch, run_all, run_txn)

__all__ = [
    "TStore", "make_store", "fingerprint",
    "TxnBatch", "TxnResult", "make_batch", "run_all", "run_txn",
    "NOP", "READ", "WRITE", "RMW",
    "RoundRobinSequencer", "ReplaySequencer", "ExplicitSequencer",
    "seq_to_order",
    "pcc_execute", "PccTrace", "MODE_FAST", "MODE_PREFIX", "MODE_SPEC",
    "occ_execute", "OccTrace",
    "pogl_execute",
    "destm_execute", "DestmTrace",
]
