"""Pot core: preordered transactions for deterministic execution.

The one pipeline (paper §2): a *sequencer* fixes the serialization order
before execution, then a concurrency-control *engine* executes the batch
deterministically against the transactional store.  The public API is
session-oriented:

    PotSession                               — streaming execution layer:
        owns the store + sequencer + a cached jitted step (donated store
        buffers); ``submit(batch, lanes)`` / ``run_stream(batches)``
        carry ``gv`` and the store image across batches and record the
        commit order for ``replay_log()`` / ``replay_sequencer()``.
        Ragged batch shapes are padded to power-of-two buckets with
        vacant NOP rows (which provably never commit), so a ragged
        stream compiles per bucket, not per shape
        (``compile_count()`` / ``bucket_counts()``).
    IngressPool                              — deterministic ingress:
        admission pool (per-client lanes, fee/age/size priority, bounded
        capacity with watermark eviction + backpressure, logical stamps
        only — no wall-clock) whose ``drain(budget)`` *forms* batches in
        a deterministic priority order; ``PotSession.serve(pool)`` makes
        the drain order the preordered sequence, and the arrival journal
        replays bit-exactly (``IngressPool.replay``).
    get_engine / ENGINES / Engine / EngineDef — engine registry:
        "pcc" (Pot Concurrency Control), "pogl", "destm", "occ"
        (and "pot" as an alias for "pcc"), every one returning the
        canonical ``ExecTrace`` schema.
    ExecTrace                                — one trace pytree for all
        engines (per-txn commit_round/commit_pos/retries/mode/... plus
        scalar rounds/exec_ops/validation_words/promotions/barrier_ops).

Building blocks:

    TStore / make_store / fingerprint        — versioned object store
    TxnBatch / make_batch                    — transactions (dynamic r/w sets)
    RoundRobinSequencer / ReplaySequencer / ExplicitSequencer
    metrics.report_from_trace                — structural cost model
    save_snapshot / restore_session / run_replica / FaultPlan
                                             — crash-consistent session
        snapshots (atomic, self-verifying) + deterministic replica
        failover under injected faults (repro.core.checkpoint):
        restore(latest snapshot) + arrival-journal suffix is bit-
        identical to the uninterrupted stream

Quickstart::

    session = PotSession(n_objects=1024, engine="pcc", n_lanes=8)
    for batch in batches:
        trace = session.submit(batch, lanes)
    assert session.fingerprint() == replica.fingerprint()

Deprecated (kept as thin shims): the per-engine free functions
``pcc_execute`` / ``occ_execute`` / ``pogl_execute`` / ``destm_execute``
with their divergent signatures, and the old per-engine trace classes
``PccTrace`` / ``OccTrace`` / ``DestmTrace`` (now all aliases of
``ExecTrace``).  New code should go through ``PotSession`` or
``get_engine(name).execute(store, batch, seq, lanes=..., n_lanes=...)``.
"""

from repro.core.destm import DestmTrace, destm_execute
from repro.core.ingress import (AdmitResult, FormedBatch, IngressPool,
                                JournalError, PoolStats,
                                programs_from_batch)
from repro.core.checkpoint import (FaultInjected, FaultPlan, ReplicaRun,
                                   SnapshotError, atomic_dir,
                                   latest_snapshot, load_snapshot,
                                   restore_session, run_replica,
                                   save_snapshot, trace_digest)
from repro.core.engine import (ENGINES, MODE_FAST, MODE_PREFIX, MODE_SPEC,
                               MODE_UNSET, Engine, EngineDef, ExecTrace,
                               get_engine, make_trace)
from repro.core.occ import OccTrace, occ_execute
from repro.core.pcc import PccTrace, pcc_execute
from repro.core.pogl import pogl_execute
from repro.core.sequencer import (ExplicitSequencer, ReplaySequencer,
                                  RoundRobinSequencer, seq_to_order,
                                  sequencer_from_state, sequencer_state)
from repro.core.session import PotSession
from repro.core.tstore import (DenseStore, ShardedStore, StoreLayout, TStore,
                               dense_image, fingerprint, make_store,
                               shard_store, unshard_store)
from repro.core.txn import (NOP, READ, RMW, WRITE, TxnBatch, TxnResult,
                            make_batch, next_pow2, pad_batch, run_all,
                            run_live, run_live_compact, run_txn)

__all__ = [
    # unified engine API
    "PotSession", "ExecTrace", "Engine", "EngineDef", "ENGINES",
    "get_engine", "make_trace",
    "MODE_UNSET", "MODE_FAST", "MODE_PREFIX", "MODE_SPEC",
    # store + transactions (dense and shard-partitioned layouts)
    "TStore", "DenseStore", "ShardedStore", "StoreLayout", "make_store",
    "shard_store", "unshard_store", "dense_image", "fingerprint",
    "TxnBatch", "TxnResult", "make_batch", "run_all", "run_live",
    "run_live_compact", "run_txn", "pad_batch", "next_pow2",
    "NOP", "READ", "WRITE", "RMW",
    # sequencers
    "RoundRobinSequencer", "ReplaySequencer", "ExplicitSequencer",
    "seq_to_order",
    # deterministic ingress (admission pool + priority-drain former)
    "IngressPool", "FormedBatch", "AdmitResult", "PoolStats",
    "programs_from_batch", "JournalError",
    # crash-consistent snapshots + deterministic replica failover
    "SnapshotError", "atomic_dir", "save_snapshot", "load_snapshot",
    "latest_snapshot", "restore_session", "run_replica", "ReplicaRun",
    "FaultPlan", "FaultInjected", "trace_digest",
    "sequencer_state", "sequencer_from_state",
    # deprecated per-engine entry points
    "pcc_execute", "PccTrace",
    "occ_execute", "OccTrace",
    "pogl_execute",
    "destm_execute", "DestmTrace",
]
