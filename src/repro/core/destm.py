"""DeSTM analog — the state of the art Pot compares against (§5, Fig. 10).

DeSTM [Ravichandran et al. 2014] divides time into *rounds*: in each round
every lane (thread) executes at most ONE transaction; commits happen in a
deterministic token order within the round; and a **barrier** separates
rounds — a transaction cannot start until every transaction of the
previous round finished, and cannot commit until every transaction of its
round has started (Fig. 10a/10b).  A transaction that conflicts with an
earlier commit of its round re-executes while holding the token (DeSTM
requires deterministic conflicts).

Consequences the paper exploits and we measure:
- a lane with n transactions needs >= n rounds even when nothing
  conflicts (Pot commits arbitrarily many per round);
- every transaction inherits the barrier wait of the slowest lane.

Final state is deterministic and — under the same round-robin order —
bitwise-equal to PoGL/PCC (asserted in tests).  Only the *cost structure*
differs, which is exactly the paper's Fig. 7/9/10 story.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import protocol
from repro.core.engine import (EngineDef, ExecTrace, make_trace,
                               register_engine, seq_rank)
from repro.core.tstore import TStore
from repro.core.txn import TxnBatch, run_all, run_txn

# The old per-engine trace dataclass is now the canonical schema.
# (barrier_ops — Σ_rounds Σ_lanes (max_cost - cost), the instruction-slots
# lanes idle at round barriers — lives in the shared ExecTrace.)
DestmTrace = ExecTrace


def _destm_execute(store: TStore, batch: TxnBatch, seq: jax.Array,
                   lanes: jax.Array, n_lanes: int,
                   max_rounds: int | None = None) -> tuple[TStore, ExecTrace]:
    """seq: (K,) 1-based sequence numbers; lanes: (K,) lane of each txn.

    Token order within a round = sequence order restricted to the round's
    transactions (with the paper's shared round-robin sequencer this is the
    lane order, matching DeSTM's token passing).
    """
    k = batch.n_txns
    n_obj = store.n_objects
    order = jnp.argsort(seq)
    gv0 = store.gv

    def round_body(state):
        values, versions, done, rnd, tr = state

        # ---- round membership: first pending txn (in seq order) per lane
        def pick(carry, p):
            taken = carry          # (n_lanes,) bool — lane already has a txn
            t = order[p]
            lane = lanes[t]
            sel = (~done[t]) & (~taken[lane])
            taken = taken.at[lane].max(sel)
            return taken, sel

        _, selected_pos = jax.lax.scan(
            pick, jnp.zeros((n_lanes,), bool), jnp.arange(k))

        # ---- speculative execution against the round-start snapshot
        res = run_all(batch, values)

        # ---- token-order commits; conflicting txns re-execute serially
        def commit_scan(carry, p):
            values, versions, written, tr_retries, tr_exec = carry
            t = order[p]
            sel = selected_pos[p]
            conflict = protocol.footprint_conflicts(
                written, res.raddrs[t], res.rn[t], res.waddrs[t], res.wn[t])

            def commit_clean(args):
                values, versions, written = args
                values, versions = protocol.apply_writes(
                    values, versions, res.waddrs[t], res.wvals[t], res.wn[t],
                    gv0 + p + 1)
                written = protocol.mark_writes(written, res.waddrs[t],
                                               res.wn[t])
                return values, versions, written

            def commit_retry(args):
                # token held: re-execute against committed state, commit.
                # NB: mark the RETRY's write set — the speculative write
                # set may differ (data-dependent addresses) and marking it
                # would hide conflicts from later round members.
                values, versions, written = args
                row = jax.tree.map(lambda a: a[t], batch)
                raddrs2, rn2, waddrs2, wvals2, wn2 = run_txn(row, values)
                del raddrs2, rn2
                values, versions = protocol.apply_writes(
                    values, versions, waddrs2, wvals2, wn2, gv0 + p + 1)
                written = protocol.mark_writes(written, waddrs2, wn2)
                return values, versions, written

            values, versions, written = jax.lax.cond(
                sel,
                lambda a: jax.lax.cond(conflict, commit_retry, commit_clean,
                                       a),
                lambda a: a, (values, versions, written))
            tr_retries = tr_retries.at[t].add((sel & conflict).astype(jnp.int32))
            tr_exec = tr_exec + jnp.where(
                sel, batch.n_ins[t] * (1 + conflict.astype(jnp.int32)), 0)
            return (values, versions, written, tr_retries, tr_exec), None

        (values, versions, _, retries, exec_ops), _ = jax.lax.scan(
            commit_scan,
            (values, versions, jnp.zeros((n_obj,), bool),
             tr["retries"], tr["exec_ops"]),
            jnp.arange(k))

        # ---- barrier accounting: lanes idle until the slowest finishes
        sel_t = jnp.zeros((k,), bool).at[order].set(selected_pos)
        cost = jnp.where(sel_t, batch.n_ins, 0)
        round_max = cost.max()
        n_sel = sel_t.sum(dtype=jnp.int32)
        barrier_ops = tr["barrier_ops"] + jnp.where(
            n_sel > 0, n_sel * round_max - cost.sum(dtype=jnp.int32), 0)

        done = done | sel_t
        commit_round = jnp.where(sel_t, rnd, tr["commit_round"])
        tr = dict(tr, retries=retries, exec_ops=exec_ops,
                  barrier_ops=barrier_ops, commit_round=commit_round)
        return values, versions, done, rnd + 1, tr

    def cond(state):
        _, _, done, rnd, _ = state
        return (~done.all()) & (rnd < limit)

    limit = max_rounds if max_rounds is not None else k + 1
    tr0 = dict(commit_round=jnp.full((k,), -1, jnp.int32),
               retries=jnp.zeros((k,), jnp.int32),
               exec_ops=jnp.zeros((), jnp.int32),
               barrier_ops=jnp.zeros((), jnp.int32))
    values, versions, done, rnd, tr = jax.lax.while_loop(
        cond, round_body,
        (store.values, store.versions, jnp.zeros((k,), bool),
         jnp.zeros((), jnp.int32), tr0))

    # DeSTM's serialization is round-major: rounds commit in order, and
    # within a round the token order (= sequence order restricted to the
    # round's members) decides.  With uneven lane loads this is NOT the
    # plain sequence order, so commit_pos must rank (round, token) pairs.
    rank = seq_rank(seq)
    commit_pos = seq_rank(tr["commit_round"] * (k + 1) + rank)
    trace = make_trace(
        k,
        commit_round=tr["commit_round"], retries=tr["retries"],
        rounds=rnd, exec_ops=tr["exec_ops"],
        barrier_ops=tr["barrier_ops"],
        # a txn executes only in its commit round
        first_round=tr["commit_round"], commit_pos=commit_pos)
    return TStore(values=values, versions=versions, gv=store.gv + k), trace


destm_execute = jax.jit(
    _destm_execute, static_argnames=("n_lanes", "max_rounds"))


def _destm_raw(store, batch, seq, lanes, n_lanes):
    return _destm_execute(store, batch, seq, lanes, n_lanes)


register_engine(EngineDef(
    "destm", _destm_raw,
    doc="DeSTM analog — one txn per lane per round, barrier-separated"))
