"""DeSTM analog — the state of the art Pot compares against (§5, Fig. 10).

DeSTM [Ravichandran et al. 2014] divides time into *rounds*: in each round
every lane (thread) executes at most ONE transaction; commits happen in a
deterministic token order within the round; and a **barrier** separates
rounds — a transaction cannot start until every transaction of the
previous round finished, and cannot commit until every transaction of its
round has started (Fig. 10a/10b).  A transaction that conflicts with an
earlier commit of its round re-executes while holding the token (DeSTM
requires deterministic conflicts).

Vectorized round (shared commit pipeline, :mod:`repro.core.protocol`):
round membership is a per-lane scatter-min (first pending position per
lane) instead of a K-step pick scan; the round's ≤ n_lanes members are
then *compacted* into an (n_lanes, L) block sorted by token order.  The
token-order commit walk inside a round runs in one of two modes, both
decision- and fingerprint-identical (asserted bitwise in
tests/test_destm_wave.py):

* **serial token walk** (``wave=False`` — the frozen-oracle port): one
  retry *event* per ``while_loop`` trip.  Batched conflict checks find
  the first compact row that conflicts (against the accumulated actual
  writes plus the speculative writes of the clean block before it), the
  whole clean block lands in one fused scatter, and only that one
  conflicting transaction re-executes serially while holding the token.
  A round costs O(#retry events) device steps.
* **wave-speculative retries** (``wave=True``, the default — PR 10's
  Block-STM move for this preordered setting): each trip re-executes
  *every* currently-conflicting member at once against the
  committed-so-far store (the clean prefix included, other wave
  members' writes NOT), then commits the maximal token-order prefix
  whose rows it can prove serial-identical — ``retry_waves`` trips per
  round instead of one per event, with equality only on fully serial
  conflict chains.  An invalid speculative row is simply discarded and
  re-executed next wave.  The wave-validity invariant: a committed
  prefix row must (i) resolve exactly as the trip-start classification
  said (its speculative footprint's verdict is unchanged when earlier
  wave members' *speculative* writes are swapped for their *actual*
  re-executed writes — ``protocol.cross_writer_conflicts`` on the
  rectangular strip kernels), and (ii) if re-executed, have logged no
  read of an address any earlier prefix row commits this trip (row
  purity then makes the wave execution bit-equal to the serial retry).
  Both checks are conservative only toward *shrinking* the prefix — a
  dropped row re-executes next wave with the serial semantics — so the
  committed history never diverges from the token walk's.

Since PR 3 the round's read phase is the *masked* executor
(``txn.run_live`` threaded through ``protocol.RoundState``): only the
≤ n_lanes members execute, and retries re-execute through the same
masked path on the compact block.  Since PR 10 the round-0 read phase
is also *seedable* (``seed=`` / ``EngineDef.raw_spec``), exactly like
pcc/occ: a :class:`protocol.SpecSeed` captured against an earlier store
snapshot is re-based by ``protocol.seed_round_state`` and round 0
charges its ordinary accounting via ``protocol.charge_round_state``
without re-walking the members — the entry point behind
``PotSession(pipeline_depth=D)`` cross-batch pipelining, bit-identical
to the unseeded call except the ``spec_*`` observables.

Consequences the paper exploits and we measure:
- a lane with n transactions needs >= n rounds even when nothing
  conflicts (Pot commits arbitrarily many per round);
- every transaction inherits the barrier wait of the slowest lane.

Final state is deterministic and — under the same round-robin order —
bitwise-equal to PoGL/PCC (asserted in tests).  Only the *cost structure*
differs, which is exactly the paper's Fig. 7/9/10 story.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import protocol
from repro.core.engine import (EngineDef, ExecTrace, make_trace,
                               rank_from_order, register_engine, seq_rank)
from repro.core.tstore import TStore, flat_values, store_with
from repro.core.txn import TxnBatch, TxnResult, run_live

# The old per-engine trace dataclass is now the canonical schema.
# (barrier_ops — Σ_rounds Σ_lanes (max_cost - cost), the instruction-slots
# lanes idle at round barriers — lives in the shared ExecTrace.)
DestmTrace = ExecTrace


def _destm_execute(store: TStore, batch: TxnBatch, seq: jax.Array,
                   lanes: jax.Array, n_lanes: int,
                   max_rounds: int | None = None,
                   incremental: bool = True,
                   compact: bool = True,
                   wave: bool = True,
                   seed: "protocol.SpecSeed | None" = None
                   ) -> tuple[TStore, ExecTrace]:
    """seq: (K,) 1-based sequence numbers; lanes: (K,) lane of each txn.

    Token order within a round = sequence order restricted to the round's
    transactions (with the paper's shared round-robin sequencer this is the
    lane order, matching DeSTM's token passing).

    ``incremental``: execute only the round's ≤ n_lanes members — every
    other transaction's row is carried, and a row is only ever consumed
    in the round its transaction is a member of, so the loop is
    bit-identical to the full per-round ``run_all`` (False, the PR 2
    behavior).  DeSTM carries no conflict table: its conflict questions
    live on the compacted (n_lanes, L) block.

    ``compact``: the round's members are the degenerate *always-compact*
    case of the shared gather-compacted read phase
    (``protocol.refresh_round_state_gathered`` with the member rows in
    token order): the executor walks (n_lanes, L), never (K, L).  False
    keeps the masked (K, L) executor (the PR 3 behavior) — decisions are
    bit-identical either way.  Rows with ``n_ins == 0`` are *vacant*
    (bucket padding): never round members, never committed, no ``gv``
    advance (their sequence numbers must sort after every real row's).

    ``wave``: wave-speculative retries (module docstring) — all of a
    trip's conflicting members re-execute at once and the maximal
    provably-serial token prefix commits, instead of one retry event
    per trip.  Bit-identical store/trace either way; only the
    ``retry_waves`` / ``waves_per_round`` observables record the mode's
    win (serial: waves == retry events).

    ``seed``: an optional :class:`protocol.SpecSeed` — round 0's read
    phase already ran speculatively against an earlier snapshot and was
    re-based onto this store; round 0 then only charges accounting
    (bit-identical result, ``spec_*`` observables record the overlap).
    """
    k = batch.n_txns
    layout = store.layout     # static: dense or S contiguous range shards
    n_obj = layout.n_objects
    order = jnp.argsort(seq)
    rank = rank_from_order(order)
    gv0 = store.gv
    lane_slot = jnp.arange(n_lanes)
    real = batch.n_ins > 0     # vacant rows (bucket padding) never commit
    n_real = real.sum(dtype=jnp.int32)
    seeded = seed is not None  # static per trace (None jits leaf-free)

    def round_body(state):
        rs, done, rnd, tr = state

        # ---- round membership: first pending txn (in seq order) per lane,
        # one scatter-min instead of a K-step pick scan
        pending_t = ~done
        first_per_lane = jnp.full((n_lanes,), k, jnp.int32).at[lanes].min(
            jnp.where(pending_t, rank, k).astype(jnp.int32))
        sel_t = pending_t & (first_per_lane[lanes] == rank)

        # ---- compact the round's members: (n_lanes,) rows sorted by
        # token order (= ascending sequence position); empty lanes sit at
        # the back with sentinel position k
        sel_pos = jnp.sort(first_per_lane)            # (n_lanes,) positions
        live = sel_pos < k
        sel_txn = order[jnp.clip(sel_pos, 0, k - 1)]  # txn id per member

        # ---- speculative execution: only the round's members run.  The
        # compact path executes exactly the (n_lanes, L) member block in
        # token order through the shared gathered read phase; the result
        # rows come back compact, no post-hoc (K, L) gathers needed.
        # Seeded round 0 consumes the re-based rows instead (their
        # members execute against the batch-start store, which is what
        # seed_round_state made the cache bit-identical to) and charges
        # the identical accounting.
        if incremental and compact:
            live_t = sel_t

            def fresh(r):
                return protocol.refresh_round_state_gathered(
                    r, batch, sel_txn, live, layout)

            if seeded:
                def charge(r):
                    r = protocol.charge_round_state(r, batch, sel_t,
                                                    n_lanes)
                    return r, jax.tree.map(lambda a: a[sel_txn], r.res)

                rs, cres = jax.lax.cond(rnd == 0, charge, fresh, rs)
            else:
                rs, cres = fresh(rs)
            ra_c, rn_c = cres.raddrs, cres.rn
            wa_c, wv_c, wn_c = cres.waddrs, cres.wvals, cres.wn
        else:
            live_t = sel_t if incremental else jnp.ones((k,), bool)

            def fresh(r):
                return protocol.refresh_round_state(r, batch, live_t,
                                                    layout)

            if seeded:
                rs = jax.lax.cond(
                    rnd == 0,
                    lambda r: protocol.charge_round_state(r, batch,
                                                          live_t, k),
                    fresh, rs)
            else:
                rs = fresh(rs)
            res = rs.res
            ra_c, rn_c = res.raddrs[sel_txn], res.rn[sel_txn]
            wa_c, wv_c, wn_c = (res.waddrs[sel_txn], res.wvals[sel_txn],
                                res.wn[sel_txn])
        values, versions = rs.values, rs.versions
        sn_c = gv0 + 1 + sel_pos                      # version stamps
        compact_batch = jax.tree.map(lambda a: a[sel_txn], batch)
        compact_res = TxnResult(raddrs=ra_c, rn=rn_c, waddrs=wa_c,
                                wvals=wv_c, wn=wn_c)
        slot = jnp.arange(wa_c.shape[1])

        # ---- token-order commits.  Both modes share the trip prologue:
        # batched conflict checks (vs the accumulated actual writes of
        # earlier trips, and vs the speculative writes of remaining
        # members ahead — they commit clean, so speculative = actual for
        # them) find the first conflicting compact row f; the clean
        # block before it lands in one fused scatter.  They differ in
        # what one trip retires beyond that clean prefix: the serial
        # walk re-executes exactly lane f (one retry EVENT per trip),
        # the wave walk re-executes EVERY conflicting member at once and
        # commits the maximal provably-serial prefix.  All operands are
        # compact (n_lanes, L) — no O(K) work per trip.
        def token_cond(st):
            return st[3].any()  # members remaining

        def trip_prologue(st):
            values, versions, written, remaining, retried, waves = st
            accum_hit = jax.vmap(
                protocol.footprint_conflicts, in_axes=(None, 0, 0, 0, 0))(
                    written, ra_c, rn_c, wa_c, wn_c)
            spec_hit = protocol.earlier_writer_conflicts(
                compact_res, None, remaining, lane_slot, n_obj)
            bad = remaining & (accum_hit | spec_hit)
            f = jnp.min(jnp.where(bad, lane_slot, n_lanes))
            clean = remaining & (lane_slot < f)
            values, versions = protocol.fused_write_back(
                values, versions, wa_c, wv_c, wn_c, clean, lane_slot, sn_c,
                layout)
            clean_slots = clean[:, None] & (slot[None, :] < wn_c[:, None])
            written = written.at[
                jnp.where(clean_slots, wa_c, n_obj).reshape(-1)].set(
                    True, mode="drop")
            return values, versions, written, accum_hit, bad, f, clean

        def token_body_serial(st):
            values, versions, written, remaining, retried, waves = st
            values, versions, written, _, bad, f, clean = trip_prologue(st)

            def do_retry(args):
                # token held: re-execute against committed state through
                # the same masked path as the round's read phase (the
                # retrying lane is the event's live set — the frozen
                # oracle's token semantics admit exactly one lane per
                # event, later conflicting lanes re-check against its
                # committed writes first), then commit.
                # NB: mark the RETRY's write set — the speculative write
                # set may differ (data-dependent addresses) and marking it
                # would hide conflicts from later round members.
                values, versions, written = args
                fc = jnp.clip(f, 0, n_lanes - 1)
                cres = run_live(compact_batch, flat_values(values, layout),
                                lane_slot == fc, compact_res, n_obj)
                waddrs2, wvals2, wn2 = (cres.waddrs[fc], cres.wvals[fc],
                                        cres.wn[fc])
                values, versions = protocol.apply_writes(
                    values, versions, waddrs2, wvals2, wn2,
                    gv0 + sel_pos[fc] + 1, layout)
                written = protocol.mark_writes(written, waddrs2, wn2)
                return values, versions, written

            values, versions, written = jax.lax.cond(
                f < n_lanes, do_retry, lambda a: a,
                (values, versions, written))
            retried = retried | (lane_slot == f)    # empty when f == n_lanes
            remaining = remaining & (lane_slot > f)
            waves = waves + (f < n_lanes).astype(jnp.int32)
            return values, versions, written, remaining, retried, waves

        def token_body_wave(st):
            values, versions, written, remaining, retried, waves = st
            (values, versions, written,
             accum_hit, bad, f, clean) = trip_prologue(st)

            def do_wave(args):
                values, versions, written, retried = args
                # (a)+(b) the wave: every conflicting member re-executes
                # in one batched pass against the committed-so-far store
                # (clean prefix included, other wave members' writes
                # NOT); the merge keeps the clean rows' speculative
                # results, so ``wres`` holds the block's RESOLVED
                # candidate result per row.
                wres = run_live(compact_batch,
                                flat_values(values, layout), bad,
                                compact_res, n_obj)
                # (c) validation, rank space, rectangular strips.
                # Classification agreement: a row's serial-turn verdict
                # equals its trip-start one unless swapping an earlier
                # wave member's speculative writes for its re-executed
                # ones flips it — a conflicting member must stay hit
                # (else the serial walk would commit its SPECULATIVE
                # row, which this trip did not re-derive), a clean
                # member must stay clean.
                hit_wave_w = protocol.cross_writer_conflicts(
                    compact_res, wres, bad, lane_slot, n_obj)
                hit_clean_spec = protocol.earlier_writer_conflicts(
                    compact_res, None, remaining & ~bad, lane_slot, n_obj)
                class_ok = jnp.where(
                    bad, accum_hit | hit_clean_spec | hit_wave_w,
                    ~hit_wave_w)
                # Execution validity: a wave row's logged READS must
                # miss every write committed between its snapshot (the
                # clean-prefix store) and its token turn — the resolved
                # writes of later-block rows before it (row purity then
                # makes the wave execution == the serial retry).
                later = remaining & (lane_slot >= f)
                exec_hit = protocol.cross_writer_conflicts(
                    wres, wres, later, lane_slot, n_obj, reads_only=True)
                # (d) maximal token-order prefix of valid rows — the
                # prefix_commit cumulative-AND over token positions.
                ok = jnp.where(later,
                               class_ok & (~bad | ~exec_hit), True)
                alive = jax.lax.associative_scan(jnp.logical_and, ok)
                commit2 = later & alive
                values, versions = protocol.fused_write_back(
                    values, versions, wres.waddrs, wres.wvals, wres.wn,
                    commit2, lane_slot, sn_c, layout)
                cmt_slots = commit2[:, None] & (
                    slot[None, :] < wres.wn[:, None])
                written = written.at[
                    jnp.where(cmt_slots, wres.waddrs,
                              n_obj).reshape(-1)].set(True, mode="drop")
                retried = retried | (bad & commit2)
                return values, versions, written, retried, commit2

            values, versions, written, retried, commit2 = jax.lax.cond(
                f < n_lanes, do_wave,
                lambda a: (*a, jnp.zeros((n_lanes,), bool)),
                (values, versions, written, retried))
            remaining = remaining & (lane_slot >= f) & ~commit2
            waves = waves + (f < n_lanes).astype(jnp.int32)
            return values, versions, written, remaining, retried, waves

        values, versions, _, _, retried_c, waves_r = jax.lax.while_loop(
            token_cond, token_body_wave if wave else token_body_serial,
            (values, versions, jnp.zeros((n_obj,), bool), live,
             jnp.zeros((n_lanes,), bool), jnp.zeros((), jnp.int32)))

        # ---- trace bookkeeping: retry events scattered back to txn ids
        # (live members have distinct txns, so add == set)
        retried_t = jnp.zeros((k,), jnp.int32).at[
            jnp.where(live, sel_txn, k)].add(
                retried_c.astype(jnp.int32), mode="drop")
        retries = tr["retries"] + retried_t
        exec_ops = tr["exec_ops"] \
            + jnp.where(sel_t, batch.n_ins, 0).sum(dtype=jnp.int32) \
            + jnp.where(retried_t > 0, batch.n_ins, 0).sum(dtype=jnp.int32)

        # ---- barrier accounting: lanes idle until the slowest finishes
        cost = jnp.where(sel_t, batch.n_ins, 0)
        round_max = cost.max()
        n_sel = sel_t.sum(dtype=jnp.int32)
        barrier_ops = tr["barrier_ops"] + jnp.where(
            n_sel > 0, n_sel * round_max - cost.sum(dtype=jnp.int32), 0)

        done = done | sel_t
        commit_round = jnp.where(sel_t, rnd, tr["commit_round"])
        tr = dict(tr, retries=retries, exec_ops=exec_ops,
                  barrier_ops=barrier_ops, commit_round=commit_round,
                  live_per_round=tr["live_per_round"].at[rnd].set(
                      live_t.sum(dtype=jnp.int32)),
                  retry_waves=tr["retry_waves"] + waves_r,
                  waves_per_round=tr["waves_per_round"].at[rnd].set(
                      waves_r))
        rs = protocol.commit_round_state(rs, values, versions)
        return rs, done, rnd + 1, tr

    def cond(state):
        _, done, rnd, _ = state
        return (~done.all()) & (rnd < limit)

    limit = max_rounds if max_rounds is not None else k + 1
    tr0 = dict(commit_round=jnp.full((k,), -1, jnp.int32),
               retries=jnp.zeros((k,), jnp.int32),
               exec_ops=jnp.zeros((), jnp.int32),
               barrier_ops=jnp.zeros((), jnp.int32),
               live_per_round=jnp.full((limit,), -1, jnp.int32),
               retry_waves=jnp.zeros((), jnp.int32),
               waves_per_round=jnp.full((limit,), -1, jnp.int32))
    if seeded:
        rs0, spec_inv, spec_rnds = protocol.seed_round_state(
            batch, store, seed, compact=(incremental and compact))
        # DeSTM carries no conflict structure: its conflict questions
        # live on the compact block.  Strip the seed's table so the
        # carried pytree matches the unseeded loop's.
        rs0 = dataclasses.replace(rs0, conflict=None, foot_bits=None,
                                  write_bits=None)
    else:
        rs0 = protocol.init_round_state(batch, store.values,
                                        store.versions,
                                        track_conflict=False,
                                        layout=layout)
    rs, done, rnd, tr = jax.lax.while_loop(
        cond, round_body,
        (rs0, ~real, jnp.zeros((), jnp.int32), tr0))
    values, versions = rs.values, rs.versions

    # DeSTM's serialization is round-major: rounds commit in order, and
    # within a round the token order (= sequence order restricted to the
    # round's members) decides.  With uneven lane loads this is NOT the
    # plain sequence order, so commit_pos must rank (round, token) pairs.
    # Excluded rows — vacant padding, plus reals a max_rounds cap left
    # uncommitted — all carry commit_round == -1 and therefore sort
    # before every committed row; slide the committed positions down
    # past them and stamp the excluded -1.
    committed = tr["commit_round"] >= 0
    n_excluded = (~committed).sum(dtype=jnp.int32)
    commit_pos = seq_rank(tr["commit_round"] * (k + 1) + rank)
    commit_pos = jnp.where(committed, commit_pos - n_excluded, -1)
    trace = make_trace(
        k,
        commit_round=tr["commit_round"], retries=tr["retries"],
        rounds=rnd, exec_ops=tr["exec_ops"],
        barrier_ops=tr["barrier_ops"],
        live_txns=rs.live_txns, live_slots=rs.live_slots,
        walked_slots=rs.walked_slots,
        live_per_round=tr["live_per_round"],
        retry_waves=tr["retry_waves"],
        waves_per_round=tr["waves_per_round"],
        # a txn executes only in its commit round
        first_round=tr["commit_round"], commit_pos=commit_pos,
        **(dict(spec_executed=n_real, spec_invalidated=spec_inv,
                spec_rounds=spec_rnds) if seeded else {}))
    return store_with(store, values, versions, store.gv + n_real), trace


destm_execute = jax.jit(
    _destm_execute,
    static_argnames=("n_lanes", "max_rounds", "incremental", "compact",
                     "wave"))


def _destm_raw(store, batch, seq, lanes, n_lanes):
    return _destm_execute(store, batch, seq, lanes, n_lanes)


def _destm_raw_spec(store, batch, seq, lanes, n_lanes, seed):
    return _destm_execute(store, batch, seq, lanes, n_lanes, seed=seed)


register_engine(EngineDef(
    "destm", _destm_raw,
    doc="DeSTM analog — one txn per lane per round, barrier-separated",
    raw_spec=_destm_raw_spec))
