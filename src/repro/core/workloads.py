"""STAMP / STMBench7 analog workload generators (paper §4, Fig. 5).

Each generator emits a ``TxnBatch`` of bytecode transactions whose
footprint *structure* mirrors the benchmark it is named after — read/write
set sizes, contention profile, and the use of data-dependent (indirect)
addressing — so the engines' structural metrics (rounds, aborts,
wait-rounds, validation work) are driven the way STAMP drives Pot.

All generators are seeded and fully deterministic.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.txn import NOP, READ, RMW, WRITE, TxnBatch, make_batch


@dataclasses.dataclass
class Workload:
    name: str
    batch: TxnBatch
    lanes: np.ndarray      # (K,) lane id per txn
    n_lanes: int
    n_objects: int
    slot: int = 1


def _zipf_addrs(rng, n, n_objects, skew):
    """Contention knob: skew=0 -> uniform; higher -> hotter hot-set."""
    if skew <= 0:
        return rng.integers(0, n_objects, size=n)
    ranks = rng.zipf(1.0 + skew, size=n)
    return np.minimum(ranks - 1, n_objects - 1)


def _assign_lanes(k: int, n_lanes: int) -> np.ndarray:
    return np.arange(k, dtype=np.int64) % n_lanes


def counters(n_txns=64, n_objects=256, n_reads=4, n_writes=4,
             n_lanes=8, skew=0.0, seed=0) -> Workload:
    """§4.1.1 microbenchmark: key-value array of counters.  Knobs map to
    the paper's Fig. 6 axes: access count and read/write ratio."""
    rng = np.random.default_rng(seed)
    progs = []
    for _ in range(n_txns):
        ins = []
        for a in _zipf_addrs(rng, n_reads, n_objects, skew):
            ins.append((READ, int(a), False, 0))
        for a in _zipf_addrs(rng, n_writes, n_objects, skew):
            ins.append((RMW, int(a), False, 1))
        progs.append(ins or [(NOP, 0, False, 0)])
    return Workload("counters", make_batch(progs),
                    _assign_lanes(n_txns, n_lanes), n_lanes, n_objects)


def vacation_like(n_txns=64, n_objects=1024, n_lanes=8, update_pct=90,
                  seed=0) -> Workload:
    """OLTP reservations: read a handful of 'table rows', update a few.
    ``update_pct`` follows STAMP's -u flag (Vacation- u=98, Vacation+ u=90;
    lower u = more contention in the paper's configs)."""
    rng = np.random.default_rng(seed)
    progs = []
    for _ in range(n_txns):
        ins = []
        hot = rng.random() * 100 < update_pct
        n_r = int(rng.integers(4, 10))
        skew = 0.9 if hot else 0.2
        addrs = _zipf_addrs(rng, n_r, n_objects, skew)
        for a in addrs[:-2]:
            ins.append((READ, int(a), False, 0))
        for a in addrs[-2:]:
            ins.append((RMW, int(a), False, 1))
        progs.append(ins)
    return Workload("vacation", make_batch(progs),
                    _assign_lanes(n_txns, n_lanes), n_lanes, n_objects)


def kmeans_like(n_txns=64, n_centroids=16, n_objects=128, n_lanes=8,
                seed=0) -> Workload:
    """Iterative clustering: tiny txns all RMW-ing a few hot centroid
    objects — high write-write contention, small footprints."""
    rng = np.random.default_rng(seed)
    progs = []
    for _ in range(n_txns):
        c = int(rng.integers(0, n_centroids))
        progs.append([(RMW, c, False, 1), (RMW, c + n_centroids, False, 1)])
    return Workload("kmeans", make_batch(progs),
                    _assign_lanes(n_txns, n_lanes), n_lanes, n_objects)


def ssca2_like(n_txns=64, n_objects=4096, n_lanes=8, seed=0) -> Workload:
    """Graph kernel: small txns, near-disjoint writes (low contention) —
    the workload where ordered commits cost the most relative overhead."""
    rng = np.random.default_rng(seed)
    progs = []
    for _ in range(n_txns):
        a = int(rng.integers(0, n_objects))
        progs.append([(READ, a, False, 0), (WRITE, (a * 7 + 13) % n_objects,
                                            False, 3)])
    return Workload("ssca2", make_batch(progs),
                    _assign_lanes(n_txns, n_lanes), n_lanes, n_objects)


def labyrinth_like(n_txns=32, n_objects=512, path_len=24, n_lanes=8,
                   seed=0) -> Workload:
    """Path routing: long transactions that read a candidate path and then
    claim (write) every cell — huge footprints, frequent overlap."""
    rng = np.random.default_rng(seed)
    progs = []
    for _ in range(n_txns):
        start = int(rng.integers(0, n_objects))
        step = int(rng.integers(1, 5))
        ins = []
        for j in range(path_len // 2):
            a = (start + j * step) % n_objects
            ins.append((READ, a, False, 0))
        for j in range(path_len // 2):
            a = (start + j * step) % n_objects
            ins.append((WRITE, a, False, 1))
        progs.append(ins)
    return Workload("labyrinth", make_batch(progs),
                    _assign_lanes(n_txns, n_lanes), n_lanes, n_objects)


def genome_like(n_txns=64, n_objects=2048, n_lanes=8, seed=0) -> Workload:
    """Sequence assembly: dedup inserts (RMW on hashed addresses) plus
    *indirect* chained reads — dynamic read sets via pointer chasing."""
    rng = np.random.default_rng(seed)
    progs = []
    for i in range(n_txns):
        ins = []
        a = int(rng.integers(0, n_objects))
        ins.append((RMW, a, False, 1))                 # hashset insert
        ins.append((READ, int(rng.integers(0, n_objects)), False, 0))
        ins.append((READ, 11, True, 0))                # chase: addr = 11+last
        ins.append((READ, 3, True, 0))                 # chase again
        if i % 3 == 0:
            ins.append((WRITE, 5, True, 2))            # link segment
        progs.append(ins)
    return Workload("genome", make_batch(progs),
                    _assign_lanes(n_txns, n_lanes), n_lanes, n_objects)


def yada_like(n_txns=48, n_objects=1024, n_lanes=8, seed=0) -> Workload:
    """Delaunay refinement: medium cavity re-triangulations with pointer
    chasing and moderate overlap."""
    rng = np.random.default_rng(seed)
    progs = []
    for _ in range(n_txns):
        ins = []
        a = int(rng.integers(0, n_objects))
        ins.append((READ, a, False, 0))
        for _ in range(int(rng.integers(2, 5))):
            ins.append((READ, int(rng.integers(1, 17)), True, 0))
        for _ in range(int(rng.integers(2, 4))):
            ins.append((WRITE, int(rng.integers(1, 17)), True, 1))
        progs.append(ins)
    return Workload("yada", make_batch(progs),
                    _assign_lanes(n_txns, n_lanes), n_lanes, n_objects)


def intruder_like(n_txns=64, n_objects=1024, n_lanes=8, seed=0) -> Workload:
    """Packet reassembly: queue pops (hot head RMW) + map inserts."""
    rng = np.random.default_rng(seed)
    progs = []
    for _ in range(n_txns):
        ins = [(RMW, 0, False, 1)]  # shared queue head — global hot spot
        for _ in range(int(rng.integers(1, 4))):
            ins.append((RMW, int(rng.integers(16, n_objects)), False, 1))
        progs.append(ins)
    return Workload("intruder", make_batch(progs),
                    _assign_lanes(n_txns, n_lanes), n_lanes, n_objects)


def bayes_like(n_txns=32, n_objects=512, n_lanes=8, seed=0) -> Workload:
    """Structure learning: few very large read sets, small writes."""
    rng = np.random.default_rng(seed)
    progs = []
    for _ in range(n_txns):
        ins = []
        for _ in range(int(rng.integers(8, 16))):
            ins.append((READ, int(rng.integers(0, n_objects)), False, 0))
        ins.append((WRITE, int(rng.integers(0, n_objects)), False, 1))
        progs.append(ins)
    return Workload("bayes", make_batch(progs),
                    _assign_lanes(n_txns, n_lanes), n_lanes, n_objects)


def stmbench7_like(workload: str = "rw", n_txns=64, n_objects=4096,
                   n_lanes=8, seed=0) -> Workload:
    """STMBench7 (Fig. 5): r / rw / w mixes over a large object graph.
    Short+long traversals (large read sets, pointer chasing) mixed with
    structural modifications (medium write sets)."""
    ratios = {"r": (0.9, 0.1), "rw": (0.6, 0.4), "w": (0.1, 0.9)}[workload]
    rng = np.random.default_rng(seed)
    progs = []
    for _ in range(n_txns):
        ins = []
        if rng.random() < ratios[0]:   # traversal
            a = int(rng.integers(0, n_objects))
            ins.append((READ, a, False, 0))
            for _ in range(int(rng.integers(6, 14))):
                ins.append((READ, int(rng.integers(1, 33)), True, 0))
        else:                          # structural modification
            a = int(rng.integers(0, n_objects))
            ins.append((READ, a, False, 0))
            for _ in range(int(rng.integers(2, 5))):
                ins.append((RMW, int(rng.integers(1, 33)), True, 1))
        progs.append(ins)
    return Workload(f"stmbench7-{workload}", make_batch(progs),
                    _assign_lanes(n_txns, n_lanes), n_lanes, n_objects)


STAMP = {
    "bayes": bayes_like,
    "genome": genome_like,
    "intruder": intruder_like,
    "kmeans": kmeans_like,
    "labyrinth": labyrinth_like,
    "ssca2": ssca2_like,
    "vacation": vacation_like,
    "yada": yada_like,
}
