"""Ordering phase: the Pot sequencer (paper §2.1).

The sequencer runs *before* execution and assigns every transaction a
sequence number — its place in the deterministic serialization order.  It
is a host-side control-plane component by design (the whole point of
preordered transactions is that ordering is decoupled from the jitted
execution phase).

Implemented sequencers:

- ``RoundRobinSequencer`` — the paper's generic sequencer: derives the
  transaction order from a deterministic order over *lanes* (our threads).
  Lanes form a tree (the main lane is the root; a spawned lane is a child
  of its spawner) and the lane order is the tree's post-order traversal.
  Lane start/stop events are processed as if they were transactions, so
  the order is deterministic under *elastic scaling* (lanes joining and
  leaving mid-run) — this is how the paper handles thread create/join and
  how this framework handles workers joining/leaving a job.
- ``ReplaySequencer`` — replays a recorded commit order (record/replay
  debugging, §2.1 "application-specific sequencers").
- ``ExplicitSequencer`` — a fully explicit order; detects the hang the
  paper warns about (a lane never produces the transaction the order is
  waiting for) and raises instead of deadlocking.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np


@dataclasses.dataclass
class Lane:
    lane_id: int
    parent: int | None
    children: list[int] = dataclasses.field(default_factory=list)
    alive: bool = True


class RoundRobinSequencer:
    """Round-robin over the post-order lane tree (paper §2.1).

    ``get_seq_no(lane_id)`` hands out the next sequence number for that
    lane; numbers are globally consecutive starting at 1 and reflect a
    round-robin interleaving of the live lanes in post-order.
    """

    def __init__(self, n_root_lanes: int = 1):
        self.lanes: dict[int, Lane] = {
            i: Lane(i, None) for i in range(n_root_lanes)}
        self._next_sn = 1
        # per-lane FIFO of pre-assigned numbers (round-robin schedule)
        self._pending: dict[int, list[int]] = {}
        self._order_log: list[tuple[int, int]] = []  # (sn, lane)

    # -- lane tree management (start/stop are sequenced events) ----------
    def spawn_lane(self, parent: int, lane_id: int | None = None) -> int:
        new_id = lane_id if lane_id is not None else (max(self.lanes) + 1)
        assert new_id not in self.lanes
        self.lanes[new_id] = Lane(new_id, parent)
        self.lanes[parent].children.append(new_id)
        return new_id

    def ensure_lane(self, lane_id: int, parent: int | None = None) -> bool:
        """Idempotently register ``lane_id`` — as a root lane (no
        parent; roots order by id in the post-order traversal) or as a
        child of ``parent``.  Returns True when the lane was newly
        created.  The ingress pool uses this to materialize client
        lanes on first contact without racing an explicit spawn."""
        if lane_id in self.lanes:
            return False
        if parent is None:
            self.lanes[lane_id] = Lane(lane_id, None)
        else:
            self.spawn_lane(parent, lane_id)
        return True

    def stop_lane(self, lane_id: int) -> None:
        self.lanes[lane_id].alive = False

    def lane_order(self) -> list[int]:
        """Post-order traversal of the lane tree, live lanes only."""
        out: list[int] = []

        def visit(lid: int):
            for c in self.lanes[lid].children:
                visit(c)
            if self.lanes[lid].alive:
                out.append(lid)

        roots = [l.lane_id for l in self.lanes.values() if l.parent is None]
        for r in sorted(roots):
            visit(r)
        return out

    # -- sequence number assignment ---------------------------------------
    def _refill(self) -> None:
        for lid in self.lane_order():
            self._pending.setdefault(lid, []).append(self._next_sn)
            self._order_log.append((self._next_sn, lid))
            self._next_sn += 1

    def get_seq_no(self, lane_id: int) -> int:
        """Next sequence number for this lane (paper's ``get-seq-no(tid)``).

        Raises instead of spinning forever for a lane the refill loop
        will never feed (unknown, or already stopped).
        """
        if lane_id not in self.lanes:
            raise KeyError(
                f"unknown lane {lane_id!r}: spawn_lane() it (or raise "
                f"n_root_lanes) before sequencing transactions on it")
        while not self._pending.get(lane_id):
            if not self.lanes[lane_id].alive:
                raise RuntimeError(
                    f"lane {lane_id} is stopped and has no pending "
                    f"sequence numbers")
            self._refill()
        return self._pending[lane_id].pop(0)

    def order_for(self, txn_lanes: Iterable[int]) -> np.ndarray:
        """Assign sequence numbers to a whole batch of transactions given
        the lane each one runs on; returns (K,) seq numbers (1-based)."""
        return np.asarray([self.get_seq_no(l) for l in txn_lanes], np.int64)


class ReplaySequencer:
    """Feed a previously recorded commit order back in (record/replay).

    The log may span a whole *stream* of batches (as recorded by
    ``PotSession.replay_log()``): entries are global 0-based txn ids in
    commit order, and each ``order_for`` call consumes the next batch's
    worth of entries, converting global ids to batch-local positions.
    For a single batch this degenerates to the classic "recorded_order[i]
    = txn index that committed i-th" form.  A stream shorter than the
    log leaves entries unconsumed — check :attr:`remaining` (0 after a
    complete replay) to detect a partial replay.
    """

    def __init__(self, recorded_order: Iterable[int]):
        self._order = [int(t) for t in recorded_order]
        self._consumed = 0   # log entries already replayed
        self._offset = 0     # txns seen so far (global -> local ids)

    @property
    def remaining(self) -> int:
        """Log entries not yet replayed (0 once the stream is complete)."""
        return len(self._order) - self._consumed

    def order_for(self, txn_lanes: Iterable[int]) -> np.ndarray:
        lanes = list(txn_lanes)
        k = len(lanes)
        if self.remaining < k:
            raise ValueError(
                f"replay log has {self.remaining} transactions left, "
                f"batch has {k}")
        chunk = self._order[self._consumed:self._consumed + k]
        local = [t - self._offset for t in chunk]
        if sorted(local) != list(range(k)):
            raise ValueError(
                f"replay log entries {chunk!r} are not a permutation of "
                f"this batch's transactions "
                f"[{self._offset}..{self._offset + k - 1}]")
        seq = np.empty(k, np.int64)
        for pos, txn_idx in enumerate(local):
            # keep sequence numbers globally increasing across the stream
            seq[txn_idx] = self._offset + pos + 1
        self._consumed += k
        self._offset += k
        return seq


class ExplicitSequencer:
    """Explicit total order over named transactions; raises on a hang
    (an ordered transaction that no lane ever executes, paper §2.1)."""

    def __init__(self, order: Iterable[str]):
        self._order = list(order)

    def order_for(self, txn_names: Iterable[str]) -> np.ndarray:
        names = list(txn_names)
        missing = [n for n in self._order if n not in names]
        if missing:
            raise RuntimeError(
                f"explicit order waits forever for {missing!r}; "
                "aborting instead of hanging (paper §2.1)")
        pos = {n: i + 1 for i, n in enumerate(self._order)}
        extra = [n for n in names if n not in pos]
        if extra:
            raise RuntimeError(f"transactions not in explicit order: {extra!r}")
        return np.asarray([pos[n] for n in names], np.int64)


def sequencer_state(seq) -> dict:
    """Serialize a sequencer's cursor as a JSON-clean dict (the snapshot
    form — repro.core.checkpoint stores it in the manifest).

    The three built-in sequencers round-trip exactly through
    :func:`sequencer_from_state`; anything else serializes as an
    ``{"type": "opaque"}`` marker, and restoring such a snapshot
    requires passing an explicitly reconstructed ``sequencer=``.
    """
    if isinstance(seq, RoundRobinSequencer):
        return {
            "type": "round_robin",
            "lanes": [[l.lane_id, l.parent, list(l.children), l.alive]
                      for l in seq.lanes.values()],
            "next_sn": seq._next_sn,
            "pending": {str(k): list(v) for k, v in seq._pending.items()},
            "order_log": [[sn, lid] for sn, lid in seq._order_log],
        }
    if isinstance(seq, ReplaySequencer):
        return {"type": "replay", "order": list(seq._order),
                "consumed": seq._consumed, "offset": seq._offset}
    if isinstance(seq, ExplicitSequencer):
        return {"type": "explicit", "order": list(seq._order)}
    return {"type": "opaque", "class": type(seq).__name__}


def sequencer_from_state(state: dict):
    """Rebuild a sequencer from :func:`sequencer_state` output — the
    restored cursor continues the SAME global numbering, which is what
    lets a restored replica rejoin the serialization order mid-stream."""
    kind = state["type"]
    if kind == "round_robin":
        s = RoundRobinSequencer(n_root_lanes=0)
        s.lanes = {
            int(l[0]): Lane(int(l[0]),
                            None if l[1] is None else int(l[1]),
                            [int(c) for c in l[2]], bool(l[3]))
            for l in state["lanes"]}
        s._next_sn = int(state["next_sn"])
        s._pending = {int(k): [int(x) for x in v]
                      for k, v in state["pending"].items()}
        s._order_log = [(int(sn), int(lid))
                        for sn, lid in state["order_log"]]
        return s
    if kind == "replay":
        s = ReplaySequencer(state["order"])
        s._consumed = int(state["consumed"])
        s._offset = int(state["offset"])
        return s
    if kind == "explicit":
        return ExplicitSequencer(state["order"])
    raise ValueError(
        f"cannot reconstruct sequencer state of type {kind!r}; restore "
        "with an explicit sequencer= instead")


def seq_to_order(seq: np.ndarray) -> np.ndarray:
    """(K,) 1-based sequence numbers -> (K,) permutation: order[p] = txn
    index holding sequence position p+1."""
    return np.argsort(seq, kind="stable")
