"""Crash-consistent session checkpoints + deterministic replica failover.

Pot's core promise — one deterministic serialization order — is exactly
what makes fault tolerance cheap (paper §1; Aviram et al. in PAPERS.md):
a replica that crashes anywhere in the stream can rejoin bit-exactly,
because everything it lost is a pure function of (last snapshot, the
shared arrival journal suffix).  This module assembles the pieces the
earlier PRs built — the replayable ingress journal (PR 6), rank-space
sequencing, the layout-polymorphic store (PR 5), the speculative window
(PR 7) — into a crash-consistent runtime layer:

- **Session snapshots** (:func:`save_snapshot` / :func:`restore_session`,
  surfaced as ``PotSession.snapshot`` / ``PotSession.restore``): the
  complete resumable state of a ``PotSession`` — the committed store
  image (dense, or one ``.npz`` per shard, so an S-sharded snapshot
  restores into any S'), ``gv``, the sequencer cursor, the submit /
  formed-batch counters, bucket/compile bookkeeping, the materialized
  replay log, the elastic lane-manager state, and the ingress pool's
  event journal (whose non-drain prefix IS the cursor into the shared
  arrival journal).  The speculative window is always *flushed into*
  the snapshot — speculation is never persisted.

- **Atomic commit protocol** (:func:`atomic_dir`): write everything into
  ``<final>.tmp``, fsync every file and the directory, then atomically
  rename — a crash at ANY point leaves either the previous snapshots or
  a ``.tmp`` turd that restore never looks at.  This is the one
  crash-safety implementation in the repo; ``repro.ckpt.checkpoint``
  (the trainer checkpoint) commits through the same helper.

- **Self-verification**: every snapshot manifest carries per-file
  sha256 digests, the store fingerprint, and a *chained* snapshot
  digest (``sha256(parent_chain || core)``), so a restore proves the
  snapshot complete and uncorrupted — and provably part of one lineage
  — before serving (:func:`load_snapshot`; :func:`latest_snapshot`
  walks back to the newest snapshot that verifies).

- **Deterministic fault injection** (:class:`FaultPlan`): fault points
  are (formed-batch index, phase) positions in the *order* — never
  wall-clock, never RNG — so a fault schedule is as replayable as the
  execution it kills.  ``action="sigkill"`` delivers a real SIGKILL
  (the subprocess harness in tests/test_failover.py);
  ``action="raise"`` raises :class:`FaultInjected` for in-process
  tests.  Torn-write injection corrupts the snapshot tmp directory
  mid-commit (before the rename), proving the latest-complete-snapshot
  invariant.

- **The replica loop** (:func:`run_replica`): admit-journal in, batches
  formed under a deterministic budget schedule, snapshot every N
  batches, faults fired between admit/drain/execute/snapshot steps.
  ``resume=True`` restores from the newest complete snapshot (or cold
  starts when none exists), re-applies the arrival-journal suffix, and
  continues — the **recovery invariant**::

      restore(latest snapshot) + drain(arrival journal suffix)
          ==  the uninterrupted stream, bit for bit

  (store fingerprints, ``ExecTrace``s — speculation observables aside,
  exactly as in PR 7 — and ``replay_log()``), at any snapshot point,
  any drain-budget schedule, any ``pipeline_depth``.

Run one replica from the command line (the subprocess harness)::

    python -m repro.core.checkpoint <config.json> <out.json>
"""

from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
import shutil
import signal

import jax.numpy as jnp
import numpy as np

from repro.core.ingress import EV_DRAIN, IngressPool, JournalError
from repro.core.sequencer import sequencer_from_state, sequencer_state
from repro.core.tstore import TStore, fingerprint as store_fingerprint
from repro.core.tstore import shard_images

SNAP_PREFIX = "snap_"
SNAP_FORMAT = 1
MANIFEST = "manifest.json"

# fault phases, in the order they occur inside one replica-loop turn
PH_ADMIT, PH_DRAIN, PH_EXECUTE, PH_SNAPSHOT = (
    "admit", "drain", "execute", "snapshot")
PHASES = (PH_ADMIT, PH_DRAIN, PH_EXECUTE, PH_SNAPSHOT)


class SnapshotError(RuntimeError):
    """A snapshot is missing, incomplete, corrupted, or off-chain."""


# --------------------------------------------------------------------------
# the atomic tmp/fsync/rename commit protocol (shared with repro.ckpt)
# --------------------------------------------------------------------------
def fsync_dir(path: str) -> None:
    """fsync a directory fd so the rename itself is durable."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def fsync_tree(path: str) -> None:
    """fsync every regular file under ``path``, then the dirs themselves."""
    for root, _dirs, files in os.walk(path):
        for name in files:
            fd = os.open(os.path.join(root, name), os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
        fsync_dir(root)


@contextlib.contextmanager
def atomic_dir(final: str, *, suffix: str = ".tmp"):
    """Atomically materialize the directory ``final``.

    Yields a ``final + suffix`` staging directory to write into.  On
    clean exit: every file is fsynced, an existing ``final`` is
    replaced, the staging dir is renamed into place, and the parent dir
    is fsynced — so a crash at ANY point leaves either the old state or
    a ``*.tmp*`` turd that readers skip, never a half-written ``final``.
    On exception the staging dir is left in place (exactly what a real
    crash leaves behind); it is replaced by the next attempt.
    """
    tmp = final + suffix
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    yield tmp
    fsync_tree(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    fsync_dir(os.path.dirname(final) or ".")


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _core_digest(manifest: dict) -> str:
    """The chained-digest payload: the fields that pin a snapshot's
    identity (execution outcome + exact file contents)."""
    core = {k: manifest[k] for k in
            ("format", "snapshot_id", "gv", "n_txns", "store_fingerprint",
             "replay_log", "files")}
    return hashlib.sha256(
        json.dumps(core, sort_keys=True).encode()).hexdigest()


def chain_digest(parent: str, manifest: dict) -> str:
    """chain = sha256(parent_chain || core): links snapshot k to k-1, so
    a snapshot directory proves it belongs to one replica lineage."""
    return hashlib.sha256(
        (parent + _core_digest(manifest)).encode()).hexdigest()


# --------------------------------------------------------------------------
# trace canonicalization (cross-process comparison / future receipts)
# --------------------------------------------------------------------------
def trace_digest(trace, *, include_spec: bool = False) -> str:
    """Canonical sha256 of an ExecTrace — comparable across processes.

    ``spec_*`` observables are excluded by default: they surface *when*
    speculative work ran (which legitimately differs around a restore
    point, where the window restarts empty), while every other field is
    bit-identical between replicas by the PR 7 pipelining invariant.
    """
    h = hashlib.sha256()
    for f in dataclasses.fields(trace):
        if not include_spec and f.name.startswith("spec_"):
            continue
        arr = np.asarray(getattr(trace, f.name))
        h.update(f.name.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


# --------------------------------------------------------------------------
# snapshot save / load / verify
# --------------------------------------------------------------------------
def _snap_path(directory: str, snapshot_id: int) -> str:
    return os.path.join(directory, f"{SNAP_PREFIX}{snapshot_id:08d}")


def snapshot_ids(directory: str) -> list[int]:
    """Ids of the *committed* snapshots in ``directory``, ascending
    (staging ``*.tmp*`` dirs — crash turds — are never listed)."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if not name.startswith(SNAP_PREFIX) or "tmp" in name:
            continue
        tail = name[len(SNAP_PREFIX):]
        if tail.isdigit():
            out.append(int(tail))
    return sorted(out)


def save_snapshot(session, directory: str, *, pool: IngressPool | None = None,
                  _torn_hook=None) -> str:
    """Write one crash-consistent snapshot of ``session`` (and the pool
    feeding it) under ``directory``; returns the committed path.

    The speculative window is flushed first (speculation is never
    persisted), the replay log is materialized, and everything commits
    through :func:`atomic_dir`.  ``_torn_hook(tmp)``, when given, runs
    after all files are staged and *before* the atomic rename — the
    fault-injection seam for torn-write tests.
    """
    session._spec_flush()
    log = session.replay_log()
    store = session.store
    snap_id = session._next_snapshot_id
    final = _snap_path(directory, snap_id)
    os.makedirs(directory, exist_ok=True)

    images = shard_images(store)
    sharded = isinstance(store, TStore) is False
    manifest = {
        "format": SNAP_FORMAT,
        "snapshot_id": snap_id,
        "engine": session.engine.name,
        "n_objects": int(store.n_objects),
        "slot": int(store.slot),
        "shards": len(images) if sharded else 1,
        "gv": int(store.gv),
        "n_txns": int(session.n_txns),
        "n_batches": len(session.traces),
        "batches_formed": int(session.batches_formed),
        "n_lanes": int(session.n_lanes),
        "bucket": bool(session.bucket),
        "bucket_ladder": session.bucket_ladder,
        "pipeline_depth": int(session.pipeline_depth),
        "replay_log": [int(t) for t in log],
        "bucket_counts": [[int(k), int(l), int(c)] for (k, l), c
                          in sorted(session._bucket_counts.items())],
        "sequencer": sequencer_state(session.sequencer),
        "elastic": (session.elastic.state_dict()
                    if session.elastic is not None else None),
        "pool_journal": (_journal_to_json(pool.journal())
                         if pool is not None else None),
        "snapshots_taken": int(session.snapshots_taken) + 1,
        "restored_from": int(session.restored_from),
        "store_fingerprint": int(store_fingerprint(store)),
        "parent_digest": session._chain_digest,
    }

    with atomic_dir(final) as tmp:
        files: dict[str, str] = {}
        if sharded:
            for i, (vals, vers) in enumerate(images):
                name = f"shard_{i}.npz"
                np.savez(os.path.join(tmp, name),
                         values=np.asarray(vals), versions=np.asarray(vers))
                files[name] = _sha256_file(os.path.join(tmp, name))
        else:
            np.savez(os.path.join(tmp, "store.npz"),
                     values=np.asarray(store.values),
                     versions=np.asarray(store.versions))
            files["store.npz"] = _sha256_file(os.path.join(tmp, "store.npz"))
        manifest["files"] = files
        manifest["chain_digest"] = chain_digest(session._chain_digest,
                                                manifest)
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f)
        if _torn_hook is not None:
            _torn_hook(tmp)

    session.snapshots_taken += 1
    session._chain_digest = manifest["chain_digest"]
    session._next_snapshot_id = snap_id + 1
    return final


def load_snapshot(path: str) -> tuple[dict, np.ndarray, np.ndarray]:
    """Load + self-verify one snapshot directory.

    Returns ``(manifest, values, versions)`` with the store already
    reassembled into its dense (O, slot) / (O,) image.  Raises
    :class:`SnapshotError` unless the snapshot proves itself complete:
    per-file sha256 digests match, the reassembled store re-hashes to
    the manifest's fingerprint, and the chain digest recomputes.
    """
    mpath = os.path.join(path, MANIFEST)
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise SnapshotError(f"unreadable manifest in {path}: {e}") from e
    if manifest.get("format") != SNAP_FORMAT:
        raise SnapshotError(
            f"unknown snapshot format {manifest.get('format')!r} in {path}")
    for name, digest in manifest["files"].items():
        fpath = os.path.join(path, name)
        if not os.path.exists(fpath):
            raise SnapshotError(f"snapshot {path} is missing {name}")
        actual = _sha256_file(fpath)
        if actual != digest:
            raise SnapshotError(
                f"snapshot {path} file {name} is corrupted: sha256 "
                f"{actual[:12]}… != manifest {digest[:12]}…")
    if chain_digest(manifest["parent_digest"], manifest) \
            != manifest["chain_digest"]:
        raise SnapshotError(f"snapshot {path} chain digest does not verify")

    parts = []
    if "store.npz" in manifest["files"]:
        with np.load(os.path.join(path, "store.npz")) as data:
            parts.append((data["values"], data["versions"]))
    else:
        for i in range(manifest["shards"]):
            with np.load(os.path.join(path, f"shard_{i}.npz")) as data:
                parts.append((data["values"], data["versions"]))
    values = np.concatenate([p[0] for p in parts], axis=0)
    versions = np.concatenate([p[1] for p in parts], axis=0)
    o = manifest["n_objects"]
    if values.shape != (o, manifest["slot"]) or versions.shape != (o,):
        raise SnapshotError(
            f"snapshot {path} store image has shape {values.shape}, "
            f"manifest says ({o}, {manifest['slot']})")
    dense = TStore(values=jnp.asarray(values), versions=jnp.asarray(versions),
                   gv=jnp.asarray(manifest["gv"], jnp.int32))
    fp = int(store_fingerprint(dense))
    if fp != manifest["store_fingerprint"]:
        raise SnapshotError(
            f"snapshot {path} store image re-hashes to 0x{fp:08x}, "
            f"manifest says 0x{manifest['store_fingerprint']:08x}")
    return manifest, values, versions


def latest_snapshot(directory: str) -> str | None:
    """Path of the newest snapshot in ``directory`` that *verifies* —
    the latest-complete-snapshot invariant: torn staging dirs are
    invisible (never renamed) and a corrupted committed snapshot is
    skipped in favor of its predecessor.  None when nothing verifies.
    """
    for snap_id in reversed(snapshot_ids(directory)):
        path = _snap_path(directory, snap_id)
        try:
            load_snapshot(path)
        except SnapshotError:
            continue
        return path
    return None


def _journal_to_json(journal) -> list:
    """Journal events as JSON-clean nested lists (tuples round-trip
    through json as lists; IngressPool validation accepts both)."""
    def clean(x):
        if isinstance(x, (list, tuple)):
            return [clean(v) for v in x]
        if isinstance(x, dict):
            return {k: clean(v) for k, v in x.items()}
        if isinstance(x, (np.integer,)):
            return int(x)
        return x
    return [clean(ev) for ev in journal]


def arrival_cursor(journal) -> int:
    """How far into the *shared arrival journal* a pool journal has
    consumed: its non-drain events are exactly the arrival prefix."""
    return sum(1 for ev in journal if ev[0] != EV_DRAIN)


def restore_session(directory: str, *, step: int | None = None,
                    arrival_journal=None, engine: str | None = None,
                    shards: int | None = None, mesh=None,
                    bucket: bool | None = None,
                    bucket_ladder: str | None = None,
                    pipeline_depth: int | None = None,
                    sequencer=None, donate: bool = True):
    """Rebuild a ``(PotSession, IngressPool | None)`` from a snapshot.

    Picks the newest *complete* snapshot under ``directory`` (or exactly
    ``snap_<step>`` when ``step`` is given), self-verifies it
    (:func:`load_snapshot`), and reconstructs the full session state:
    store (resharded into ``shards``/``mesh`` if overridden — snapshots
    are layout-portable), sequencer cursor, replay log, submit/formed
    counters, bucket bookkeeping, elastic lane manager, and the ingress
    pool replayed from its journaled cursor.  With ``arrival_journal``
    (the shared replication feed), the suffix of admissions the snapshot
    had not yet seen is applied to the restored pool, so draining the
    restored replica converges to the uninterrupted stream bit-exactly.

    Overrides (``engine``, ``shards``, ``bucket_ladder``,
    ``pipeline_depth``, ...) default to the snapshot's own values.
    """
    from repro.core.session import PotSession
    from repro.runtime.elastic import ElasticLaneManager

    if step is not None:
        path = _snap_path(directory, step)
        manifest, values, versions = load_snapshot(path)
    else:
        path = latest_snapshot(directory)
        if path is None:
            raise SnapshotError(
                f"no complete snapshot under {directory!r}")
        manifest, values, versions = load_snapshot(path)

    target_shards = shards if shards is not None else manifest["shards"]
    store = TStore(values=jnp.asarray(values),
                   versions=jnp.asarray(versions),
                   gv=jnp.asarray(manifest["gv"], jnp.int32))
    if sequencer is None:
        sequencer = sequencer_from_state(manifest["sequencer"])
    session = PotSession(
        store=store,
        engine=engine if engine is not None else manifest["engine"],
        sequencer=sequencer,
        n_lanes=manifest["n_lanes"],
        donate=donate,
        bucket=bucket if bucket is not None else manifest["bucket"],
        bucket_ladder=(bucket_ladder if bucket_ladder is not None
                       else manifest["bucket_ladder"]),
        shards=target_shards if target_shards > 1 or mesh is not None else 1,
        mesh=mesh,
        pipeline_depth=(pipeline_depth if pipeline_depth is not None
                        else manifest["pipeline_depth"]))

    # resume the session's host-side cursors exactly where the snapshot
    # left them: future batches continue the same global history
    session._n_txns = manifest["n_txns"]
    session._log = list(manifest["replay_log"])
    session._log_batches = 0          # traces list restarts empty …
    session._log_txns = manifest["n_txns"]   # … but ids keep their offset
    session._bucket_counts = {(k, l): c
                              for k, l, c in manifest["bucket_counts"]}
    session._batches_formed = manifest["batches_formed"]
    session.snapshots_taken = manifest["snapshots_taken"]
    session.restored_from = manifest["snapshot_id"]
    session._chain_digest = manifest["chain_digest"]
    session._next_snapshot_id = manifest["snapshot_id"] + 1
    if manifest["elastic"] is not None:
        session.elastic = ElasticLaneManager.from_state(manifest["elastic"])

    pool = None
    if manifest["pool_journal"] is not None:
        pool, _ = IngressPool.replay(manifest["pool_journal"])
        if arrival_journal is not None:
            arrival_journal = list(arrival_journal)
            cursor = arrival_cursor(manifest["pool_journal"])
            if cursor > len(arrival_journal):
                raise JournalError(
                    f"snapshot consumed {cursor} arrival events but the "
                    f"shared journal has only {len(arrival_journal)} — "
                    "journals diverged or the feed was truncated")
            pool.apply(arrival_journal[cursor:])
    return session, pool


# --------------------------------------------------------------------------
# deterministic fault injection
# --------------------------------------------------------------------------
class FaultInjected(RuntimeError):
    """Raised by a ``FaultPlan(action="raise")`` at its fault point."""

    def __init__(self, batch: int, phase: str):
        super().__init__(f"injected fault at batch {batch}, phase {phase!r}")
        self.batch, self.phase = batch, phase


@dataclasses.dataclass
class FaultPlan:
    """A deterministic crash schedule over the replica loop.

    Fault points are positions in the ORDER — (formed-batch index,
    phase) — never wall-clock and never RNG, so a fault plan replays as
    deterministically as the execution it interrupts.  Phases fire
    between the loop's steps: ``admit`` (after the journal is applied,
    before the first drain), ``drain`` (before forming batch k),
    ``execute`` (after forming, before executing batch k), ``snapshot``
    (before the snapshot that follows batch k).  With ``torn=True`` the
    snapshot-phase fault corrupts the staged tmp directory mid-commit
    (truncating the payload before the atomic rename) and THEN dies —
    the torn-write case the latest-complete-snapshot invariant covers.

    ``action``: ``"sigkill"`` (default) delivers a real ``SIGKILL`` to
    the current process — the subprocess harness; ``"raise"`` raises
    :class:`FaultInjected` for in-process tests.
    """

    kill_batch: int | None = None
    kill_phase: str = PH_EXECUTE
    torn: bool = False
    action: str = "sigkill"

    def __post_init__(self):
        if self.kill_phase not in PHASES:
            raise ValueError(f"unknown fault phase {self.kill_phase!r}; "
                             f"pick one of {PHASES}")
        if self.action not in ("sigkill", "raise"):
            raise ValueError(f"unknown fault action {self.action!r}")
        if self.torn and self.kill_phase != PH_SNAPSHOT:
            raise ValueError("torn=True only makes sense at the "
                             "'snapshot' phase (it corrupts the staged "
                             "snapshot mid-commit)")

    def matches(self, batch: int, phase: str) -> bool:
        return self.kill_batch is not None and batch == self.kill_batch \
            and phase == self.kill_phase

    def _die(self, batch: int, phase: str):
        if self.action == "raise":
            raise FaultInjected(batch, phase)
        os.kill(os.getpid(), signal.SIGKILL)   # pragma: no cover

    def fire(self, batch: int, phase: str) -> None:
        """Die iff (batch, phase) is the planned fault point.  The torn
        variant does not fire here — it runs as :meth:`torn_hook` inside
        the snapshot commit instead."""
        if self.matches(batch, phase) and not self.torn:
            self._die(batch, phase)

    def torn_hook(self, tmp: str) -> None:
        """The mid-commit fault: truncate the staged store payload and
        mangle the manifest, then die before the atomic rename — the
        staging dir is left exactly as a torn write would leave it."""
        for name in sorted(os.listdir(tmp)):
            path = os.path.join(tmp, name)
            size = os.path.getsize(path)
            with open(path, "r+b") as f:
                f.truncate(max(size // 2, 1))
        self._die(self.kill_batch if self.kill_batch is not None else -1,
                  PH_SNAPSHOT)


# --------------------------------------------------------------------------
# the replica loop
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ReplicaRun:
    """What one :func:`run_replica` call produced (host-side views)."""

    session: object                     # the PotSession
    pool: IngressPool
    fingerprints: list[int]             # store fingerprint after each
    #                                     executed batch (in record order)

    def summary(self) -> dict:
        """JSON-clean cross-process comparison payload."""
        s = self.session
        return {
            "fingerprint": int(s.fingerprint()),
            "fingerprints": [int(f) for f in self.fingerprints],
            "replay_log": [int(t) for t in s.replay_log()],
            "trace_digests": [trace_digest(t) for t in s.traces],
            "n_batches": len(s.traces),
            "batches_formed": int(s.batches_formed),
            "n_txns": int(s.n_txns),
            "gv": int(s.gv),
            "pool_depth": len(self.pool),
            "restored_from": int(s.restored_from),
            "snapshots_taken": int(s.snapshots_taken),
            "recovery_batches": int(s.recovery_batches),
            "chain_digest": s._chain_digest,
            "elastic": (s.elastic.state_dict()
                        if s.elastic is not None else None),
        }


def run_replica(arrival_journal, *, directory: str, n_objects: int,
                slot: int = 1, engine: str = "pcc", n_lanes: int = 8,
                shards: int = 1, mesh=None, pipeline_depth: int = 0,
                bucket_ladder: str = "pow2", budgets=(16,),
                snapshot_every: int = 2, elastic_events=None,
                fault_plan: FaultPlan | None = None, resume: bool = False,
                record_fingerprints: bool = True) -> ReplicaRun:
    """Serve one replica from a shared arrival journal, snapshotting as
    it goes — the deterministic failover loop.

    Cold start (``resume=False`` or no complete snapshot yet): replay
    the arrival journal into a fresh pool and serve it with a fresh
    session.  Warm start (``resume=True`` with a complete snapshot):
    :func:`restore_session` + the arrival-journal suffix.  Either way
    the loop is a pure function of (journal, budgets, snapshot_every,
    elastic_events): batch k always drains with ``budgets[k %
    len(budgets)]`` and a snapshot commits after every
    ``snapshot_every``-th formed batch (0 disables) — so a restarted
    replica re-enters the SAME schedule at the position the snapshot
    recorded, and its stream is bit-identical to the uninterrupted run.

    ``fault_plan`` fires between steps (see :class:`FaultPlan`).
    """
    from repro.core.session import PotSession
    from repro.runtime.elastic import ElasticLaneManager, ScalingEvent

    plan = fault_plan if fault_plan is not None else FaultPlan()
    budgets = tuple(int(b) for b in budgets)
    if not budgets:
        raise ValueError("budgets must name at least one drain budget")
    arrival_journal = list(arrival_journal)

    session = pool = None
    if resume:
        try:
            session, pool = restore_session(
                directory, arrival_journal=arrival_journal, mesh=mesh)
        except SnapshotError:
            session = pool = None     # nothing committed yet: cold start
    if session is None:
        pool, _ = IngressPool.replay(arrival_journal)
        session = PotSession(n_objects, slot=slot, engine=engine,
                             n_lanes=n_lanes, shards=shards, mesh=mesh,
                             bucket_ladder=bucket_ladder,
                             pipeline_depth=pipeline_depth)
        if elastic_events:
            session.elastic = ElasticLaneManager(
                n_lanes, [ScalingEvent(*ev) for ev in elastic_events])

    fingerprints: list[int] = []

    def _executed(traces):
        # one fingerprint per loop step that committed work: at D=0 this
        # is exactly the per-batch store sequence; pipelined runs emit
        # one per window drain (positions shift, values stay on the
        # committed-batch boundaries)
        if record_fingerprints and traces:
            fingerprints.append(int(session.fingerprint()))

    plan.fire(session.batches_formed, PH_ADMIT)
    while True:
        b = session.batches_formed
        plan.fire(b, PH_DRAIN)
        fb = pool.drain(budgets[b % len(budgets)])
        if fb is None:
            break
        plan.fire(b, PH_EXECUTE)
        _executed(session._serve_formed(fb, ladder=fb.ladder))
        done = session.batches_formed
        if snapshot_every and done % snapshot_every == 0:
            hook = None
            if plan.matches(done, PH_SNAPSHOT) and plan.torn:
                hook = plan.torn_hook
            else:
                plan.fire(done, PH_SNAPSHOT)
            session.snapshot(directory, pool=pool, _torn_hook=hook)
            if record_fingerprints:
                # the snapshot flushed the speculative window: record
                # the store state the snapshot actually captured
                fingerprints.append(int(session.fingerprint()))
    _executed(session._spec_flush())
    return ReplicaRun(session=session, pool=pool, fingerprints=fingerprints)


# --------------------------------------------------------------------------
# subprocess harness entry point
# --------------------------------------------------------------------------
def _main(argv) -> int:     # pragma: no cover - exercised via subprocess
    """``python -m repro.core.checkpoint <config.json> <out.json>``:
    run one replica per the JSON config, write its summary atomically.

    Config keys = :func:`run_replica` kwargs plus ``journal`` (the
    arrival journal as nested lists) and optional ``fault`` (a
    :class:`FaultPlan` field dict).  A victim run simply never writes
    its out file — SIGKILL is the point.
    """
    cfg_path, out_path = argv
    with open(cfg_path) as f:
        cfg = json.load(f)
    journal = cfg.pop("journal")
    fault = cfg.pop("fault", None)
    plan = FaultPlan(**fault) if fault else None
    run = run_replica(journal, fault_plan=plan, **cfg)
    payload = run.summary()
    with atomic_dir(out_path + ".d") as tmp:
        with open(os.path.join(tmp, "out.json"), "w") as f:
            json.dump(payload, f)
    shutil.move(os.path.join(out_path + ".d", "out.json"), out_path)
    shutil.rmtree(out_path + ".d", ignore_errors=True)
    return 0


if __name__ == "__main__":   # pragma: no cover
    import sys
    raise SystemExit(_main(sys.argv[1:]))
