"""Shared pieces of the concurrency-control engines: conflict detection and
ordered write-back over transaction footprints (read/write sets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def footprint_conflicts(written: jax.Array, raddrs, rn, waddrs, wn) -> jax.Array:
    """Does this txn's footprint overlap ``written`` (O,) bool?

    This is the validation step (paper Fig. 2b line 9): a read-write or
    write-write overlap with a transaction that committed after our read
    phase means the speculation is stale.
    """
    length = raddrs.shape[0]
    idx = jnp.arange(length)
    r_hit = jnp.any(jnp.where(idx < rn, written[raddrs], False))
    w_hit = jnp.any(jnp.where(idx < wn, written[waddrs], False))
    return r_hit | w_hit


def mark_writes(written: jax.Array, waddrs, wn) -> jax.Array:
    """written |= this txn's write set."""
    length = waddrs.shape[0]
    n_obj = written.shape[0]
    tgt = jnp.where(jnp.arange(length) < wn, waddrs, n_obj)
    return written.at[tgt].set(True, mode="drop")


def dedup_last_writer(waddrs, wn):
    """Mask selecting, per address, only the LAST write-set entry (a txn may
    write the same object twice; the later deferred write must win)."""
    length = waddrs.shape[0]
    idx = jnp.arange(length)
    valid = idx < wn
    shadowed = (
        (waddrs[None, :] == waddrs[:, None])
        & (idx[None, :] > idx[:, None])
        & valid[None, :]
    ).any(axis=1)
    return valid & ~shadowed


def apply_writes(values, versions, waddrs, wvals, wn, seq_no):
    """Write-back one committing txn: install deferred values and stamp the
    objects' versions with the txn's sequence number (paper §3.1: sequence
    numbers retrofitted as TL2 versions)."""
    n_obj = values.shape[0]
    keep = dedup_last_writer(waddrs, wn)
    tgt = jnp.where(keep, waddrs, n_obj)
    values = values.at[tgt].set(wvals, mode="drop")
    versions = versions.at[tgt].set(seq_no, mode="drop")
    return values, versions
