"""Shared pieces of the concurrency-control engines.

Three layers:

**Scalar helpers** (`footprint_conflicts` / `mark_writes` /
`apply_writes`) — the per-transaction primitives used by the serial
paths (PoGL, PCC live promotion, DeSTM token-held retries) and by the
preserved scan engines in :mod:`repro.core.legacy_scan`.

**Incremental round state** (PR 3) — :class:`RoundState`, the
persistent execution state every engine threads through its
`lax.while_loop` rounds instead of rebuilding from scratch:

* the committed store image (``values`` / ``versions``);
* the cached per-transaction :class:`~repro.core.txn.TxnResult` —
  :func:`refresh_round_state` re-executes only the *live* rows
  (uncommitted/aborted transactions, via
  :func:`repro.core.txn.run_live`) and keeps the settled rows' cached
  results, so a low-contention round no longer pays a full-batch
  ``run_all`` on already-committed transactions;
* the carried conflict structure — the K×K ``conflict`` table plus,
  on TPU, the bit-packed footprints behind it
  (``kernels.ops.update_packed_footprints``): only the rows/columns of
  re-executed transactions are recomputed per round, via the
  masked-row variant of the bitset-intersection Pallas kernel
  (``kernels.conflict.conflict_matrix_bits_delta``; dense
  recompute-and-select fallback off-TPU).

Correctness rests on one invariant: an engine's commit decision only
ever *consumes* conflict entries and footprint rows of transactions
that are still pending — and every pending transaction is live, hence
refreshed.  Settled rows go stale in the cache but are masked out of
every reduction, so the incremental loop is bit-identical to the
from-scratch rebuild (``incremental=False`` on every engine, asserted
by tests and by ``scripts/ci.sh --incremental-smoke``).

**Gather-compacted rounds** (PR 4) — the masked executor still walks the
full static (K, L) grid even when only a handful of rows are live
(shapes are static under jit).  Engines therefore run their round loop
as a *cascade* over :func:`compact_ladder` widths: once the live set
fits a narrower rung C, the read phase gathers the live rows into a
(C, L) block, executes that
(:func:`refresh_round_state_compact` / the caller-ordered
:func:`refresh_round_state_gathered`), and scatters results — plus the
packed-footprint rows and the conflict table's refreshed row/column
strips (``kernels.ops.conflict_matrix_delta_compact``, two rectangular
bitset-intersection strips instead of a K×K pass) — back to full-K
positions.  Commit decisions and the fused write-back stay in rank
space, so the cascade is bit-identical to the masked loop
(``compact=False``; asserted by tests and ``scripts/ci.sh
--compact-smoke``); only the device work changes, from K·L to C·L per
round (``RoundState.walked_slots`` / ``ExecTrace.walked_slots``).
DeSTM's ≤ n_lanes rounds are the degenerate always-compact case: its
members run through :func:`refresh_round_state_gathered` in token
order at width n_lanes.  Vacant rows (``n_ins == 0`` — shape-bucket
padding from ``PotSession.submit``) never enter a live set and never
commit; :func:`prefix_commit` takes the ``real`` mask to enforce it.

**Shard-partitioned stores** (PR 5) — every function in this module is
layout-polymorphic over :class:`repro.core.tstore.StoreLayout`: with
the store partitioned into S contiguous range shards
(:class:`~repro.core.tstore.ShardedStore`), the read phase executes
against the flat view of the stacked shards (bit-identical — padding
rows are never addressed), the conflict analysis decomposes per shard
— (S, K, ceil(C/32)) packed footprints, per-shard tables OR-reduced
into the carried K×K ``conflict`` (kernels/ops.py ``*_sharded`` twins
of the full, masked-delta and compact-strip paths) — and
:func:`fused_write_back` splits into S *independent* scatters (one per
device under ``jax.experimental.shard_map`` when the layout carries a
mesh, a vmap over the shard axis otherwise).  The invariant making S a
pure layout knob: conflict(t, u) == OR over shards of per-shard
conflicts, and every commit decision stays in global rank space — so
sharded runs are bit-identical to dense ones (tests/
test_sharded_store.py, ``scripts/ci.sh --shard-smoke``).

**Vectorized commit pipeline** (PR 2) — the batched commit machinery
shared by PCC / OCC / DeSTM.  Instead of walking K transactions through
a `lax.scan` with an O(n_objects) bitmap probe and a `lax.cond`
write-back each (K sequential device steps per round), a round is three
batched stages:

1. conflict analysis — the carried ``RoundState.conflict`` table
   (:func:`conflict_table` builds the from-scratch equivalent);
2. a commit *decision* — :func:`prefix_commit` (the maximal in-order
   prefix, an `associative_scan` cumulative-AND: ≤⌈log₂K⌉ device
   steps) or :func:`wave_commit` (OCC's greedy arrival-order kernel, a
   fixpoint that converges in the conflict-chain depth, one batched
   step per iteration; its trip count is surfaced in
   ``ExecTrace.wave_trips``).  Both consume
   :func:`earlier_writer_conflicts`, which answers "does position p's
   footprint hit the writes of a marked position q < p" either as a
   masked row-reduction of the conflict matrix (TPU: regular,
   VPU-friendly, exactly the dense-bitset argument of validate.py) or
   as a first-writer-per-address scatter-min + gather (O(K·L) work —
   the right trade on backends where irregular gathers are cheap and
   K² dense work is not).  The two formulations are decision-identical
   (asserted in tests);
3. :func:`fused_write_back` — every committing transaction's deferred
   writes installed in ONE flattened scatter, the winner per address
   selected by (commit-position, write-slot) segment-max, subsuming
   both the per-transaction apply chain and per-transaction
   last-writer dedup.

All stages reproduce the scan engines' decisions bit-exactly
(tests/test_commit_pipeline.py asserts equality against
`legacy_scan` and a pure-NumPy reference on random batches).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.tstore import StoreLayout, flat_values
from repro.core.txn import (TxnBatch, TxnResult, gather_live_indices,
                            next_pow2, run_compact, run_live,
                            scatter_result, scatter_rows)
from repro.kernels import ops as kernel_ops


def footprint_conflicts(written: jax.Array, raddrs, rn, waddrs, wn) -> jax.Array:
    """Does this txn's footprint overlap ``written`` (O,) bool?

    This is the validation step (paper Fig. 2b line 9): a read-write or
    write-write overlap with a transaction that committed after our read
    phase means the speculation is stale.
    """
    length = raddrs.shape[0]
    idx = jnp.arange(length)
    r_hit = jnp.any(jnp.where(idx < rn, written[raddrs], False))
    w_hit = jnp.any(jnp.where(idx < wn, written[waddrs], False))
    return r_hit | w_hit


def mark_writes(written: jax.Array, waddrs, wn) -> jax.Array:
    """written |= this txn's write set."""
    length = waddrs.shape[0]
    n_obj = written.shape[0]
    tgt = jnp.where(jnp.arange(length) < wn, waddrs, n_obj)
    return written.at[tgt].set(True, mode="drop")


def dedup_last_writer(waddrs, wn):
    """Mask selecting, per address, only the LAST write-set entry (a txn may
    write the same object twice; the later deferred write must win).

    Sort-based O(F log F): order the slots by address (stable, so equal
    addresses keep slot order) and keep a slot iff it is valid and the
    next slot in sorted order holds a different address.
    """
    length = waddrs.shape[0]
    idx = jnp.arange(length)
    valid = idx < wn
    # invalid slots sort behind every real address (addresses are object
    # ids, far below int32 max)
    key = jnp.where(valid, waddrs, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(key)
    sorted_key = key[order]
    nxt = jnp.concatenate([sorted_key[1:],
                           jnp.full((1,), -1, sorted_key.dtype)])
    last_of_run = sorted_key != nxt
    keep = jnp.zeros((length,), bool).at[order].set(last_of_run)
    return valid & keep


def _dedup_last_writer_reference(waddrs, wn):
    """Pre-PR2 all-pairs O(F²) formulation, kept as the behavioral oracle
    for :func:`dedup_last_writer` (tests/test_commit_pipeline.py)."""
    length = waddrs.shape[0]
    idx = jnp.arange(length)
    valid = idx < wn
    shadowed = (
        (waddrs[None, :] == waddrs[:, None])
        & (idx[None, :] > idx[:, None])
        & valid[None, :]
    ).any(axis=1)
    return valid & ~shadowed


def apply_writes(values, versions, waddrs, wvals, wn, seq_no,
                 layout: StoreLayout | None = None):
    """Write-back one committing txn: install deferred values and stamp the
    objects' versions with the txn's sequence number (paper §3.1: sequence
    numbers retrofitted as TL2 versions).

    Under a sharded ``layout`` the scatter splits per shard: address a
    lands in shard a // C at offset a % C — same values, same winners
    (a transaction's deduped writes hit distinct addresses), hence
    bit-identical to the dense scatter.
    """
    keep = dedup_last_writer(waddrs, wn)
    if layout is not None and layout.sharded:
        shard = jnp.where(keep, layout.shard_of(waddrs), layout.shards)
        off = layout.offset_of(waddrs)
        values = values.at[shard, off].set(wvals, mode="drop")
        versions = versions.at[shard, off].set(seq_no, mode="drop")
        return values, versions
    n_obj = values.shape[0]
    tgt = jnp.where(keep, waddrs, n_obj)
    values = values.at[tgt].set(wvals, mode="drop")
    versions = versions.at[tgt].set(seq_no, mode="drop")
    return values, versions


# --------------------------------------------------------------------------
# Vectorized commit pipeline
# --------------------------------------------------------------------------
#
# Everything below works in TRANSACTION space (storage order), with the
# serialization order threaded through as ``rank`` — rank[t] = the
# sequence position of txn t (engines compute it once per batch via
# engine.rank_from_order).  Staying in txn space keeps the hot per-round
# path free of (K, L) permutation gathers: the only order-dependent
# arrays are (K,) rank comparisons.


def _matrix_backend() -> bool:
    # one dispatch predicate shared with the kernel wrappers
    return kernel_ops._on_tpu()


def conflict_table(res, n_objects: int,
                   use_matrix: bool | None = None) -> jax.Array | None:
    """The round's K×K footprint-vs-write-set conflict matrix, in txn
    space: entry (i, j) = footprint(i) ∩ writes(j) ≠ ∅ (the paper's
    per-txn validation question asked for all ordered pairs at once).

    Materialized only where the dense bitset-intersection kernel is the
    right formulation (TPU, `kernels/conflict.py`; cf. validate.py's
    dense-bitset argument).  Returns ``None`` elsewhere —
    :func:`earlier_writer_conflicts` then uses the first-writer
    scatter-min formulation, which gives identical verdicts with O(K·L)
    work (asserted in tests/test_commit_pipeline.py).
    """
    if use_matrix is None:
        use_matrix = _matrix_backend()
    if not use_matrix:
        return None
    return kernel_ops.conflict_matrix(
        res.raddrs, res.rn, res.waddrs, res.wn, n_objects)


# --------------------------------------------------------------------------
# Incremental round state (PR 3)
# --------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RoundState:
    """Persistent per-batch execution state threaded through an engine's
    `lax.while_loop` rounds.

    ``res`` caches every transaction's last speculative execution; a
    round calls :func:`refresh_round_state` with the mask of *live*
    (still-pending) transactions and only those rows re-execute — the
    paper's abort-and-retry, restricted to the transactions it actually
    applies to.  ``conflict`` (and on TPU the packed ``foot_bits`` /
    ``write_bits`` behind it) is carried the same way: suffix footprints
    change only via re-execution, so only live rows/columns are
    recomputed.  ``live_txns`` / ``live_slots`` accumulate the actual
    re-execution work for the trace (the observable proving settled
    transactions are skipped).

    ``conflict``/``foot_bits``/``write_bits`` are ``None`` when the
    engine uses the scatter-min conflict formulation (off-TPU default)
    or carries no table at all (DeSTM's compact-block rounds); the
    choice is static per trace, so the pytree structure is while_loop-
    stable.
    """

    values: jax.Array        # (O, S) committed store image
    versions: jax.Array      # (O,)
    res: TxnResult           # cached speculative executions (K rows)
    conflict: jax.Array | None    # (K, K) carried conflict table
    foot_bits: jax.Array | None   # (K, W) packed footprints (TPU path)
    write_bits: jax.Array | None  # (K, W) packed write sets (TPU path)
    live: jax.Array          # (K,) bool — rows refreshed this round
    live_txns: jax.Array     # () int32 — Σ rounds live count
    live_slots: jax.Array    # () int32 — Σ rounds live instruction slots
    walked_slots: jax.Array  # () int32 — Σ rounds executor width × L (the
    #   device slots the read phase actually walked; K·L per masked
    #   round, C·L per compact round — see ExecTrace.walked_slots)


def init_round_state(batch: TxnBatch, values: jax.Array,
                     versions: jax.Array, *,
                     track_conflict: bool = True,
                     use_matrix: bool | None = None,
                     layout: StoreLayout | None = None) -> RoundState:
    """A fresh RoundState with empty caches.

    ``track_conflict=False`` (DeSTM) carries no table — the engine asks
    its conflict questions on a compacted per-round block instead.
    ``use_matrix`` follows :func:`conflict_table`'s backend dispatch:
    when the scatter-min formulation is in use there is no table to
    carry either.  Cache rows start zeroed; the caller's invariant is
    that every row is refreshed (appears in a ``refresh_round_state``
    live mask) no later than the first round in which it is consumed —
    PCC/OCC satisfy it by making every pending transaction live, DeSTM
    by making exactly the round's members live (a member's row is only
    ever consumed in its own round).

    Under a sharded ``layout`` the conflict analysis is always the
    matrix formulation, partitioned per shard: ``foot_bits`` /
    ``write_bits`` carry (S, K, ceil(C/32)) packed words — each shard's
    bitset spans only its own C-object range — and ``conflict`` carries
    the OR-reduced K×K table the decisions consume (decision-identical
    to both dense formulations; see kernels/ops.py).
    """
    sharded = layout is not None and layout.sharded
    if use_matrix is None:
        use_matrix = _matrix_backend() or sharded
    k, length = batch.opcodes.shape
    slot = values.shape[-1]
    z = jnp.zeros
    res = TxnResult(
        raddrs=z((k, length), jnp.int32), rn=z((k,), jnp.int32),
        waddrs=z((k, length), jnp.int32),
        wvals=z((k, length, slot), jnp.int32), wn=z((k,), jnp.int32))
    conflict = foot_bits = write_bits = None
    if track_conflict and use_matrix:
        conflict = z((k, k), bool)
        if sharded:
            w = layout.words_per_shard
            foot_bits = z((layout.shards, k, w), jnp.int32)
            write_bits = z((layout.shards, k, w), jnp.int32)
        elif kernel_ops._on_tpu():
            w = -(-values.shape[0] // 32)
            foot_bits = z((k, w), jnp.int32)
            write_bits = z((k, w), jnp.int32)
    return RoundState(
        values=values, versions=versions, res=res, conflict=conflict,
        foot_bits=foot_bits, write_bits=write_bits,
        live=z((k,), bool), live_txns=z((), jnp.int32),
        live_slots=z((), jnp.int32), walked_slots=z((), jnp.int32))


def refresh_round_state(state: RoundState, batch: TxnBatch,
                        live: jax.Array,
                        layout: StoreLayout | None = None) -> RoundState:
    """One round's incremental read phase: re-execute the live rows
    against the current store image and delta-update the carried
    conflict structure.

    Post-conditions (tests/test_round_state.py):

    * ``res`` rows with ``live`` equal the same rows of a from-scratch
      ``run_all(batch, state.values)``; settled rows are carried
      bit-exactly;
    * ``conflict`` entries (i, j) with ``live[i] or live[j]`` equal the
      from-scratch table built from the merged ``res``; entries between
      two settled transactions keep last round's verdict (they are
      stale but, by the pending ⊆ live invariant, never consumed).

    Under a sharded ``layout``, execution runs against the flat view of
    the stacked shards (bit-identical — see ``tstore.flat_values``) and
    the conflict delta decomposes per shard, OR-reduced into the carried
    K×K table (kernels/ops.py sharded twins).
    """
    sharded = layout is not None and layout.sharded
    n_obj = layout.n_objects if layout is not None \
        else state.values.shape[0]
    res = run_live(batch, flat_values(state.values, layout), live,
                   state.res, n_objects=n_obj)
    conflict, foot_bits, write_bits = (
        state.conflict, state.foot_bits, state.write_bits)
    if conflict is not None:
        if sharded:                 # per-shard bitsets, OR-reduced table
            foot_bits, write_bits = \
                kernel_ops.update_packed_footprints_sharded(
                    foot_bits, write_bits, res.raddrs, res.rn,
                    res.waddrs, res.wn, live, layout)
            conflict = kernel_ops.conflict_matrix_delta_sharded(
                foot_bits, write_bits, conflict, live, layout)
        elif foot_bits is not None:  # TPU: packed bitsets + masked kernel
            foot_bits, write_bits = kernel_ops.update_packed_footprints(
                foot_bits, write_bits, res.raddrs, res.rn, res.waddrs,
                res.wn, live, n_obj)
            conflict = kernel_ops.conflict_matrix_delta(
                foot_bits, write_bits, conflict, live, n_obj)
        else:                       # dense recompute-and-select fallback
            fresh = kernel_ops._conflict_matrix_dense(
                res.raddrs, res.rn, res.waddrs, res.wn, n_obj)
            refresh = live[:, None] | live[None, :]
            conflict = jnp.where(refresh, fresh, conflict)
    k, length = batch.opcodes.shape
    return RoundState(
        values=state.values, versions=state.versions, res=res,
        conflict=conflict, foot_bits=foot_bits, write_bits=write_bits,
        live=live,
        live_txns=state.live_txns + live.sum(dtype=jnp.int32),
        live_slots=state.live_slots
        + jnp.where(live, batch.n_ins, 0).sum(dtype=jnp.int32),
        walked_slots=state.walked_slots + jnp.asarray(k * length, jnp.int32))


def commit_round_state(state: RoundState, values: jax.Array,
                       versions: jax.Array) -> RoundState:
    """Fold a round's committed store image back into the carried state."""
    return dataclasses.replace(state, values=values, versions=versions)


# --------------------------------------------------------------------------
# Gather-compacted rounds (PR 4)
# --------------------------------------------------------------------------


def compact_ladder(k: int, min_width: int = 8, step: int = 4) -> list[int]:
    """The descending compact widths an engine's round cascade runs at:
    ``[k, p/step, p/step², ...]`` with ``p = next_pow2(k)``, stopping
    above ``min_width`` (where gather/scatter overhead would eat the
    saving).  Rung 0 is the full masked width (round 0's live set is the
    whole batch); each later rung is entered only once the live count
    fits it, so a rung-C round's device work is C·L, not K·L.  Shapes
    are static under jit, hence a *static* ladder of loop bodies rather
    than a per-round dynamic width; its length is O(log K), bounding
    compile cost.
    """
    widths = [k]
    c = next_pow2(k) // step
    while c >= min_width and c < k:
        widths.append(c)
        c //= step
    return widths


def run_compact_cascade(ladder: list[int], state, body_at, cond_at):
    """Drive an engine's round loop down the compact ladder: one
    `lax.while_loop` per rung, where ``body_at(width)`` builds the round
    body executing the read phase at that width and ``cond_at(next_width)``
    builds the loop predicate that additionally hands over to the next
    rung once the live set fits it (``next_width`` is 0 on the last rung —
    no hand-over, run to completion).  The carried ``state`` pytree must
    be rung-independent; only the body internals change width.  Shared by
    PCC and OCC so the hand-over rule lives in exactly one place."""
    for i, width in enumerate(ladder):
        nxt = ladder[i + 1] if i + 1 < len(ladder) else 0
        state = jax.lax.while_loop(cond_at(nxt), body_at(width), state)
    return state


def refresh_round_state_gathered(state: RoundState, batch: TxnBatch,
                                 idx: jax.Array, valid: jax.Array,
                                 layout: StoreLayout | None = None
                                 ) -> tuple[RoundState, TxnResult]:
    """One round's read phase over a caller-gathered compact block: execute
    rows ``batch[idx]`` (``valid`` masks gather padding, possibly with
    duplicate indices) at width C = ``idx.shape[0]`` and scatter the
    results — plus, when a conflict table is carried, the packed-footprint
    rows and the table's refreshed row/column strips — back to full-K
    positions.

    The caller chooses the gather order: :func:`refresh_round_state_compact`
    packs live rows ascending; DeSTM passes its round members in token
    order so the returned compact block feeds the token walk directly.

    Bit-identical post-conditions to :func:`refresh_round_state` with
    ``live = scatter(valid at idx)`` (asserted in
    tests/test_compact_bucket.py): row purity makes the compact execution
    equal the masked one row-for-row, and decisions downstream stay in
    rank space, so they cannot tell the two read phases apart.

    Returns ``(state, cres)`` — the compact (C, L) result block is
    exposed for engines that keep working at width C.
    """
    k, length = batch.opcodes.shape
    width = idx.shape[0]
    sharded = layout is not None and layout.sharded
    n_obj = layout.n_objects if layout is not None \
        else state.values.shape[0]
    cres = run_compact(batch, flat_values(state.values, layout), idx,
                       valid, n_objects=n_obj)
    res = scatter_result(state.res, cres, idx, valid, k)
    live = scatter_rows(jnp.zeros((k,), bool), valid, idx, valid)
    conflict, foot_bits, write_bits = (
        state.conflict, state.foot_bits, state.write_bits)
    if conflict is not None:
        if sharded:                 # per-shard strips, OR-reduced table
            foot_bits, write_bits = \
                kernel_ops.update_packed_footprints_compact_sharded(
                    foot_bits, write_bits, cres.raddrs, cres.rn,
                    cres.waddrs, cres.wn, idx, valid, layout)
            conflict = kernel_ops.conflict_matrix_delta_compact_sharded(
                foot_bits, write_bits, conflict, idx, valid, layout)
        elif foot_bits is not None:  # TPU: packed strips + pair kernel
            foot_bits, write_bits = kernel_ops.update_packed_footprints_compact(
                foot_bits, write_bits, cres.raddrs, cres.rn, cres.waddrs,
                cres.wn, idx, valid, n_obj)
            conflict = kernel_ops.conflict_matrix_delta_compact(
                foot_bits, write_bits, conflict, idx, valid, n_obj)
        else:                       # dense recompute-and-select fallback
            fresh = kernel_ops._conflict_matrix_dense(
                res.raddrs, res.rn, res.waddrs, res.wn, n_obj)
            refresh = live[:, None] | live[None, :]
            conflict = jnp.where(refresh, fresh, conflict)
    return RoundState(
        values=state.values, versions=state.versions, res=res,
        conflict=conflict, foot_bits=foot_bits, write_bits=write_bits,
        live=live,
        live_txns=state.live_txns + valid.sum(dtype=jnp.int32),
        live_slots=state.live_slots
        + jnp.where(valid, batch.n_ins[idx], 0).sum(dtype=jnp.int32),
        walked_slots=state.walked_slots
        + jnp.asarray(width * length, jnp.int32)), cres


def refresh_round_state_compact(state: RoundState, batch: TxnBatch,
                                live: jax.Array, width: int,
                                layout: StoreLayout | None = None
                                ) -> tuple[RoundState, TxnResult,
                                           jax.Array, jax.Array]:
    """One round's read phase at compact width C = ``width``: gather the
    live rows (ascending index) into a (C, L) block and refresh through
    :func:`refresh_round_state_gathered`.  Requires
    ``live.sum() <= width`` — the caller's rung invariant (engines only
    descend a :func:`compact_ladder` rung once the live count fits it).

    Returns ``(state, cres, idx, valid)``.
    """
    idx, valid = gather_live_indices(live, width)
    state, cres = refresh_round_state_gathered(state, batch, idx, valid,
                                               layout)
    return state, cres, idx, valid


# --------------------------------------------------------------------------
# Cross-batch speculative pipelining (PR 7)
# --------------------------------------------------------------------------
#
# While batch n's tail rounds commit, PotSession executes batch n+1
# against the store image snapshotted at enqueue time (spec_execute),
# capturing the round-0 read phase AND the conflict analysis as a
# SpecSeed.  When batch n+1's turn comes, the engine re-bases the seed
# onto the now-current store (seed_round_state): rows whose read set
# hit an address written after the snapshot (versions > snap_gv — the
# exact dirty predicate, version stamps being globally monotone
# sequence numbers) re-execute through the same compact-ladder
# machinery; every other row's cached result is already bit-identical
# to what a fresh round 0 would produce, because a row's execution is
# a pure function of its read values (read-your-writes is row-local
# and logged in raddrs, so chained indirect reads are covered by
# induction along the read chain).  The engine then charges round 0's
# ordinary work accounting without re-walking it, and everything
# downstream — commit decisions, write-back, trace — is the serial
# computation on bit-identical inputs.  Ranks stay globally consecutive
# across batches, so the validation never leaves rank space.


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SpecSeed:
    """A speculative round-0 execution of one batch against an earlier
    store snapshot: the cached results and conflict structure a seeded
    engine re-bases instead of re-walking (see module section above).
    ``conflict``/``foot_bits``/``write_bits`` mirror
    :class:`RoundState`'s backend-static optionality."""

    res: TxnResult                # (K rows) speculative executions
    conflict: jax.Array | None    # (K, K) speculative conflict table
    foot_bits: jax.Array | None   # packed footprints (TPU / sharded)
    write_bits: jax.Array | None  # packed write sets  (TPU / sharded)
    snap_gv: jax.Array            # () int32 — store.gv at the snapshot


def spec_execute(store, batch: TxnBatch) -> SpecSeed:
    """Speculatively run ``batch``'s round-0 read phase + conflict
    analysis against ``store``'s current image and capture it as a
    :class:`SpecSeed`.  Pure read — the store is not modified (and the
    session's jit of this function must NOT donate it)."""
    layout = store.layout
    rs = init_round_state(batch, store.values, store.versions,
                          layout=layout)
    rs = refresh_round_state(rs, batch, batch.n_ins > 0, layout)
    return SpecSeed(res=rs.res, conflict=rs.conflict,
                    foot_bits=rs.foot_bits, write_bits=rs.write_bits,
                    snap_gv=store.gv)


def speculation_invalid(res: TxnResult, versions: jax.Array,
                        snap_gv: jax.Array,
                        layout: StoreLayout | None = None) -> jax.Array:
    """(K,) bool — rows whose logged read set touches an address written
    after the snapshot (``versions > snap_gv``).  Read-set-only is
    sound: clean reads replay bit-identically (row purity), and a row's
    own writes need no check — its write set is a function of its reads.
    Conservative only where run_txn logs a read-your-writes read whose
    address happens to be dirty (a false re-execution, never a false
    accept)."""
    if layout is not None and layout.sharded:
        return kernel_ops.spec_read_invalid_sharded(
            res.raddrs, res.rn, versions, snap_gv, layout)
    n_obj = layout.n_objects if layout is not None else versions.shape[0]
    return kernel_ops.spec_read_invalid(res.raddrs, res.rn, versions,
                                        snap_gv, n_obj)


def seed_round_state(batch: TxnBatch, store, seed: SpecSeed,
                     compact: bool = True
                     ) -> tuple[RoundState, jax.Array, jax.Array]:
    """Re-base a :class:`SpecSeed` onto the current store: validate the
    speculated rows, re-execute only the invalidated ones (through the
    compact ladder when they fit a narrow rung), and return a
    RoundState whose ``res``/``conflict``/``foot_bits``/``write_bits``
    are bit-identical to a fresh round-0 refresh of the whole batch
    against ``store`` — with the work counters zeroed, so the engine's
    round 0 can charge its ordinary accounting on top and the trace
    stays bit-identical to the serial run (the re-execution cost is
    surfaced separately, via the returned counts).

    Returns ``(state, n_invalid, spec_rounds)`` — ``spec_rounds`` is 1
    iff any row re-executed, else 0.
    """
    layout = store.layout
    k = batch.n_txns
    rs = init_round_state(batch, store.values, store.versions,
                          layout=layout)
    rs = dataclasses.replace(rs, res=seed.res, conflict=seed.conflict,
                             foot_bits=seed.foot_bits,
                             write_bits=seed.write_bits)
    real = batch.n_ins > 0
    invalid = speculation_invalid(seed.res, store.versions, seed.snap_gv,
                                  layout) & real
    n_inv = invalid.sum(dtype=jnp.int32)
    # exactly-one-rung dispatch over the same ladder the engines cascade
    # down: the narrowest width the invalidated set fits re-executes it
    ladder = compact_ladder(k) if compact else [k]
    for i, width in enumerate(ladder):
        nxt = ladder[i + 1] if i + 1 < len(ladder) else 0
        sel = n_inv > nxt
        if width < k:
            sel = sel & (n_inv <= width)

        def refresh(r, width=width):
            if width >= k:
                return refresh_round_state(r, batch, invalid, layout)
            return refresh_round_state_compact(r, batch, invalid, width,
                                               layout)[0]

        rs = jax.lax.cond(sel, refresh, lambda r: r, rs)
    z = jnp.zeros
    rs = dataclasses.replace(
        rs, live=z((k,), bool), live_txns=z((), jnp.int32),
        live_slots=z((), jnp.int32), walked_slots=z((), jnp.int32))
    return rs, n_inv, (n_inv > 0).astype(jnp.int32)


def charge_round_state(state: RoundState, batch: TxnBatch,
                       live: jax.Array, width: int) -> RoundState:
    """The accounting-only twin of a round-0 refresh at ``width``: set
    the live mask and charge exactly the counters
    :func:`refresh_round_state` (full rung) or
    :func:`refresh_round_state_compact` (``live.sum() <= width``, where
    the gathered ``valid`` count equals ``live.sum()``) would — without
    touching ``res`` or the conflict structure, which a
    :func:`seed_round_state` re-base already made bit-identical."""
    length = batch.opcodes.shape[1]
    return dataclasses.replace(
        state, live=live,
        live_txns=state.live_txns + live.sum(dtype=jnp.int32),
        live_slots=state.live_slots
        + jnp.where(live, batch.n_ins, 0).sum(dtype=jnp.int32),
        walked_slots=state.walked_slots
        + jnp.asarray(width * length, jnp.int32))


def earlier_writer_conflicts(res, conflict, writer_mask: jax.Array,
                             rank: jax.Array, n_objects: int) -> jax.Array:
    """bad (K,) bool, txn space: does txn t's footprint (reads ∪ writes)
    hit the write set of any txn q with ``writer_mask[q]`` that comes
    earlier in the serialization order (rank[q] < rank[t])?

    This is the one conflict question every engine's commit decision
    reduces to (PCC: q pending this round; OCC: q currently committing;
    DeSTM: q a remaining round member).  Two exact formulations:

    * matrix path (``conflict`` present): a masked row-reduction of the
      precomputed K×K matrix — one batched step, perfectly regular (the
      TPU-native choice);
    * scatter path (``conflict`` is None): the *first marked writer per
      address* via one scatter-min over write slots, then a footprint
      gather — O(K·L) work with no K² term (the right trade where
      irregular gathers are cheap).
      ∃ marked q earlier writing address a  ⟺  first_writer[a] < rank.
    """
    if conflict is not None:
        earlier = writer_mask[None, :] & (rank[None, :] < rank[:, None])
        return (conflict & earlier).any(axis=1)
    k, length = res.waddrs.shape
    slot = jnp.arange(length)
    wvalid = (slot[None, :] < res.wn[:, None]) & writer_mask[:, None]
    first_writer = jnp.full((n_objects + 1,), k, jnp.int32).at[
        jnp.where(wvalid, res.waddrs, n_objects)
    ].min(jnp.where(wvalid, rank[:, None], k).astype(jnp.int32))
    rvalid = slot[None, :] < res.rn[:, None]
    r_hit = jnp.where(rvalid, first_writer[res.raddrs], k) < rank[:, None]
    svalid = slot[None, :] < res.wn[:, None]
    w_hit = jnp.where(svalid, first_writer[res.waddrs], k) < rank[:, None]
    return r_hit.any(axis=1) | w_hit.any(axis=1)


def cross_writer_conflicts(reader_res, writer_res, writer_mask: jax.Array,
                           rank: jax.Array, n_objects: int,
                           reads_only: bool = False) -> jax.Array:
    """bad (C,) bool: does reader row t's footprint (or, with
    ``reads_only``, its logged read set alone) hit the write set of a
    writer row q with ``writer_mask[q]`` and ``rank[q] < rank[t]``?

    The two-block generalization of :func:`earlier_writer_conflicts`
    for DeSTM's wave-speculative retries (PR 10), where the question
    crosses result blocks: a row's *speculative* footprint against a
    wave's *re-executed* write sets (classification agreement), and a
    wave row's re-executed read set against the block's resolved write
    sets (execution validity).  Verdicts come from the rectangular
    strip kernel (:func:`repro.kernels.ops.cross_conflicts`) masked to
    earlier-rank marked writers — rank space, like every commit
    decision."""
    mat = kernel_ops.cross_conflicts(
        reader_res.raddrs, reader_res.rn, reader_res.waddrs, reader_res.wn,
        writer_res.waddrs, writer_res.wn, n_objects, reads_only=reads_only)
    earlier = writer_mask[None, :] & (rank[None, :] < rank[:, None])
    return (mat & earlier).any(axis=1)


def prefix_commit(res, conflict, order: jax.Array, rank: jax.Array,
                  n_comm: jax.Array, n_objects: int,
                  real: jax.Array | None = None) -> jax.Array:
    """Maximal committing in-order prefix (PCC's ordered commit, §2.2.2).

    A pending position commits iff no position of this round's pending
    prefix up to and including it conflicts with an earlier *committing*
    transaction.  Under the prefix rule "conflicts with an earlier
    committing txn" equals "conflicts with ANY earlier pending txn":
    every pending position before the first conflict commits, and
    nothing after it does.  That collapses the old K-step scan into one
    batched conflict query plus a cumulative AND — ≤⌈log₂K⌉ device
    steps via `associative_scan`.

    n_comm: () int32 count of already-committed positions.  ``real``
    optionally masks out *vacant* rows (bucket padding, ``n_ins == 0`` —
    they sort after every real row and must never commit).  Returns
    committing (K,) bool in TXN space.
    """
    k = rank.shape[0]
    pending = rank >= n_comm
    if real is not None:
        pending = pending & real
    bad = earlier_writer_conflicts(res, conflict, pending, rank, n_objects)
    # positions before the pending window never break the chain
    ok_pos = jnp.where(jnp.arange(k) >= n_comm, ~bad[order], True)
    alive_pos = jax.lax.associative_scan(jnp.logical_and, ok_pos)
    return pending & alive_pos[rank]


def wave_commit(res, conflict, pending: jax.Array, rank: jax.Array,
                n_objects: int, block: int = 1) -> jax.Array:
    """OCC's arrival-order wave rule: c[t] = pending[t] ∧ ¬∃ earlier q:
    c[q] ∧ conflict[t, q] — the greedy kernel of the conflict DAG (no
    prefix rule: a conflicting txn aborts but later ones keep
    committing).

    Solved by fixpoint iteration from the optimistic start c = pending;
    each step is one batched conflict query, and the iteration provably
    reaches the unique solution in at most the conflict-chain depth:
    a txn's verdict is final once all its conflict predecessors'
    verdicts are, by induction along the order.

    ``block`` unrolls B conflict queries per `while_loop` trip (the
    blocked solve): on deep conflict chains the dominant cost is the
    per-trip loop overhead (condition sync + carried-state round trip),
    which the unroll divides by B.  Decision-identical for ANY block:
    the iterates F(c), F²(c), ... from c = pending converge monotonely
    layer-by-layer to the unique greedy solution, and a convergent
    sequence with F^B(c) == c must already sit AT the fixpoint (a
    B-periodic tail of a convergent sequence is constant), so the
    blocked convergence test never exits early on a non-solution and
    terminates once B·trips covers the chain depth.

    Returns ``(committing, trips)`` — ``trips`` () int32 counts
    `while_loop` trips (≥ 1; the final converging trip is included),
    i.e. ceil over B of the wave's conflict-chain depth + 1.  Engines
    accumulate it into ``ExecTrace.wave_trips`` so contention cost is
    observable per round.
    """

    def body(state):
        c, _, trips = state
        start = c
        for _ in range(block):
            blocked = earlier_writer_conflicts(res, conflict, c, rank,
                                               n_objects)
            c = pending & ~blocked
        return c, (c == start).all(), trips + 1

    c, _, trips = jax.lax.while_loop(
        lambda s: ~s[1], body,
        (pending, jnp.asarray(False), jnp.zeros((), jnp.int32)))
    return c, trips


def fused_write_back(values, versions, waddrs, wvals, wn, committing,
                     rank, seq_nos, layout: StoreLayout | None = None):
    """Install a whole round of commits in one flattened scatter.

    waddrs (K, L) / wvals (K, L, S) / wn (K,) / committing (K,) /
    rank (K,) / seq_nos (K,) are all in txn space; ``committing``
    selects the round's committers and ``seq_nos`` carries each txn's
    version stamp.  The winning writer per address is the one with the
    largest (rank, slot) priority — serialization-order-major, so a
    later committing transaction overwrites an earlier one, and
    slot-minor, so within one transaction the later deferred write
    shadows the earlier (subsuming :func:`dedup_last_writer`).
    Priorities are unique per slot, hence exactly one winner per
    address and a duplicate-free scatter.

    Under a sharded ``layout`` the round's scatter splits into S
    *independent* per-shard scatters (winner selection is per address,
    and an address lives in exactly one shard, so each shard's winners
    are decided from exactly the writes the dense scatter would route
    there — bit-identical).  With ``layout.mesh`` set, the S scatters
    run one-per-device under ``jax.experimental.shard_map``; otherwise
    they run as one vmap over the shard axis.
    """
    if layout is not None and layout.sharded:
        return _fused_write_back_sharded(
            values, versions, waddrs, wvals, wn, committing, rank,
            seq_nos, layout)
    # the dense store IS the one-shard case: shard 0 spanning the whole
    # address space (every executor address is < n_obj, so the shard
    # filter is a no-op) — one copy of the winner-selection logic
    return _shard_write_back(values, versions, 0, waddrs, wvals, wn,
                             committing, rank, seq_nos, values.shape[0])


def _shard_write_back(values_s, versions_s, shard, waddrs, wvals, wn,
                      committing, rank, seq_nos, shard_size: int):
    """One shard's slice of :func:`fused_write_back`: the (rank, slot)
    segment-max winner selection, restricted to the write slots whose
    address falls in this shard's range and rebased to shard-local
    offsets.  ``values_s`` (C, slot) / ``versions_s`` (C,).  THE single
    copy of the winner-selection logic — the dense scatter is the
    degenerate call with ``shard=0, shard_size=n_obj``."""
    c = values_s.shape[0]
    k, length = waddrs.shape
    slot = jnp.arange(length)
    valid = (committing[:, None] & (slot[None, :] < wn[:, None])
             & (waddrs // shard_size == shard))
    prio = (rank.astype(jnp.int32)[:, None] * length
            + slot[None, :].astype(jnp.int32))
    addr = jnp.where(valid, waddrs % shard_size, c).reshape(-1)
    flat_prio = jnp.where(valid, prio, -1).reshape(-1)
    best = jnp.full((c + 1,), -1, jnp.int32).at[addr].max(flat_prio)
    win = valid.reshape(-1) & (flat_prio == best[addr])
    tgt = jnp.where(win, addr, c)
    values_s = values_s.at[tgt].set(wvals.reshape(k * length, -1),
                                    mode="drop")
    versions_s = versions_s.at[tgt].set(
        jnp.repeat(jnp.asarray(seq_nos, jnp.int32), length), mode="drop")
    return values_s, versions_s


def _fused_write_back_sharded(values, versions, waddrs, wvals, wn,
                              committing, rank, seq_nos,
                              layout: StoreLayout):
    """S independent per-shard commit scatters (see fused_write_back).

    values (S, C, slot) / versions (S, C).  The mesh path shards the
    store axis one-shard-per-device and replicates the (K, L) round
    operands — each device installs exactly its own range's writes, no
    cross-device traffic beyond the broadcast of the round's operands.
    """
    wb = functools.partial(_shard_write_back,
                           shard_size=layout.shard_size)
    if layout.mesh is None:
        return jax.vmap(
            wb, in_axes=(0, 0, 0) + (None,) * 6)(
                values, versions, jnp.arange(layout.shards), waddrs,
                wvals, wn, committing, rank, seq_nos)

    from jax.experimental.shard_map import shard_map
    axis = tuple(layout.mesh.shape.keys())[0]
    spec = jax.sharding.PartitionSpec

    def body(values_b, versions_b, waddrs, wvals, wn, committing, rank,
             seq_nos):
        v, ver = wb(values_b[0], versions_b[0], jax.lax.axis_index(axis),
                    waddrs, wvals, wn, committing, rank, seq_nos)
        return v[None], ver[None]

    return shard_map(
        body, mesh=layout.mesh,
        in_specs=(spec(axis), spec(axis)) + (spec(),) * 6,
        out_specs=(spec(axis), spec(axis)),
        check_rep=False,
    )(values, versions, waddrs, wvals, wn, committing, rank,
      jnp.asarray(seq_nos, jnp.int32))
