"""Deterministic ingress: admission pool + priority-drain batch former.

This is the layer *above* everything the engine pipeline built: a
production system serving millions of clients never sees neat pre-built
batches — it sees a firehose of single transactions arriving on client
connections.  Pot's determinism guarantee starts at the preordered
sequence (paper §2.1), so the component that *forms* that sequence under
real traffic must itself be deterministic: two replicas fed the same
arrivals must emit bit-identical batch streams end-to-end (QueCC's
queue-oriented planning under a predefined order; Aviram et al. on
deterministic scheduling as the basis of cheap replication).

**The no-wall-clock rule.**  Nothing in this module may read a clock,
an RNG, or any other ambient nondeterminism.  Every quantity that looks
temporal is *logical*: arrivals carry a monotone integer **stamp** (the
admission counter, or a caller-supplied logical time), "age" is a stamp
difference, and priorities are integer arithmetic over (fee, age, size).
This is what makes an :class:`IngressPool` a pure state machine — its
entire behavior is a function of the admission/drain event sequence, so
the event journal IS the replication/replay substrate.

The pool does four things:

1. **Admission** (:meth:`IngressPool.admit`): a transaction enters with
   a per-client *lane* id, a *fee* (the caller's priority pressure), and
   a logical arrival *stamp*.  Capacity is bounded: when an admission
   pushes occupancy past ``capacity``, the pool deterministically evicts
   the worst-priority lane *tails* down to the ``evict_to`` watermark
   (tails, so every lane's surviving queue stays a contiguous prefix of
   its program order — no holes in a client's sequence).  Occupancy at
   or above ``backpressure_at`` raises the :attr:`backpressure` signal
   (callers should throttle; admission itself stays deterministic
   whether they do or not).  Per-client lanes are the DoS posture: one
   client's flood competes on priority like everyone else and is first
   in line for tail eviction.
2. **Per-lane sequencing**: each admitted transaction gets a per-lane
   sequence number from a :class:`~repro.core.sequencer
   .RoundRobinSequencer` (lanes join/leave via :meth:`spawn_lane` /
   :meth:`stop_lane`, the paper's lane-tree events), so a lane's program
   order is preserved end-to-end: the drain never reorders two
   transactions of the same lane.
3. **Priority drain** (:meth:`IngressPool.drain`): forms a
   :class:`FormedBatch` of up to ``budget`` transactions by repeatedly
   picking the best *lane head* under the total order

       key(t) = (-effective_priority(t), lane(t), lane_seq(t))

   with ``effective_priority = fee·fee_weight - size·size_weight +
   age_weight·((latest_stamp - stamp) // age_unit)`` — fee pressure,
   size pressure, and logical-age pressure (anti-starvation: parked
   transactions climb as newer stamps arrive).  Only lane heads are
   eligible, which is what preserves per-lane order; ties break by
   (lane, lane_seq), never by arrival interleaving.  The drain order is
   the preordered sequence: the batch rows come out in drain order and
   carry globally consecutive sequence numbers, ready for
   ``PotSession.serve``.  Because the key is a pure function of pool
   state and draining removes entries without touching stamps, the flat
   drained sequence is invariant to how a drain prefix is partitioned
   into budgets: ``drain(3); drain(5)`` emits the same eight
   transactions in the same order as ``drain(8)``.
4. **Batch forming**: the drain also picks the (K, L) *bucket family*
   for the formed batch from observed queue occupancy — the recent
   drain-size history: when mid-size tails dominate (pow-of-two padding
   would waste ≥ 2× the slots of the dense {1,2,4,8} ∪ 8·n ladder), it
   recommends the ``dense`` bucket ladder, otherwise ``pow2``
   (:meth:`preferred_ladder`, closing the PR 5 auto-selection loop).
   The recommendation rides on the FormedBatch; padding itself stays in
   ``PotSession`` and uses :func:`repro.core.txn.pad_batch`'s vacant-row
   convention, so the choice can never change committed state — only
   compile counts and padding waste.

**Arrival journal.**  Every admission, lane event, and drain call is
recorded as a plain-data event tuple.  :meth:`IngressPool.replay` feeds
a journal through a fresh pool and reproduces the exact original
FormedBatch stream — admissions, evictions, drain order, sequence
numbers, bucket choices, everything.  :meth:`arrival_journal` is the
drain-free view: feed it to N replicas, let each drain under its own
budgets/interleavings, and every replica emits the same flat
transaction sequence (and therefore bit-identical stores through
``PotSession``) for any drain schedules that cover the same prefix.
Journal loading is defensive: :meth:`IngressPool.replay` /
:meth:`IngressPool.apply` validate every event (shape, kind, arity,
field types, stamp monotonicity) and raise :class:`JournalError` with
the failing index instead of diverging on a truncated, reordered, or
corrupted feed — a replica must prove its feed well-formed before
serving it (the failover restore path in ``repro.core.checkpoint``
rides on ``apply``).
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Iterable, Sequence

import numpy as np

from repro.core.sequencer import RoundRobinSequencer
from repro.core.txn import TxnBatch, make_batch, next_pow2

# journal event kinds (plain tuples so a journal is transport-friendly)
EV_CONFIG, EV_SPAWN, EV_STOP, EV_ADMIT, EV_DRAIN = (
    "config", "spawn", "stop", "admit", "drain")

# the knobs that must match between replicas for bit-identical behavior;
# they travel in the journal's leading config event
_CONFIG_KEYS = ("capacity", "evict_to", "backpressure_at", "fee_weight",
                "age_weight", "age_unit", "size_weight",
                "ladder_window")

# event arity per kind (including the kind tag itself) — the cheap
# structural gate journal loading applies before touching pool state
_EV_ARITY = {EV_CONFIG: 2, EV_SPAWN: 3, EV_STOP: 2, EV_ADMIT: 5,
             EV_DRAIN: 2}


class JournalError(ValueError):
    """A journal failed validation: truncated, reordered, or corrupted.

    Raised by :meth:`IngressPool.replay` / :meth:`IngressPool.apply`
    with the failing event's index, instead of letting a malformed
    tuple fail deep inside drain/``make_batch`` with an opaque shape
    error.  The journal IS the replication substrate — a replica must
    refuse a feed it cannot prove well-formed rather than diverge.
    """


@dataclasses.dataclass(frozen=True)
class _Entry:
    """One admitted transaction parked in the pool."""

    txn_id: int        # admission id (global counter, 0-based)
    lane: int          # client lane
    lane_seq: int      # per-lane sequence number (RoundRobinSequencer)
    stamp: int         # logical arrival stamp (monotone, no wall-clock)
    fee: int           # caller priority pressure
    program: tuple     # ((op, addr, indirect, operand), ...) — immutable

    @property
    def size(self) -> int:
        return len(self.program)


@dataclasses.dataclass(frozen=True)
class AdmitResult:
    """Outcome of one admission attempt."""

    admitted: bool
    txn_id: int                   # -1 when rejected outright
    stamp: int
    lane_seq: int                 # -1 when rejected outright
    evicted: tuple[int, ...]      # txn_ids evicted by this admission
    #                               (may include txn_id itself: the
    #                               incoming txn lost the watermark
    #                               eviction and admitted is False)
    backpressure: bool            # pool at/over the backpressure mark
    reason: str = ""


@dataclasses.dataclass
class PoolStats:
    """Monotone ingress counters (the metrics CSV observables)."""

    admitted: int = 0             # accepted and still-or-once pooled
    rejected: int = 0             # refused outright (stopped lane, ...)
    evicted: int = 0              # watermark-evicted after admission
    drained: int = 0              # handed to a FormedBatch
    drain_calls: int = 0
    backpressure_admits: int = 0  # admissions while the signal was up


@dataclasses.dataclass
class FormedBatch:
    """One drained batch: the preordered sequence segment it represents.

    Rows are in drain order; ``seq`` is globally consecutive across the
    pool's lifetime (1-based), so the drain order IS the serialization
    order when submitted through ``PotSession.serve``.
    """

    batch: TxnBatch
    lanes: np.ndarray      # (K,) client lane per row
    seq: np.ndarray        # (K,) global sequence numbers, ascending
    txn_ids: np.ndarray    # (K,) admission ids (journal cross-reference)
    stamps: np.ndarray     # (K,) logical arrival stamps
    ladder: str            # occupancy-recommended bucket family
    budget: int            # the drain budget that formed this batch

    @property
    def n_txns(self) -> int:
        return self.batch.n_txns


def programs_from_batch(batch: TxnBatch) -> list[tuple]:
    """Invert :func:`repro.core.txn.make_batch`: recover each row's live
    instruction tuple — the admission-side representation.  Lets existing
    workload generators feed an IngressPool."""
    op = np.asarray(batch.opcodes)
    ad = np.asarray(batch.addrs)
    ind = np.asarray(batch.indirect)
    opr = np.asarray(batch.operands)
    n = np.asarray(batch.n_ins)
    return [tuple((int(op[i, j]), int(ad[i, j]), bool(ind[i, j]),
                   int(opr[i, j])) for j in range(int(n[i])))
            for i in range(op.shape[0])]


def dense_bucket(k: int) -> int:
    """The denser small-K serving ladder: {1, 2, 4, 8} below 8, then
    multiples of 8 (mirrors ``PotSession``'s ``bucket_ladder="dense"``)."""
    if k <= 8:
        return next_pow2(k)
    return -(-k // 8) * 8


class IngressPool:
    """Deterministic admission pool + priority-drain batch former.

    Args:
      capacity: hard bound on parked transactions.  An admission that
        pushes occupancy past it triggers watermark eviction.
      evict_to: occupancy the eviction drains down to (default
        ``3 * capacity // 4``) — eviction runs in bursts so each
        overflow pays once, not per admission.
      backpressure_at: occupancy at which :attr:`backpressure` raises
        (default ``evict_to``).  Purely a signal — admission semantics
        do not change, so replicas with and without throttling callers
        stay deterministic.
      fee_weight / age_weight / age_unit / size_weight: integer priority
        formula knobs (see the module docstring).  ``age_unit <= 0``
        disables age pressure.
      ladder_window: how many recent drain sizes inform
        :meth:`preferred_ladder`.

    All knobs are recorded in the journal's config event, so
    :meth:`replay` reconstructs an identically-configured pool.
    """

    def __init__(self, capacity: int = 4096, *, evict_to: int | None = None,
                 backpressure_at: int | None = None, fee_weight: int = 16,
                 age_weight: int = 1, age_unit: int = 64,
                 size_weight: int = 1, ladder_window: int = 64):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.evict_to = (int(evict_to) if evict_to is not None
                         else max(1, (3 * self.capacity) // 4))
        if not 0 < self.evict_to <= self.capacity:
            raise ValueError(
                f"evict_to must be in [1, capacity], got {self.evict_to}")
        self.backpressure_at = (int(backpressure_at)
                                if backpressure_at is not None
                                else self.evict_to)
        self.fee_weight = int(fee_weight)
        self.age_weight = int(age_weight)
        self.age_unit = int(age_unit)
        self.size_weight = int(size_weight)
        self.ladder_window = int(ladder_window)
        # lane lifecycle + per-lane sequence numbers ride the paper's
        # sequencer; the pool's lanes are RoundRobinSequencer lanes
        self._seqr = RoundRobinSequencer(n_root_lanes=0)
        self._queues: dict[int, deque[_Entry]] = {}
        self._stopped: set[int] = set()
        self._depth = 0
        self._stamp = 0           # latest logical arrival stamp
        self._next_txn_id = 0
        self._drain_seq = 0       # global seq numbers handed out so far
        self._drain_sizes: list[int] = []
        self.stats = PoolStats()
        self._journal: list[tuple] = [
            (EV_CONFIG, {k: getattr(self, k) for k in _CONFIG_KEYS})]

    # ------------------------------------------------------------ lanes
    def spawn_lane(self, lane_id: int, parent: int | None = None) -> int:
        """Register a client lane (journaled).  ``parent`` threads the
        paper's lane tree through the round-robin sequencer; root lanes
        (no parent) order by id."""
        lane_id = int(lane_id)
        if lane_id in self._seqr.lanes:
            raise ValueError(f"lane {lane_id} already exists")
        if parent is None:
            self._seqr.ensure_lane(lane_id)
        else:
            self._seqr.spawn_lane(int(parent), lane_id)
        self._queues.setdefault(lane_id, deque())
        self._journal.append((EV_SPAWN, lane_id,
                              None if parent is None else int(parent)))
        return lane_id

    def stop_lane(self, lane_id: int) -> None:
        """Stop a lane (journaled): already-parked transactions still
        drain in order, but new admissions on the lane are rejected and
        the round-robin refill stops feeding it."""
        lane_id = int(lane_id)
        if lane_id not in self._seqr.lanes:
            raise KeyError(f"unknown lane {lane_id}")
        self._seqr.stop_lane(lane_id)
        self._stopped.add(lane_id)
        self._journal.append((EV_STOP, lane_id))

    # -------------------------------------------------------- admission
    @property
    def depth(self) -> int:
        """Parked transactions right now (the queue-depth observable)."""
        return self._depth

    def __len__(self) -> int:
        return self._depth

    @property
    def backpressure(self) -> bool:
        """True when occupancy is at/over the backpressure watermark —
        the deterministic "slow down" signal for admission callers."""
        return self._depth >= self.backpressure_at

    def _eff_priority(self, e: _Entry) -> int:
        age = ((self._stamp - e.stamp) // self.age_unit
               if self.age_unit > 0 else 0)
        return (e.fee * self.fee_weight - e.size * self.size_weight
                + age * self.age_weight)

    def _drain_key(self, e: _Entry) -> tuple[int, int, int]:
        """The total drain order: best-first under
        (-priority, lane, lane_seq).  Pure in (entry, pool stamp)."""
        return (-self._eff_priority(e), e.lane, e.lane_seq)

    def admit(self, program: Sequence[tuple], *, lane: int = 0,
              fee: int = 0, stamp: int | None = None) -> AdmitResult:
        """Admit one transaction (journaled).

        ``program`` is the transaction's instruction list
        (``(opcode, addr, indirect, operand)`` tuples — the
        :func:`make_batch` row form).  ``stamp`` defaults to the next
        logical instant; an explicit stamp must be >= the latest one
        (callers may admit a *group* under one stamp — drain order over
        distinct lanes is then invariant to the admission order within
        the group, because the drain key never consults arrival
        interleaving).
        """
        lane = int(lane)
        program = tuple(tuple(ins) for ins in program)
        if not program:
            raise ValueError(
                "empty program: an n_ins == 0 row is the vacant-row "
                "padding convention and would never commit; admit a "
                "single NOP instead")
        for i, ins in enumerate(program):
            # fail at admission, not deep inside drain's make_batch
            if len(ins) != 4:
                raise ValueError(
                    f"program instruction {i} has {len(ins)} fields, "
                    f"expected 4 (opcode, addr, indirect, operand): "
                    f"{ins!r}")
        if lane in self._stopped:
            self.stats.rejected += 1
            return AdmitResult(False, -1, self._stamp, -1, (),
                               self.backpressure, reason="lane stopped")
        if stamp is None:
            stamp = self._stamp + 1
        else:
            stamp = int(stamp)
            if stamp < self._stamp:
                raise ValueError(
                    f"stamps must be non-decreasing: got {stamp} after "
                    f"{self._stamp} (logical time cannot run backwards)")
        bp = self.backpressure
        if bp:
            self.stats.backpressure_admits += 1
        self._stamp = stamp
        if lane not in self._seqr.lanes:
            self._seqr.ensure_lane(lane)
            self._queues.setdefault(lane, deque())
        self._journal.append((EV_ADMIT, stamp, lane, int(fee), program))
        lane_seq = self._seqr.get_seq_no(lane)
        entry = _Entry(self._next_txn_id, lane, lane_seq, stamp,
                       int(fee), program)
        self._next_txn_id += 1
        self._queues[lane].append(entry)
        self._depth += 1
        self.stats.admitted += 1
        evicted: tuple[int, ...] = ()
        if self._depth > self.capacity:
            evicted = self._evict_down_to(self.evict_to)
        admitted = entry.txn_id not in evicted
        return AdmitResult(admitted, entry.txn_id, stamp, lane_seq,
                           evicted, bp,
                           reason="" if admitted else "evicted at admission")

    def admit_many(self, txns: Iterable[tuple], *,
                   stamp: int | None = None) -> list[AdmitResult]:
        """Admit a group of ``(program, lane, fee)`` tuples under one
        logical stamp (defaults to the next instant).  Drain order over
        the group's distinct lanes is invariant to its internal order."""
        txns = list(txns)
        if stamp is None:
            stamp = self._stamp + 1
        return [self.admit(p, lane=l, fee=f, stamp=stamp)
                for p, l, f in txns]

    def _evict_down_to(self, target: int) -> tuple[int, ...]:
        """Deterministic watermark eviction: drop worst-priority lane
        *tails* (largest drain key) until occupancy <= target.  Tails
        keep every lane's surviving queue a contiguous prefix of its
        program order."""
        evicted: list[int] = []
        while self._depth > target:
            worst_lane, worst_key = -1, None
            for lane in sorted(self._queues):
                q = self._queues[lane]
                if not q:
                    continue
                key = self._drain_key(q[-1])
                if worst_key is None or key > worst_key:
                    worst_key, worst_lane = key, lane
            if worst_lane < 0:      # pragma: no cover - depth bookkeeping
                break
            e = self._queues[worst_lane].pop()
            self._depth -= 1
            self.stats.evicted += 1
            evicted.append(e.txn_id)
        return tuple(evicted)

    # ------------------------------------------------------------ drain
    def preferred_ladder(self) -> str:
        """Occupancy-driven bucket-family choice for the formed batches:
        ``dense`` when the recent drain sizes' pow2 padding would waste
        at least twice the slots of the dense {1,2,4,8} ∪ 8·n ladder,
        else ``pow2``.  Deterministic in the drain-size history."""
        ks = self._drain_sizes[-self.ladder_window:]
        if not ks:
            return "pow2"
        waste_p = sum(next_pow2(k) - k for k in ks)
        waste_d = sum(dense_bucket(k) - k for k in ks)
        return "dense" if waste_p > 0 and 2 * waste_d <= waste_p \
            else "pow2"

    def drain(self, budget: int) -> FormedBatch | None:
        """Form the next batch: up to ``budget`` transactions in drain
        order (journaled).  Returns None when the pool is empty.

        Pure in (pool state, budget): repeatedly pops the lane head with
        the smallest ``(-priority, lane, lane_seq)`` key.  Priorities are
        fixed for the duration of the call (stamps only advance on
        admission), so partitioning a drain prefix into budgets cannot
        change the flat drained sequence."""
        budget = int(budget)
        if budget < 1:
            raise ValueError(f"drain budget must be >= 1, got {budget}")
        self._journal.append((EV_DRAIN, budget))
        self.stats.drain_calls += 1
        heap = [(self._drain_key(q[0]), lane)
                for lane, q in self._queues.items() if q]
        heapq.heapify(heap)
        picked: list[_Entry] = []
        while heap and len(picked) < budget:
            _, lane = heapq.heappop(heap)
            q = self._queues[lane]
            picked.append(q.popleft())
            if q:
                heapq.heappush(heap, (self._drain_key(q[0]), lane))
        if not picked:
            return None
        k = len(picked)
        self._depth -= k
        self.stats.drained += k
        self._drain_sizes.append(k)
        batch = make_batch([list(e.program) for e in picked])
        base = self._drain_seq
        self._drain_seq += k
        return FormedBatch(
            batch=batch,
            lanes=np.asarray([e.lane for e in picked], np.int64),
            seq=np.arange(base + 1, base + k + 1, dtype=np.int64),
            txn_ids=np.asarray([e.txn_id for e in picked], np.int64),
            stamps=np.asarray([e.stamp for e in picked], np.int64),
            ladder=self.preferred_ladder(), budget=budget)

    def drain_all(self, budget: int) -> list[FormedBatch]:
        """Drain to empty in ``budget``-sized batches."""
        out = []
        while True:
            fb = self.drain(budget)
            if fb is None:
                return out
            out.append(fb)

    # ---------------------------------------------------------- journal
    def journal(self) -> list[tuple]:
        """The full event journal (config, lane events, admissions,
        drains) — plain tuples, replayable via :meth:`replay`."""
        return list(self._journal)

    def arrival_journal(self) -> list[tuple]:
        """The drain-free journal view: config + lane events +
        admissions.  Feed it to replicas that choose their own drain
        schedules — any schedules covering the same drain prefix emit
        the same flat transaction sequence."""
        return [ev for ev in self._journal if ev[0] != EV_DRAIN]

    @staticmethod
    def _check_event(ev, index: int) -> tuple:
        """Structural validation of one journal event (defensive journal
        loading): shape, kind, arity, field types.  Accepts the tuple
        form and its JSON round-trip (lists); raises
        :class:`JournalError` naming the failing index."""
        if not isinstance(ev, (tuple, list)) or not ev:
            raise JournalError(
                f"journal event {index} is not an event tuple: {ev!r} "
                "(journal corrupted?)")
        kind = ev[0]
        if kind not in _EV_ARITY:
            raise JournalError(
                f"journal event {index} has unknown kind {kind!r} "
                "(journal corrupted?)")
        if len(ev) != _EV_ARITY[kind]:
            raise JournalError(
                f"journal event {index} ({kind!r}) has {len(ev)} fields, "
                f"expected {_EV_ARITY[kind]} — truncated or corrupted "
                f"event: {ev!r}")
        if kind == EV_ADMIT:
            _, stamp, lane, fee, program = ev
            for field, val in (("stamp", stamp), ("lane", lane),
                               ("fee", fee)):
                if not isinstance(val, (int, np.integer)) \
                        or isinstance(val, bool):
                    raise JournalError(
                        f"journal event {index} (admit) has non-integer "
                        f"{field} {val!r} (journal corrupted?)")
            if not isinstance(program, (tuple, list)) or not program:
                raise JournalError(
                    f"journal event {index} (admit) has no program "
                    f"(truncated event?): {program!r}")
            for i, ins in enumerate(program):
                if not isinstance(ins, (tuple, list)) or len(ins) != 4:
                    raise JournalError(
                        f"journal event {index} (admit) instruction {i} "
                        f"is not a 4-field tuple: {ins!r} (journal "
                        "corrupted?)")
        elif kind in (EV_SPAWN, EV_STOP, EV_DRAIN):
            if not isinstance(ev[1], (int, np.integer)) \
                    or isinstance(ev[1], bool):
                raise JournalError(
                    f"journal event {index} ({kind!r}) has non-integer "
                    f"argument {ev[1]!r} (journal corrupted?)")
        return tuple(ev)

    def apply(self, events: Iterable[tuple], *,
              base_index: int = 0) -> list[FormedBatch]:
        """Apply a validated journal suffix to THIS pool (the restore /
        catch-up path: a replica restored from a snapshot feeds the
        arrival-journal events its snapshot had not yet seen).

        Every event is structurally validated before touching pool
        state, and semantic violations (a stamp running backwards = a
        reordered journal; lane events against an impossible lane tree)
        are wrapped as :class:`JournalError` with the failing event's
        index.  Returns the FormedBatches produced by replayed drains.
        """
        formed: list[FormedBatch] = []
        for i, ev in enumerate(events):
            index = base_index + i
            ev = self._check_event(ev, index)
            kind = ev[0]
            if kind == EV_CONFIG:
                raise JournalError(
                    f"journal event {index} is a config event mid-"
                    "journal — journals were concatenated or reordered")
            try:
                if kind == EV_SPAWN:
                    self.spawn_lane(ev[1], parent=ev[2])
                elif kind == EV_STOP:
                    self.stop_lane(ev[1])
                elif kind == EV_ADMIT:
                    _, stamp, lane, fee, program = ev
                    self.admit(program, lane=lane, fee=fee, stamp=stamp)
                else:   # EV_DRAIN (kinds are exhaustive per _check_event)
                    fb = self.drain(ev[1])
                    if fb is not None:
                        formed.append(fb)
            except JournalError:
                raise
            except (KeyError, ValueError) as e:
                raise JournalError(
                    f"journal event {index} ({kind!r}) cannot apply: {e} "
                    "— reordered or corrupted journal") from e
        return formed

    @classmethod
    def replay(cls, journal: Iterable[tuple]
               ) -> tuple["IngressPool", list[FormedBatch]]:
        """Feed a journal through a fresh pool.  Reproduces the original
        pool bit-exactly: admissions (with their original stamps),
        evictions, lane events, and — for journaled drains — the exact
        FormedBatch stream, in order.  Returns ``(pool, formed)``.

        Defensive by construction (:class:`JournalError`): the journal
        must lead with a well-formed config event carrying exactly the
        replica-affecting knobs, and every subsequent event is validated
        by :meth:`apply` before it touches pool state."""
        journal = list(journal)
        if not journal:
            raise JournalError("empty journal: not even a config event "
                               "(was the feed truncated?)")
        head = cls._check_event(journal[0], 0)
        if head[0] != EV_CONFIG:
            raise JournalError(
                "journal must start with its config event (was this "
                "sliced without IngressPool.journal()?)")
        cfg = head[1]
        if not isinstance(cfg, dict) or set(cfg) != set(_CONFIG_KEYS):
            raise JournalError(
                f"journal config event carries keys "
                f"{sorted(cfg) if isinstance(cfg, dict) else cfg!r}, "
                f"expected exactly {sorted(_CONFIG_KEYS)} (journal from "
                "an incompatible pool version, or corrupted)")
        pool = cls(**cfg)
        formed = pool.apply(journal[1:], base_index=1)
        return pool, formed

    # ------------------------------------------------------ observables
    def observables(self) -> dict:
        """The metrics-facing snapshot (queue depth + monotone counters
        + the backpressure signal) — what ``report_from_trace`` folds
        into its CSV columns."""
        return dict(queue_depth=self._depth,
                    admitted=self.stats.admitted,
                    rejected=self.stats.rejected,
                    evicted=self.stats.evicted,
                    drained=self.stats.drained,
                    drain_calls=self.stats.drain_calls,
                    backpressure=int(self.backpressure),
                    backpressure_admits=self.stats.backpressure_admits)
