"""Transactional object store (TStore).

The TPU/JAX analog of the paper's shared mutable heap + TL2 metadata:

- ``values``   (O, S) int32  — O objects, each a slot-vector of S words.
- ``versions`` (O,)   int32  — per-object version = sequence number of the
  last committed writer (the paper retrofits sequence numbers as TL2
  versions, §3.1 "Speculative STM transaction"); 0 means "initial state".
- ``gv``       ()     int32  — global version = sequence number of the last
  committed transaction (the paper's ``gv``/``sn_c``).

The store is a pure pytree threaded through ``jax.lax`` control flow; all
engines (OCC / PCC / PoGL / DeSTM-analog) transform it functionally.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TStore:
    values: jax.Array    # (O, S) int32
    versions: jax.Array  # (O,)   int32
    gv: jax.Array        # ()     int32

    @property
    def n_objects(self) -> int:
        return self.values.shape[0]

    @property
    def slot(self) -> int:
        return self.values.shape[1]


def make_store(n_objects: int, slot: int = 1, init=None) -> TStore:
    """Create a fresh store. ``init`` is an optional (O, S) initial image."""
    if init is None:
        values = jnp.zeros((n_objects, slot), dtype=jnp.int32)
    else:
        values = jnp.asarray(init, dtype=jnp.int32).reshape(n_objects, -1)
    return TStore(
        values=values,
        versions=jnp.zeros((n_objects,), dtype=jnp.int32),
        gv=jnp.zeros((), dtype=jnp.int32),
    )


def fingerprint(store: TStore) -> jax.Array:
    """Order-sensitive FNV-1a (32-bit) fingerprint of the store image.

    Used by the determinism harness: two executions are "the same outcome"
    iff their fingerprints are bitwise equal.
    """
    data = store.values.astype(jnp.uint32).reshape(-1)

    def step(h, x):
        h = (h ^ x) * jnp.uint32(0x01000193)
        return h, None

    h0 = jnp.uint32(0x811C9DC5)
    h, _ = jax.lax.scan(step, h0, data)
    return h
