"""Transactional object store (TStore) + store-layout abstraction.

The TPU/JAX analog of the paper's shared mutable heap + TL2 metadata.
Since PR 5 the store is a *layout-polymorphic* pytree: the protocol
layer only ever talks to it through :class:`StoreLayout`, and two
concrete layouts implement it:

- :class:`TStore` — the dense layout (the S=1 degenerate case):

  * ``values``   (O, S) int32  — O objects, each a slot-vector of S words.
  * ``versions`` (O,)   int32  — per-object version = sequence number of
    the last committed writer (the paper retrofits sequence numbers as
    TL2 versions, §3.1 "Speculative STM transaction"); 0 = initial state.
  * ``gv``       ()     int32  — global version = sequence number of the
    last committed transaction (the paper's ``gv``/``sn_c``).

- :class:`ShardedStore` — the address space partitioned into S
  contiguous range shards of C = ceil(O/S) objects each (object ``a``
  lives in shard ``a // C`` at offset ``a % C``):

  * ``values``   (S, C, slot) int32 — stacked shard images (the last
    shard may carry padding rows past object O-1; they are never
    addressed, never written, and excluded from the fingerprint);
  * ``versions`` (S, C) int32; ``gv`` () int32 as above.

  Nothing in Pot's protocol requires one dense address space: the
  global serialization order lives in *rank* space (per transaction),
  while footprints, conflict analysis, and write-back all decompose
  per address — hence per shard.  ``ShardedStore`` is bit-identical to
  the dense store under every engine (same fingerprints, traces and
  replay logs; asserted in tests/test_sharded_store.py and
  ``scripts/ci.sh --shard-smoke``): the layout changes only *where*
  device work happens, never a decision.

The store is a pure pytree threaded through ``jax.lax`` control flow;
all engines (OCC / PCC / PoGL / DeSTM-analog) transform it
functionally.  ``DenseStore`` is an alias of ``TStore``.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class StoreLayout:
    """Static description of how the object address space is laid out.

    ``shards`` contiguous ranges of ``shard_size`` objects each; global
    address ``a`` maps to ``(a // shard_size, a % shard_size)``.  The
    dense store is the ``shards == 1`` case.  ``mesh`` optionally names
    a 1-axis :class:`jax.sharding.Mesh` of exactly ``shards`` devices —
    when present, the per-shard write-back scatters run under
    ``jax.experimental.shard_map`` over it (one device per shard);
    when absent they run as one vmapped scatter per shard on a single
    device.  Hashable (a static jit constant): it travels on the store
    pytree as a meta field, so the engine step specializes per layout.
    """

    n_objects: int
    shards: int = 1
    mesh: object | None = None   # jax.sharding.Mesh (hashable) or None

    @property
    def shard_size(self) -> int:
        """Objects per shard C = ceil(O/S); the last shard may pad."""
        return -(-self.n_objects // self.shards)

    @property
    def padded_objects(self) -> int:
        """S * C >= O — the flat length of the stacked shard images."""
        return self.shards * self.shard_size

    @property
    def sharded(self) -> bool:
        """True iff the store's arrays carry the stacked-shard axes.

        A 1-shard layout WITH a mesh still counts: its arrays are
        (1, C, slot) and its write-back runs under shard_map, so it
        must route through the sharded code paths (every
        :class:`ShardedStore` instance satisfies ``shards > 1 or mesh``
        — :func:`shard_store` returns the dense store otherwise)."""
        return self.shards > 1 or self.mesh is not None

    @property
    def words_per_shard(self) -> int:
        """Packed-bitset width per shard, ceil(C/32) — the conflict
        kernels' W axis shrinks by S under the sharded layout."""
        return -(-self.shard_size // 32)

    def shard_of(self, addr: jax.Array) -> jax.Array:
        return addr // self.shard_size

    def offset_of(self, addr: jax.Array) -> jax.Array:
        return addr % self.shard_size


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TStore:
    values: jax.Array    # (O, S) int32
    versions: jax.Array  # (O,)   int32
    gv: jax.Array        # ()     int32

    @property
    def n_objects(self) -> int:
        return self.values.shape[0]

    @property
    def slot(self) -> int:
        return self.values.shape[1]

    @property
    def layout(self) -> StoreLayout:
        return StoreLayout(self.n_objects, 1)


DenseStore = TStore  # the S=1 degenerate case of the layout abstraction


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("values", "versions", "gv"),
                   meta_fields=("n_objects", "mesh"))
@dataclasses.dataclass
class ShardedStore:
    """Range-partitioned store: S stacked shard images (see module doc).

    ``n_objects`` and ``mesh`` are static pytree *meta* fields: the real
    object count cannot be recovered from the (padded) array shapes, and
    the mesh must be a hashable jit constant for the shard_map path.
    """

    values: jax.Array    # (S, C, slot) int32
    versions: jax.Array  # (S, C)       int32
    gv: jax.Array        # ()           int32
    n_objects: int       # real object count (required: the padded array
    #   shapes cannot recover it, and a zero default would silently give
    #   shard_size == 0 addressing)
    mesh: object | None = None

    @property
    def shards(self) -> int:
        return self.values.shape[0]

    @property
    def shard_size(self) -> int:
        return self.values.shape[1]

    @property
    def slot(self) -> int:
        return self.values.shape[2]

    @property
    def layout(self) -> StoreLayout:
        return StoreLayout(self.n_objects, self.shards, self.mesh)


def flat_values(values: jax.Array, layout: StoreLayout | None) -> jax.Array:
    """The executor-facing flat (O_pad, slot) view of a store image.

    For the dense layout this is the image itself; for the sharded
    layout it is a free reshape of the stacked (S, C, slot) shards —
    contiguous-range partitioning means shard s's row c IS global
    object s*C + c, so the flat view needs no permutation.  Rows past
    ``layout.n_objects`` are padding and are never addressed (every
    effective address is reduced mod n_objects).
    """
    if layout is None or not layout.sharded:
        return values
    s, c, slot = values.shape
    return values.reshape(s * c, slot)


def store_with(store, values, versions, gv):
    """Rebuild a store of the same layout around new contents."""
    return dataclasses.replace(store, values=values, versions=versions,
                               gv=gv)


def make_store(n_objects: int, slot: int = 1, init=None, *,
               shards: int = 1, mesh=None) -> TStore | ShardedStore:
    """Create a fresh store. ``init`` is an optional (O, S) initial image.

    ``shards > 1`` returns a :class:`ShardedStore` over ``shards``
    contiguous address ranges (bit-identical semantics; see module doc).
    ``mesh`` optionally places one shard per device for the write-back
    scatters (requires a 1-axis mesh of exactly ``shards`` devices).
    """
    if init is None:
        values = jnp.zeros((n_objects, slot), dtype=jnp.int32)
    else:
        values = jnp.asarray(init, dtype=jnp.int32).reshape(n_objects, -1)
    dense = TStore(
        values=values,
        versions=jnp.zeros((n_objects,), dtype=jnp.int32),
        gv=jnp.zeros((), dtype=jnp.int32),
    )
    if shards == 1 and mesh is None:
        return dense
    return shard_store(dense, shards, mesh=mesh)


def shard_store(store: TStore, shards: int, mesh=None):
    """Partition a dense store into ``shards`` contiguous range shards.

    Pads the address space up to S * ceil(O/S) (padding rows are inert:
    never addressed, never written, excluded from the fingerprint).
    ``shards == 1`` without a mesh is the dense layout already — the
    store is returned unchanged, so every :class:`ShardedStore` that
    exists routes through the sharded code paths (see
    ``StoreLayout.sharded``).
    """
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    if shards == 1 and mesh is None:
        return store
    layout = StoreLayout(store.n_objects, shards, mesh)
    if mesh is not None:
        sizes = tuple(mesh.shape.values())
        if len(sizes) != 1 or sizes[0] != shards:
            raise ValueError(
                f"mesh must have exactly one axis of size shards={shards}, "
                f"got axes {dict(mesh.shape)}")
    pad = layout.padded_objects - store.n_objects
    values = jnp.pad(store.values, ((0, pad), (0, 0)))
    versions = jnp.pad(store.versions, (0, pad))
    return ShardedStore(
        values=values.reshape(shards, layout.shard_size, store.slot),
        versions=versions.reshape(shards, layout.shard_size),
        gv=store.gv, n_objects=store.n_objects, mesh=mesh)


def unshard_store(store) -> TStore:
    """Reassemble the dense image of a sharded store (drops padding).
    Idempotent: a dense store is returned unchanged."""
    if isinstance(store, TStore):
        return store
    o = store.n_objects
    return TStore(
        values=store.values.reshape(-1, store.slot)[:o],
        versions=store.versions.reshape(-1)[:o],
        gv=store.gv)


def shard_images(store) -> list[tuple[jax.Array, jax.Array]]:
    """Per-shard ``(values, versions)`` images, trimmed to real rows.

    The snapshot serialization form (repro.core.checkpoint): one image
    per shard — (C, slot) values + (C,) versions, with the last shard's
    padding rows dropped — whose concatenation IS the dense store image.
    A dense store yields its single full image.  Because the shards are
    contiguous address ranges, a snapshot written at S shards restores
    into any S' by concatenating and re-sharding.
    """
    if isinstance(store, TStore):
        return [(store.values, store.versions)]
    o, c = store.n_objects, store.shard_size
    out = []
    for s in range(store.shards):
        rows = min(o, (s + 1) * c) - min(o, s * c)
        out.append((store.values[s, :rows], store.versions[s, :rows]))
    return out


def dense_image(store) -> jax.Array:
    """The (O, slot) committed image of any store layout."""
    if isinstance(store, ShardedStore):
        return store.values.reshape(-1, store.slot)[:store.n_objects]
    return store.values


def fingerprint(store) -> jax.Array:
    """Order-sensitive FNV-1a (32-bit) fingerprint of the store image.

    Used by the determinism harness: two executions are "the same
    outcome" iff their fingerprints are bitwise equal.  Layout-blind:
    a sharded store hashes its dense image (padding excluded), so
    sharded and dense runs of the same history fingerprint identically.
    """
    data = dense_image(store).astype(jnp.uint32).reshape(-1)

    def step(h, x):
        h = (h ^ x) * jnp.uint32(0x01000193)
        return h, None

    h0 = jnp.uint32(0x811C9DC5)
    h, _ = jax.lax.scan(step, h0, data)
    return h
