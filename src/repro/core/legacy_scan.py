"""Pre-PR2 scan-based commit machinery, preserved verbatim.

These are the sequential reference implementations the vectorized commit
pipeline (protocol.conflict_table / prefix_commit / wave_commit /
fused_write_back) replaced: every round walks all K transactions through
two `lax.scan`s — an O(n_objects) bitmap probe plus a `lax.cond`
write-back per transaction.  They are kept (unregistered) for two jobs:

* **equivalence**: tests/test_commit_pipeline.py asserts the new
  pipeline's TStore image and ExecTrace commit_pos/mode/retries are
  bit-identical to these scans on every workload;
* **benchmarking**: benchmarks/engine_bench.py times old-vs-new and the
  `--bench-smoke` CI stage cross-checks their store fingerprints.

Do not "fix" or optimize this module — its value is being frozen.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import protocol
from repro.core.engine import (MODE_FAST, MODE_PREFIX, MODE_SPEC, MODE_UNSET,
                               ExecTrace, make_trace, seq_rank)
from repro.core.tstore import TStore
from repro.core.txn import TxnBatch, TxnResult, run_all, run_txn


def _pcc_execute_scan(store: TStore, batch: TxnBatch, seq: jax.Array,
                      max_rounds: int | None = None,
                      live_promotion: bool = True) -> tuple[TStore, ExecTrace]:
    """Scan-based PCC round: per-txn validation probe + per-txn write-back."""
    k = batch.n_txns
    n_obj = store.n_objects
    order = jnp.argsort(seq)  # order[p] = txn index at seq position p
    gv0 = store.gv

    def round_body(state):
        values, versions, gv, n_comm, rnd, tr = state
        res: TxnResult = run_all(batch, values)

        # --- ordered commit: maximal non-conflicting in-order prefix -----
        def commit_scan(carry, p):
            written, alive = carry
            t = order[p]
            pending = p >= n_comm
            conflict = protocol.footprint_conflicts(
                written, res.raddrs[t], res.rn[t], res.waddrs[t], res.wn[t])
            committing = alive & pending & ~conflict
            written = jax.lax.cond(
                committing,
                lambda w: protocol.mark_writes(w, res.waddrs[t], res.wn[t]),
                lambda w: w, written)
            alive = alive & (committing | ~pending)
            return (written, alive), committing

        (_, _), committing_pos = jax.lax.scan(
            commit_scan,
            (jnp.zeros((n_obj,), bool), jnp.asarray(True)),
            jnp.arange(k))

        # --- write-back in sequence order --------------------------------
        def apply_scan(carry, p):
            vals, vers = carry
            t = order[p]
            sn = gv0 + p + 1

            def do(args):
                v, ve = args
                return protocol.apply_writes(
                    v, ve, res.waddrs[t], res.wvals[t], res.wn[t], sn)

            vals, vers = jax.lax.cond(
                committing_pos[p], do, lambda a: a, (vals, vers))
            return (vals, vers), None

        (values, versions), _ = jax.lax.scan(
            apply_scan, (values, versions), jnp.arange(k))

        n_new = committing_pos.sum(dtype=jnp.int32)
        gv = gv + n_new

        # ---- live promotion (paper §2.2.3)
        promoted_pos = -jnp.ones((), jnp.int32)
        if live_promotion:
            head_pos = n_comm + n_new

            def promote(args):
                values, versions, gv = args
                t = order[jnp.clip(head_pos, 0, k - 1)]
                row = jax.tree.map(lambda a: a[t], batch)
                raddrs2, rn2, waddrs2, wvals2, wn2 = run_txn(row, values)
                del raddrs2, rn2
                values, versions = protocol.apply_writes(
                    values, versions, waddrs2, wvals2, wn2,
                    gv0 + head_pos + 1)
                return values, versions, gv + 1

            do_promote = head_pos < k
            values, versions, gv = jax.lax.cond(
                do_promote, promote, lambda a: a, (values, versions, gv))
            promoted_pos = jnp.where(do_promote, head_pos, -1)
            n_new = n_new + do_promote.astype(jnp.int32)

        # --- trace bookkeeping (by txn index) ----------------------------
        pos = jnp.arange(k)
        pending_pos = pos >= n_comm
        is_head = pos == n_comm
        promoted_mask = pos == promoted_pos
        committing_all = committing_pos | promoted_mask
        mode_pos = jnp.where(
            committing_all,
            jnp.where(is_head | promoted_mask, MODE_FAST, MODE_PREFIX),
            jnp.where(pending_pos, MODE_SPEC, MODE_UNSET))
        commit_round = tr["commit_round"].at[order].max(
            jnp.where(committing_all, rnd, -1))
        first_round = tr["first_round"].at[order].min(
            jnp.where(pending_pos, rnd, jnp.iinfo(jnp.int32).max))
        retries = tr["retries"].at[order].add(
            (pending_pos & ~committing_all).astype(jnp.int32))
        mode = tr["mode"].at[order].max(mode_pos)
        wait_rounds = tr["wait_rounds"].at[order].add(
            (pending_pos & ~committing_all).astype(jnp.int32))
        rn_pos = res.rn[order]
        validation_words = tr["validation_words"] + jnp.where(
            pending_pos & ~is_head, rn_pos, 0).sum(dtype=jnp.int32)
        exec_ops = tr["exec_ops"] + jnp.where(
            pending_pos, batch.n_ins[order], 0).sum(dtype=jnp.int32) \
            + jnp.where(promoted_mask, batch.n_ins[order],
                        0).sum(dtype=jnp.int32)
        promotions = tr["promotions"] + promoted_mask.sum(dtype=jnp.int32)
        tr = dict(tr, commit_round=commit_round, first_round=first_round,
                  retries=retries, mode=mode, wait_rounds=wait_rounds,
                  validation_words=validation_words, exec_ops=exec_ops,
                  promotions=promotions)
        return values, versions, gv, n_comm + n_new, rnd + 1, tr

    def cond(state):
        *_, n_comm, rnd, _ = state
        return (n_comm < k) & (rnd < limit)

    limit = max_rounds if max_rounds is not None else k + 1
    tr0 = dict(
        commit_round=jnp.full((k,), -1, jnp.int32),
        first_round=jnp.full((k,), jnp.iinfo(jnp.int32).max, jnp.int32),
        retries=jnp.zeros((k,), jnp.int32),
        mode=jnp.zeros((k,), jnp.int32),
        wait_rounds=jnp.zeros((k,), jnp.int32),
        validation_words=jnp.zeros((), jnp.int32),
        exec_ops=jnp.zeros((), jnp.int32),
        promotions=jnp.zeros((), jnp.int32),
    )
    values, versions, gv, n_comm, rnd, tr = jax.lax.while_loop(
        cond, round_body,
        (store.values, store.versions, store.gv, jnp.zeros((), jnp.int32),
         jnp.zeros((), jnp.int32), tr0))

    trace = make_trace(
        k,
        commit_round=tr["commit_round"], first_round=tr["first_round"],
        retries=tr["retries"], mode=tr["mode"],
        wait_rounds=tr["wait_rounds"], rounds=rnd,
        validation_words=tr["validation_words"], exec_ops=tr["exec_ops"],
        promotions=tr["promotions"],
        commit_pos=seq_rank(seq))
    return TStore(values=values, versions=versions, gv=gv), trace


def _occ_execute_scan(store: TStore, batch: TxnBatch, arrival: jax.Array,
                      max_waves: int | None = None) -> tuple[TStore, ExecTrace]:
    """Scan-based OCC wave: per-txn probe, arrival order, no prefix rule.

    Version stamps are gv-rebased (gv0 + commit position + 1, matching
    repro.core.occ) so they stay globally monotone across batches —
    identical on the single-batch gv=0 stores every equivalence test
    uses, required for the cross-batch dirty predicate (PR 7)."""
    k = batch.n_txns
    n_obj = store.n_objects
    gv0 = store.gv

    def wave_body(state):
        values, versions, done, n_comm, wave, tr = state
        res = run_all(batch, values)

        def commit_scan(carry, p):
            written = carry
            t = arrival[p]
            pending = ~done[t]
            conflict = protocol.footprint_conflicts(
                written, res.raddrs[t], res.rn[t], res.waddrs[t], res.wn[t])
            committing = pending & ~conflict   # NOTE: no prefix/order rule
            written = jax.lax.cond(
                committing,
                lambda w: protocol.mark_writes(w, res.waddrs[t], res.wn[t]),
                lambda w: w, written)
            return written, committing

        _, committing_pos = jax.lax.scan(
            commit_scan, jnp.zeros((n_obj,), bool), jnp.arange(k))

        commit_idx = n_comm + jnp.cumsum(committing_pos) - 1

        def apply_scan(carry, p):
            vals, vers = carry
            t = arrival[p]

            def do(args):
                v, ve = args
                return protocol.apply_writes(
                    v, ve, res.waddrs[t], res.wvals[t], res.wn[t],
                    gv0 + commit_idx[p] + 1)

            vals, vers = jax.lax.cond(
                committing_pos[p], do, lambda a: a, (vals, vers))
            return (vals, vers), None

        (values, versions), _ = jax.lax.scan(
            apply_scan, (values, versions), jnp.arange(k))

        pending_t = ~done
        commit_pos = tr["commit_pos"].at[arrival].max(
            jnp.where(committing_pos, commit_idx, -1))
        retries = tr["retries"] + (
            pending_t & ~jnp.zeros_like(pending_t).at[arrival].set(
                committing_pos)).astype(jnp.int32)
        exec_ops = tr["exec_ops"] + jnp.where(
            pending_t, batch.n_ins, 0).sum(dtype=jnp.int32)
        done = done.at[arrival].max(committing_pos)
        tr = dict(tr, commit_pos=commit_pos, retries=retries,
                  exec_ops=exec_ops)
        return (values, versions, done,
                n_comm + committing_pos.sum(dtype=jnp.int32), wave + 1, tr)

    def cond(state):
        _, _, done, _, wave, _ = state
        return (~done.all()) & (wave < limit)

    limit = max_waves if max_waves is not None else k + 1
    tr0 = dict(commit_pos=jnp.full((k,), -1, jnp.int32),
               retries=jnp.zeros((k,), jnp.int32),
               exec_ops=jnp.zeros((), jnp.int32))
    values, versions, done, n_comm, wave, tr = jax.lax.while_loop(
        cond, wave_body,
        (store.values, store.versions, jnp.zeros((k,), bool),
         jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32), tr0))

    trace = make_trace(
        k,
        commit_pos=tr["commit_pos"], retries=tr["retries"],
        rounds=wave, exec_ops=tr["exec_ops"],
        commit_round=tr["retries"])
    return TStore(values=values, versions=versions, gv=store.gv + n_comm), trace


def _destm_execute_scan(store: TStore, batch: TxnBatch, seq: jax.Array,
                        lanes: jax.Array, n_lanes: int,
                        max_rounds: int | None = None
                        ) -> tuple[TStore, ExecTrace]:
    """Scan-based DeSTM round: per-lane pick scan + token-order commit scan."""
    k = batch.n_txns
    n_obj = store.n_objects
    order = jnp.argsort(seq)
    gv0 = store.gv

    def round_body(state):
        values, versions, done, rnd, tr = state

        def pick(carry, p):
            taken = carry          # (n_lanes,) bool — lane already has a txn
            t = order[p]
            lane = lanes[t]
            sel = (~done[t]) & (~taken[lane])
            taken = taken.at[lane].max(sel)
            return taken, sel

        _, selected_pos = jax.lax.scan(
            pick, jnp.zeros((n_lanes,), bool), jnp.arange(k))

        res = run_all(batch, values)

        def commit_scan(carry, p):
            values, versions, written, tr_retries, tr_exec = carry
            t = order[p]
            sel = selected_pos[p]
            conflict = protocol.footprint_conflicts(
                written, res.raddrs[t], res.rn[t], res.waddrs[t], res.wn[t])

            def commit_clean(args):
                values, versions, written = args
                values, versions = protocol.apply_writes(
                    values, versions, res.waddrs[t], res.wvals[t], res.wn[t],
                    gv0 + p + 1)
                written = protocol.mark_writes(written, res.waddrs[t],
                                               res.wn[t])
                return values, versions, written

            def commit_retry(args):
                values, versions, written = args
                row = jax.tree.map(lambda a: a[t], batch)
                raddrs2, rn2, waddrs2, wvals2, wn2 = run_txn(row, values)
                del raddrs2, rn2
                values, versions = protocol.apply_writes(
                    values, versions, waddrs2, wvals2, wn2, gv0 + p + 1)
                written = protocol.mark_writes(written, waddrs2, wn2)
                return values, versions, written

            values, versions, written = jax.lax.cond(
                sel,
                lambda a: jax.lax.cond(conflict, commit_retry, commit_clean,
                                       a),
                lambda a: a, (values, versions, written))
            tr_retries = tr_retries.at[t].add((sel & conflict).astype(jnp.int32))
            tr_exec = tr_exec + jnp.where(
                sel, batch.n_ins[t] * (1 + conflict.astype(jnp.int32)), 0)
            return (values, versions, written, tr_retries, tr_exec), None

        (values, versions, _, retries, exec_ops), _ = jax.lax.scan(
            commit_scan,
            (values, versions, jnp.zeros((n_obj,), bool),
             tr["retries"], tr["exec_ops"]),
            jnp.arange(k))

        sel_t = jnp.zeros((k,), bool).at[order].set(selected_pos)
        cost = jnp.where(sel_t, batch.n_ins, 0)
        round_max = cost.max()
        n_sel = sel_t.sum(dtype=jnp.int32)
        barrier_ops = tr["barrier_ops"] + jnp.where(
            n_sel > 0, n_sel * round_max - cost.sum(dtype=jnp.int32), 0)

        done = done | sel_t
        commit_round = jnp.where(sel_t, rnd, tr["commit_round"])
        tr = dict(tr, retries=retries, exec_ops=exec_ops,
                  barrier_ops=barrier_ops, commit_round=commit_round)
        return values, versions, done, rnd + 1, tr

    def cond(state):
        _, _, done, rnd, _ = state
        return (~done.all()) & (rnd < limit)

    limit = max_rounds if max_rounds is not None else k + 1
    tr0 = dict(commit_round=jnp.full((k,), -1, jnp.int32),
               retries=jnp.zeros((k,), jnp.int32),
               exec_ops=jnp.zeros((), jnp.int32),
               barrier_ops=jnp.zeros((), jnp.int32))
    values, versions, done, rnd, tr = jax.lax.while_loop(
        cond, round_body,
        (store.values, store.versions, jnp.zeros((k,), bool),
         jnp.zeros((), jnp.int32), tr0))

    rank = seq_rank(seq)
    commit_pos = seq_rank(tr["commit_round"] * (k + 1) + rank)
    trace = make_trace(
        k,
        commit_round=tr["commit_round"], retries=tr["retries"],
        rounds=rnd, exec_ops=tr["exec_ops"],
        barrier_ops=tr["barrier_ops"],
        first_round=tr["commit_round"], commit_pos=commit_pos)
    return TStore(values=values, versions=versions, gv=store.gv + k), trace


pcc_execute_scan = jax.jit(
    _pcc_execute_scan, static_argnames=("max_rounds", "live_promotion"))
occ_execute_scan = jax.jit(_occ_execute_scan, static_argnames=("max_waves",))
destm_execute_scan = jax.jit(
    _destm_execute_scan, static_argnames=("n_lanes", "max_rounds"))
