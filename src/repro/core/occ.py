"""Baseline OCC — *traditional transactions* (paper §2, Fig. 2a).

Traditional OCC intertwines ordering with concurrency control: the final
serialization order is whatever the runtime interleaving produced.  We
model the interleaving with an explicit ``arrival`` permutation (which
transaction reaches its validation/write phase first); the engine commits
non-conflicting transactions in arrival-order waves.

Each wave runs through the shared vectorized commit pipeline
(:mod:`repro.core.protocol`): one K×K conflict matrix, then OCC's greedy
arrival-order rule — commit iff no conflict with an earlier *committing*
transaction, with NO prefix cutoff — solved as a masked mat-vec fixpoint
(``protocol.wave_commit``; converges in the conflict-chain depth, one
batched device step per iteration, exactly reproducing the old K-step
commit scan), and one fused write-back scatter for the whole wave.

The point this baseline exists to make (and the tests assert): the final
store DEPENDS on ``arrival`` — different interleavings, different outcome
— which is precisely the nondeterminism Pot eliminates.  It also records
the commit order so it can be replayed through ``ReplaySequencer``
(record/replay use case, paper §2.1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import protocol
from repro.core.engine import (EngineDef, ExecTrace, make_trace,
                               rank_from_order, register_engine)
from repro.core.tstore import TStore, store_with
from repro.core.txn import TxnBatch

# The old per-engine trace dataclass is now the canonical schema.
OccTrace = ExecTrace


def _occ_execute(store: TStore, batch: TxnBatch, arrival: jax.Array,
                 max_waves: int | None = None,
                 incremental: bool = True,
                 compact: bool = True,
                 wave_block: int = 8,
                 seed: "protocol.SpecSeed | None" = None
                 ) -> tuple[TStore, ExecTrace]:
    """arrival: (K,) permutation — arrival[p] = txn reaching commit p-th.

    ``incremental``: re-execute only the not-yet-committed transactions
    each wave (masked ``run_live`` + carried conflict table through
    ``protocol.RoundState``); False rebuilds per wave (PR 2 behavior).
    Decision-identical — the wave rule only consumes pending rows.

    ``compact``: cascade the wave loop over ``protocol.compact_ladder``
    widths — the surviving conflict tail of a contended batch executes
    gather-compacted at (C, L) once it fits a rung, instead of a masked
    pass over the full (K, L) grid.  Decision-identical to the masked
    loop.  Rows with ``n_ins == 0`` are *vacant* (bucket padding): never
    pending, never committed, no ``gv`` advance (their arrival positions
    must sort after every real row's).

    ``wave_block``: unroll B conflict queries per ``wave_commit``
    `while_loop` trip (the blocked fixpoint solve) — cuts
    ``ExecTrace.wave_trips`` by ~B on deep conflict chains, provably
    decision-identical for any B (see :func:`protocol.wave_commit`).

    ``seed``: optional :class:`protocol.SpecSeed` — the cross-batch
    speculative round-0 execution re-based onto the current store by
    ``protocol.seed_round_state`` (see :mod:`repro.core.pcc`); the
    store and every pre-existing trace field stay bit-identical to the
    unseeded call.
    """
    k = batch.n_txns
    layout = store.layout     # static: dense or S contiguous range shards
    n_obj = layout.n_objects
    # arrival rank of each txn: one argsort's inverse, computed once
    rank = rank_from_order(arrival)
    gv0 = store.gv
    real = batch.n_ins > 0     # vacant rows (bucket padding) never commit

    def wave_body_at(width: int):
        full = width >= k

        def wave_body(state):
            rs, done, n_comm, wave, tr = state

            # --- read phase (masked at the full rung, gather-compacted
            # below it) + carried conflict table --------------------------
            pending_t = ~done
            live = pending_t if incremental else jnp.ones((k,), bool)

            def refresh(r):
                if full:
                    return protocol.refresh_round_state(r, batch, live,
                                                        layout)
                return protocol.refresh_round_state_compact(
                    r, batch, live, width, layout)[0]

            if seeded:
                # wave 0's read phase already ran speculatively and was
                # re-based onto this store by seed_round_state — charge
                # the identical work accounting without re-walking
                rs = jax.lax.cond(
                    wave == 0,
                    lambda r: protocol.charge_round_state(
                        r, batch, live, k if full else width),
                    refresh, rs)
            else:
                rs = refresh(rs)
            res = rs.res

            # --- greedy wave fixpoint (trip count = conflict-chain depth)
            committing_t, trips = protocol.wave_commit(
                res, rs.conflict, pending_t, rank, n_obj, block=wave_block)

            # commit position = running count in arrival order; the cumsum
            # lives in position space, gathered back through each txn's
            # rank.  Version stamps are gv-rebased (gv0 + position + 1) so
            # they stay globally monotone across batches — the dirty
            # predicate behind cross-batch speculation (versions > snap_gv)
            commit_idx_t = n_comm + jnp.cumsum(committing_t[arrival])[rank] - 1
            values, versions = protocol.fused_write_back(
                rs.values, rs.versions, res.waddrs, res.wvals, res.wn,
                committing_t, rank, gv0 + commit_idx_t + 1, layout)

            commit_pos = jnp.maximum(
                tr["commit_pos"],
                jnp.where(committing_t, commit_idx_t, -1))
            retries = tr["retries"] + (pending_t & ~committing_t)
            exec_ops = tr["exec_ops"] + jnp.where(
                pending_t, batch.n_ins, 0).sum(dtype=jnp.int32)
            done = done | committing_t
            tr = dict(tr, commit_pos=commit_pos, retries=retries,
                      exec_ops=exec_ops,
                      wave_trips=tr["wave_trips"] + trips,
                      live_per_round=tr["live_per_round"].at[wave].set(
                          live.sum(dtype=jnp.int32)))
            rs = protocol.commit_round_state(rs, values, versions)
            return (rs, done,
                    n_comm + committing_t.sum(dtype=jnp.int32), wave + 1, tr)

        return wave_body

    def cond_at(next_width: int):
        def cond(state):
            _, done, _, wave, _ = state
            go = (~done.all()) & (wave < limit)
            if next_width:
                # hand over to the narrower rung once the pending set fits
                go = go & ((~done).sum(dtype=jnp.int32) > next_width)
            return go

        return cond

    limit = max_waves if max_waves is not None else k + 1
    tr0 = dict(commit_pos=jnp.full((k,), -1, jnp.int32),
               retries=jnp.zeros((k,), jnp.int32),
               exec_ops=jnp.zeros((), jnp.int32),
               wave_trips=jnp.zeros((), jnp.int32),
               live_per_round=jnp.full((limit,), -1, jnp.int32))
    seeded = seed is not None   # static per trace (None jits leaf-free)
    if seeded:
        rs0, spec_inv, spec_rnds = protocol.seed_round_state(
            batch, store, seed, compact=(incremental and compact))
    else:
        rs0 = protocol.init_round_state(batch, store.values,
                                        store.versions, layout=layout)
    ladder = (protocol.compact_ladder(k) if (incremental and compact)
              else [k])
    state = (rs0, ~real, jnp.zeros((), jnp.int32),
             jnp.zeros((), jnp.int32), tr0)
    state = protocol.run_compact_cascade(ladder, state, wave_body_at,
                                         cond_at)
    rs, done, n_comm, wave, tr = state

    trace = make_trace(
        k,
        commit_pos=tr["commit_pos"], retries=tr["retries"],
        rounds=wave, exec_ops=tr["exec_ops"],
        wave_trips=tr["wave_trips"],
        live_txns=rs.live_txns, live_slots=rs.live_slots,
        walked_slots=rs.walked_slots,
        live_per_round=tr["live_per_round"],
        # a txn that retried r waves committed in wave r (vacant: none)
        commit_round=jnp.where(real, tr["retries"], -1),
        **(dict(spec_executed=real.sum(dtype=jnp.int32),
                spec_invalidated=spec_inv,
                spec_rounds=spec_rnds) if seeded else {}))
    return store_with(store, rs.values, rs.versions,
                      store.gv + n_comm), trace


occ_execute = jax.jit(
    _occ_execute, static_argnames=("max_waves", "incremental", "compact",
                                   "wave_block"))


def _occ_raw(store, batch, seq, lanes, n_lanes):
    del lanes, n_lanes
    # OCC has no preordering: the sequence order IS the arrival
    # interleaving — the runtime-dependent knob its outcome depends on.
    return _occ_execute(store, batch, jnp.argsort(seq))


def _occ_raw_spec(store, batch, seq, lanes, n_lanes, seed):
    del lanes, n_lanes
    return _occ_execute(store, batch, jnp.argsort(seq), seed=seed)


register_engine(EngineDef(
    "occ", _occ_raw,
    doc="traditional OCC baseline — commit order = arrival interleaving",
    raw_spec=_occ_raw_spec))
