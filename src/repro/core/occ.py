"""Baseline OCC — *traditional transactions* (paper §2, Fig. 2a).

Traditional OCC intertwines ordering with concurrency control: the final
serialization order is whatever the runtime interleaving produced.  We
model the interleaving with an explicit ``arrival`` permutation (which
transaction reaches its validation/write phase first); the engine commits
non-conflicting transactions in arrival-order waves.

The point this baseline exists to make (and the tests assert): the final
store DEPENDS on ``arrival`` — different interleavings, different outcome
— which is precisely the nondeterminism Pot eliminates.  It also records
the commit order so it can be replayed through ``ReplaySequencer``
(record/replay use case, paper §2.1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import protocol
from repro.core.engine import (EngineDef, ExecTrace, make_trace,
                               register_engine)
from repro.core.tstore import TStore
from repro.core.txn import TxnBatch, run_all

# The old per-engine trace dataclass is now the canonical schema.
OccTrace = ExecTrace


def _occ_execute(store: TStore, batch: TxnBatch, arrival: jax.Array,
                 max_waves: int | None = None) -> tuple[TStore, ExecTrace]:
    """arrival: (K,) permutation — arrival[p] = txn reaching commit p-th."""
    k = batch.n_txns
    n_obj = store.n_objects

    def wave_body(state):
        values, versions, done, n_comm, wave, tr = state
        res = run_all(batch, values)

        def commit_scan(carry, p):
            written = carry
            t = arrival[p]
            pending = ~done[t]
            conflict = protocol.footprint_conflicts(
                written, res.raddrs[t], res.rn[t], res.waddrs[t], res.wn[t])
            committing = pending & ~conflict   # NOTE: no prefix/order rule
            written = jax.lax.cond(
                committing,
                lambda w: protocol.mark_writes(w, res.waddrs[t], res.wn[t]),
                lambda w: w, written)
            return written, committing

        _, committing_pos = jax.lax.scan(
            commit_scan, jnp.zeros((n_obj,), bool), jnp.arange(k))

        # write-back in arrival order; commit position = running count
        commit_idx = n_comm + jnp.cumsum(committing_pos) - 1

        def apply_scan(carry, p):
            vals, vers = carry
            t = arrival[p]

            def do(args):
                v, ve = args
                return protocol.apply_writes(
                    v, ve, res.waddrs[t], res.wvals[t], res.wn[t],
                    commit_idx[p] + 1)

            vals, vers = jax.lax.cond(
                committing_pos[p], do, lambda a: a, (vals, vers))
            return (vals, vers), None

        (values, versions), _ = jax.lax.scan(
            apply_scan, (values, versions), jnp.arange(k))

        pending_t = ~done
        commit_pos = tr["commit_pos"].at[arrival].max(
            jnp.where(committing_pos, commit_idx, -1))
        retries = tr["retries"] + (
            pending_t & ~jnp.zeros_like(pending_t).at[arrival].set(
                committing_pos)).astype(jnp.int32)
        exec_ops = tr["exec_ops"] + jnp.where(
            pending_t, batch.n_ins, 0).sum(dtype=jnp.int32)
        done = done.at[arrival].max(committing_pos)
        tr = dict(tr, commit_pos=commit_pos, retries=retries,
                  exec_ops=exec_ops)
        return (values, versions, done,
                n_comm + committing_pos.sum(dtype=jnp.int32), wave + 1, tr)

    def cond(state):
        _, _, done, _, wave, _ = state
        return (~done.all()) & (wave < limit)

    limit = max_waves if max_waves is not None else k + 1
    tr0 = dict(commit_pos=jnp.full((k,), -1, jnp.int32),
               retries=jnp.zeros((k,), jnp.int32),
               exec_ops=jnp.zeros((), jnp.int32))
    values, versions, done, n_comm, wave, tr = jax.lax.while_loop(
        cond, wave_body,
        (store.values, store.versions, jnp.zeros((k,), bool),
         jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32), tr0))

    trace = make_trace(
        k,
        commit_pos=tr["commit_pos"], retries=tr["retries"],
        rounds=wave, exec_ops=tr["exec_ops"],
        # a txn that retried r waves committed in wave r
        commit_round=tr["retries"])
    return TStore(values=values, versions=versions, gv=store.gv + n_comm), trace


occ_execute = jax.jit(_occ_execute, static_argnames=("max_waves",))


def _occ_raw(store, batch, seq, lanes, n_lanes):
    del lanes, n_lanes
    # OCC has no preordering: the sequence order IS the arrival
    # interleaving — the runtime-dependent knob its outcome depends on.
    return _occ_execute(store, batch, jnp.argsort(seq))


register_engine(EngineDef(
    "occ", _occ_raw,
    doc="traditional OCC baseline — commit order = arrival interleaving"))
