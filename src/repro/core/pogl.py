"""PoGL — Preordered Global Lock (paper §4.1.2).

The "trivial" implementation of preordered transactions: execute strictly
serially in the sequence order, no speculation.  Deterministic by
construction; zero parallelism.  Doubles as the **serial oracle** for
property tests — every other deterministic engine must produce a store
image bitwise-equal to PoGL's.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import protocol
from repro.core.engine import (MODE_FAST, EngineDef, make_trace,
                               rank_from_order, register_engine)
from repro.core.tstore import TStore, flat_values, store_with
from repro.core.txn import TxnBatch, run_txn


def _pogl_ordered(store: TStore, batch: TxnBatch, order: jax.Array) -> TStore:
    k = batch.n_txns
    gv0 = store.gv
    layout = store.layout     # static: dense or S contiguous range shards

    def step(carry, p):
        values, versions = carry
        t = order[p]
        row = jax.tree.map(lambda a: a[t], batch)
        raddrs, rn, waddrs, wvals, wn = run_txn(
            row, flat_values(values, layout), layout.n_objects)
        del raddrs, rn
        values, versions = protocol.apply_writes(
            values, versions, waddrs, wvals, wn, gv0 + p + 1, layout)
        return (values, versions), None

    (values, versions), _ = jax.lax.scan(
        step, (store.values, store.versions), jnp.arange(k))
    return store_with(store, values, versions, store.gv + k)


@jax.jit
def pogl_execute(store: TStore, batch: TxnBatch, seq: jax.Array) -> TStore:
    return _pogl_ordered(store, batch, jnp.argsort(seq))


def _pogl_raw(store, batch, seq, lanes, n_lanes):
    del lanes, n_lanes
    k = batch.n_txns
    # argsort once; the rank is its inverse permutation (one scatter)
    order = jnp.argsort(seq)
    rank = rank_from_order(order)
    # vacant rows (bucket padding, n_ins == 0; they sort after every real
    # row) execute as no-ops but never commit: no gv advance, no position
    real = batch.n_ins > 0
    n_real = real.sum(dtype=jnp.int32)
    # one txn per serial "round", uninstrumented (global lock = fast path)
    trace = make_trace(
        k, commit_round=jnp.where(real, rank, -1),
        commit_pos=jnp.where(real, rank, -1),
        first_round=jnp.where(real, rank, -1),
        mode=jnp.where(real, MODE_FAST, 0).astype(jnp.int32),
        rounds=n_real,
        exec_ops=batch.n_ins.sum(dtype=jnp.int32))
    out = _pogl_ordered(store, batch, order)
    out = store_with(out, out.values, out.versions, store.gv + n_real)
    return out, trace


register_engine(EngineDef(
    "pogl", _pogl_raw,
    doc="Preordered Global Lock — strictly serial in sequence order"))
