"""PoGL — Preordered Global Lock (paper §4.1.2).

The "trivial" implementation of preordered transactions: execute strictly
serially in the sequence order, no speculation.  Deterministic by
construction; zero parallelism.  Doubles as the **serial oracle** for
property tests — every other deterministic engine must produce a store
image bitwise-equal to PoGL's.

Since PR 10 the engine is also *seedable* (``seed=`` /
``EngineDef.raw_spec``), so ``PotSession(pipeline_depth=D)`` cross-batch
pipelining covers all four engines: a :class:`protocol.SpecSeed`
captured against an earlier snapshot is re-based onto the current store
by ``protocol.seed_round_state``; the serial walk then *reuses* a
cached row whenever its logged read set misses every address written
earlier in this batch (row purity makes the cached result bit-equal to
a fresh run), re-executing only the rows the within-batch order
actually invalidates.  The store, trace, and commit positions are
bit-identical to the unseeded walk — only the ``spec_*`` observables
record the overlap (within-batch re-runs count toward
``spec_invalidated`` alongside the cross-batch ones).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import protocol
from repro.core.engine import (MODE_FAST, EngineDef, make_trace,
                               rank_from_order, register_engine)
from repro.core.tstore import TStore, flat_values, store_with
from repro.core.txn import TxnBatch, run_txn


def _pogl_ordered(store: TStore, batch: TxnBatch, order: jax.Array) -> TStore:
    k = batch.n_txns
    gv0 = store.gv
    layout = store.layout     # static: dense or S contiguous range shards

    def step(carry, p):
        values, versions = carry
        t = order[p]
        row = jax.tree.map(lambda a: a[t], batch)
        raddrs, rn, waddrs, wvals, wn = run_txn(
            row, flat_values(values, layout), layout.n_objects)
        del raddrs, rn
        values, versions = protocol.apply_writes(
            values, versions, waddrs, wvals, wn, gv0 + p + 1, layout)
        return (values, versions), None

    (values, versions), _ = jax.lax.scan(
        step, (store.values, store.versions), jnp.arange(k))
    return store_with(store, values, versions, store.gv + k)


def _pogl_seeded(store: TStore, batch: TxnBatch, order: jax.Array,
                 res) -> tuple[TStore, jax.Array]:
    """The serial walk over re-based speculative rows ``res`` (bit-equal
    to executing each row against the batch-start store).  A cached row
    replays bit-identically unless an EARLIER row of this batch wrote an
    address it read (read-set check only — sound by row purity, same
    argument as :func:`protocol.speculation_invalid`; conservative only
    on read-your-writes rows).  Returns the store plus the number of
    rows the within-batch order forced to re-execute."""
    k = batch.n_txns
    gv0 = store.gv
    layout = store.layout
    n_obj = layout.n_objects
    slot = jnp.arange(batch.opcodes.shape[1])

    def step(carry, p):
        values, versions, written, n_rerun = carry
        t = order[p]
        row = jax.tree.map(lambda a: a[t], batch)
        ra, rn = res.raddrs[t], res.rn[t]
        stale = (written[ra] & (slot < rn)).any()

        def rerun(_):
            _, _, waddrs, wvals, wn = run_txn(
                row, flat_values(values, layout), n_obj)
            return waddrs, wvals, wn

        def cached(_):
            return res.waddrs[t], res.wvals[t], res.wn[t]

        waddrs, wvals, wn = jax.lax.cond(stale, rerun, cached, None)
        values, versions = protocol.apply_writes(
            values, versions, waddrs, wvals, wn, gv0 + p + 1, layout)
        written = protocol.mark_writes(written, waddrs, wn)
        return (values, versions, written,
                n_rerun + stale.astype(jnp.int32)), None

    (values, versions, _, n_rerun), _ = jax.lax.scan(
        step, (store.values, store.versions, jnp.zeros((n_obj,), bool),
               jnp.zeros((), jnp.int32)),
        jnp.arange(k))
    return store_with(store, values, versions, store.gv + k), n_rerun


@jax.jit
def pogl_execute(store: TStore, batch: TxnBatch, seq: jax.Array) -> TStore:
    return _pogl_ordered(store, batch, jnp.argsort(seq))


def _pogl_raw(store, batch, seq, lanes, n_lanes, seed=None):
    del lanes, n_lanes
    k = batch.n_txns
    # argsort once; the rank is its inverse permutation (one scatter)
    order = jnp.argsort(seq)
    rank = rank_from_order(order)
    # vacant rows (bucket padding, n_ins == 0; they sort after every real
    # row) execute as no-ops but never commit: no gv advance, no position
    real = batch.n_ins > 0
    n_real = real.sum(dtype=jnp.int32)
    seeded = seed is not None  # static per trace (None jits leaf-free)
    if seeded:
        rs, spec_inv, spec_rnds = protocol.seed_round_state(batch, store,
                                                            seed)
        out, n_rerun = _pogl_seeded(store, batch, order, rs.res)
        spec = dict(spec_executed=n_real,
                    spec_invalidated=spec_inv + n_rerun,
                    spec_rounds=spec_rnds)
    else:
        out = _pogl_ordered(store, batch, order)
        spec = {}
    # one txn per serial "round", uninstrumented (global lock = fast path)
    trace = make_trace(
        k, commit_round=jnp.where(real, rank, -1),
        commit_pos=jnp.where(real, rank, -1),
        first_round=jnp.where(real, rank, -1),
        mode=jnp.where(real, MODE_FAST, 0).astype(jnp.int32),
        rounds=n_real,
        exec_ops=batch.n_ins.sum(dtype=jnp.int32),
        **spec)
    out = store_with(out, out.values, out.versions, store.gv + n_real)
    return out, trace


def _pogl_raw_spec(store, batch, seq, lanes, n_lanes, seed):
    return _pogl_raw(store, batch, seq, lanes, n_lanes, seed=seed)


register_engine(EngineDef(
    "pogl", _pogl_raw,
    doc="Preordered Global Lock — strictly serial in sequence order",
    raw_spec=_pogl_raw_spec))
