"""PoGL — Preordered Global Lock (paper §4.1.2).

The "trivial" implementation of preordered transactions: execute strictly
serially in the sequence order, no speculation.  Deterministic by
construction; zero parallelism.  Doubles as the **serial oracle** for
property tests — every other deterministic engine must produce a store
image bitwise-equal to PoGL's.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import protocol
from repro.core.tstore import TStore
from repro.core.txn import TxnBatch, run_txn


@jax.jit
def pogl_execute(store: TStore, batch: TxnBatch, seq: jax.Array) -> TStore:
    k = batch.n_txns
    order = jnp.argsort(seq)
    gv0 = store.gv

    def step(carry, p):
        values, versions = carry
        t = order[p]
        row = jax.tree.map(lambda a: a[t], batch)
        raddrs, rn, waddrs, wvals, wn = run_txn(row, values)
        del raddrs, rn
        values, versions = protocol.apply_writes(
            values, versions, waddrs, wvals, wn, gv0 + p + 1)
        return (values, versions), None

    (values, versions), _ = jax.lax.scan(
        step, (store.values, store.versions), jnp.arange(k))
    return TStore(values=values, versions=versions, gv=store.gv + k)
