"""Pot Concurrency Control (PCC) — the paper's contribution (§2.2), adapted
to a dataflow runtime.

Round-based prefix commit
-------------------------
Each engine round:

1. **Speculative read phase** — every pending transaction executes
   (vmapped) against the committed store image (deferred updates, logged
   footprints: OCC read phase, Fig. 2a/2b).
2. **Ordered commit** — walking transactions in *sequence order* (the
   order fixed by the sequencer before execution), commit the maximal
   in-order prefix of pending transactions whose footprints do not overlap
   the writes of transactions committing earlier in the same round
   (paper §2.2.2 "ordered commits" + §2.2.3 "multiple simultaneous fast
   transactions": a string of successive compatible transactions commits
   together).
3. The conflicting suffix re-executes next round against the new store
   (abort & retry, overlapping its predecessors' commit wait exactly as
   speculative transactions overlap waiting in the paper).

Transaction modes fall out structurally:

- the **head** of the pending prefix is the paper's *fast transaction*: its
  read phase ran against the fully-committed store and nothing can commit
  before it, so it needs **no validation** — it always commits (progress
  guarantee), and on TPU its write-back takes the direct-update Pallas
  kernel with no version tracking (kernels/commit.py).
- prefix members behind the head are *promoted* transactions
  (compatibility-checked fast commits / live promotion, §2.2.3);
- the remainder stay *speculative* and retry.

Determinism: the result depends only on (store, transactions, sequence
order) — never on arrival order, lane count, or timing.  ``pcc_execute``
takes an ``arrival`` permutation argument solely so tests can prove the
output is invariant to it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import protocol
from repro.core.engine import (MODE_FAST, MODE_PREFIX, MODE_SPEC, MODE_UNSET,
                               EngineDef, ExecTrace, make_trace,
                               register_engine, seq_rank)
from repro.core.tstore import TStore
from repro.core.txn import TxnBatch, TxnResult, run_all, run_txn

# The old per-engine trace dataclass is now the canonical schema.
PccTrace = ExecTrace


def _pcc_execute(store: TStore, batch: TxnBatch, seq: jax.Array,
                 max_rounds: int | None = None,
                 live_promotion: bool = True) -> tuple[TStore, ExecTrace]:
    """Execute a batch of preordered transactions under PCC.

    Args:
      store: committed TStore.
      batch: K transactions (dynamic read/write sets).
      seq:   (K,) int32 — 1-based sequence numbers from the sequencer
             (a permutation of 1..K).
      live_promotion: paper §2.2.3 — after the prefix commits, the next
             pending transaction has become the fast transaction (all
             predecessors committed); it re-executes against the updated
             store within the SAME round and commits unconditionally
             (its abort-and-retry-in-fast-mode path).  Halves the round
             count on conflict chains; False gives the Pot* ablation.
    Returns:
      (new store, trace).  ``new_store.gv`` equals ``store.gv + K``.
    """
    k = batch.n_txns
    n_obj = store.n_objects
    order = jnp.argsort(seq)  # order[p] = txn index at seq position p
    gv0 = store.gv

    def round_body(state):
        values, versions, gv, n_comm, rnd, tr = state
        res: TxnResult = run_all(batch, values)

        # --- ordered commit: maximal non-conflicting in-order prefix -----
        def commit_scan(carry, p):
            written, alive = carry
            t = order[p]
            pending = p >= n_comm
            conflict = protocol.footprint_conflicts(
                written, res.raddrs[t], res.rn[t], res.waddrs[t], res.wn[t])
            committing = alive & pending & ~conflict
            written = jax.lax.cond(
                committing,
                lambda w: protocol.mark_writes(w, res.waddrs[t], res.wn[t]),
                lambda w: w, written)
            alive = alive & (committing | ~pending)
            return (written, alive), committing

        (_, _), committing_pos = jax.lax.scan(
            commit_scan,
            (jnp.zeros((n_obj,), bool), jnp.asarray(True)),
            jnp.arange(k))

        # --- write-back in sequence order --------------------------------
        def apply_scan(carry, p):
            vals, vers = carry
            t = order[p]
            sn = gv0 + p + 1

            def do(args):
                v, ve = args
                return protocol.apply_writes(
                    v, ve, res.waddrs[t], res.wvals[t], res.wn[t], sn)

            vals, vers = jax.lax.cond(
                committing_pos[p], do, lambda a: a, (vals, vers))
            return (vals, vers), None

        (values, versions), _ = jax.lax.scan(
            apply_scan, (values, versions), jnp.arange(k))

        n_new = committing_pos.sum(dtype=jnp.int32)
        gv = gv + n_new

        # ---- live promotion (paper §2.2.3): the first NON-committing
        # pending transaction is now the fast transaction — re-execute it
        # against the freshly-committed store and commit unconditionally.
        promoted_pos = -jnp.ones((), jnp.int32)
        if live_promotion:
            head_pos = n_comm + n_new

            def promote(args):
                values, versions, gv = args
                t = order[jnp.clip(head_pos, 0, k - 1)]
                row = jax.tree.map(lambda a: a[t], batch)
                raddrs2, rn2, waddrs2, wvals2, wn2 = run_txn(row, values)
                del raddrs2, rn2
                values, versions = protocol.apply_writes(
                    values, versions, waddrs2, wvals2, wn2,
                    gv0 + head_pos + 1)
                return values, versions, gv + 1

            do_promote = head_pos < k
            values, versions, gv = jax.lax.cond(
                do_promote, promote, lambda a: a, (values, versions, gv))
            promoted_pos = jnp.where(do_promote, head_pos, -1)
            n_new = n_new + do_promote.astype(jnp.int32)

        # --- trace bookkeeping (by txn index) ----------------------------
        pos = jnp.arange(k)
        pending_pos = pos >= n_comm
        is_head = pos == n_comm
        promoted_mask = pos == promoted_pos
        committing_all = committing_pos | promoted_mask
        mode_pos = jnp.where(
            committing_all,
            jnp.where(is_head | promoted_mask, MODE_FAST, MODE_PREFIX),
            jnp.where(pending_pos, MODE_SPEC, MODE_UNSET))
        # scatter position-indexed info back to txn order
        commit_round = tr["commit_round"].at[order].max(
            jnp.where(committing_all, rnd, -1))
        first_round = tr["first_round"].at[order].min(
            jnp.where(pending_pos, rnd, jnp.iinfo(jnp.int32).max))
        retries = tr["retries"].at[order].add(
            (pending_pos & ~committing_all).astype(jnp.int32))
        mode = tr["mode"].at[order].max(mode_pos)
        wait_rounds = tr["wait_rounds"].at[order].add(
            (pending_pos & ~committing_all).astype(jnp.int32))
        # validation: head (fast) validates nothing; everyone else pending
        # validates its read set this round (paper Fig. 2b line 9 / 2c line 2)
        rn_pos = res.rn[order]
        validation_words = tr["validation_words"] + jnp.where(
            pending_pos & ~is_head, rn_pos, 0).sum(dtype=jnp.int32)
        exec_ops = tr["exec_ops"] + jnp.where(
            pending_pos, batch.n_ins[order], 0).sum(dtype=jnp.int32) \
            + jnp.where(promoted_mask, batch.n_ins[order],
                        0).sum(dtype=jnp.int32)  # promotion re-execution
        promotions = tr["promotions"] + promoted_mask.sum(dtype=jnp.int32)
        tr = dict(tr, commit_round=commit_round, first_round=first_round,
                  retries=retries, mode=mode, wait_rounds=wait_rounds,
                  validation_words=validation_words, exec_ops=exec_ops,
                  promotions=promotions)
        return values, versions, gv, n_comm + n_new, rnd + 1, tr

    def cond(state):
        *_, n_comm, rnd, _ = state
        return (n_comm < k) & (rnd < limit)

    limit = max_rounds if max_rounds is not None else k + 1
    tr0 = dict(
        commit_round=jnp.full((k,), -1, jnp.int32),
        first_round=jnp.full((k,), jnp.iinfo(jnp.int32).max, jnp.int32),
        retries=jnp.zeros((k,), jnp.int32),
        mode=jnp.zeros((k,), jnp.int32),
        wait_rounds=jnp.zeros((k,), jnp.int32),
        validation_words=jnp.zeros((), jnp.int32),
        exec_ops=jnp.zeros((), jnp.int32),
        promotions=jnp.zeros((), jnp.int32),
    )
    values, versions, gv, n_comm, rnd, tr = jax.lax.while_loop(
        cond, round_body,
        (store.values, store.versions, store.gv, jnp.zeros((), jnp.int32),
         jnp.zeros((), jnp.int32), tr0))

    trace = make_trace(
        k,
        commit_round=tr["commit_round"], first_round=tr["first_round"],
        retries=tr["retries"], mode=tr["mode"],
        wait_rounds=tr["wait_rounds"], rounds=rnd,
        validation_words=tr["validation_words"], exec_ops=tr["exec_ops"],
        promotions=tr["promotions"],
        # PCC commits in sequence order: position = rank in the order
        commit_pos=seq_rank(seq))
    return TStore(values=values, versions=versions, gv=gv), trace


pcc_execute = jax.jit(
    _pcc_execute, static_argnames=("max_rounds", "live_promotion"))


def _pcc_raw(store, batch, seq, lanes, n_lanes):
    del lanes, n_lanes  # PCC has no lane structure
    return _pcc_execute(store, batch, seq)


register_engine(EngineDef(
    "pcc", _pcc_raw,
    doc="Pot Concurrency Control — ordered prefix commit + live promotion"))
