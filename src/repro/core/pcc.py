"""Pot Concurrency Control (PCC) — the paper's contribution (§2.2), adapted
to a dataflow runtime.

Vectorized round-based prefix commit
------------------------------------
Each engine round is three *batched* stages (the shared commit pipeline,
:mod:`repro.core.protocol`), not a walk over transactions:

1. **Speculative read phase** — every pending transaction executes
   (vmapped) against the committed store image (deferred updates, logged
   footprints: OCC read phase, Fig. 2a/2b).  Since PR 3 this is the
   *masked* executor (``txn.run_live`` via ``protocol.RoundState``):
   only the pending suffix re-executes, committed transactions keep
   their cached results, and the conflict table is delta-updated rather
   than rebuilt — per-round live counts land in
   ``ExecTrace.live_per_round``.
2. **Batched conflict analysis** — the paper's per-transaction
   validation question asked for the whole batch at once
   (``protocol.earlier_writer_conflicts``): on TPU a masked
   row-reduction of the K×K footprint-conflict matrix
   (``kernels/conflict.py``, a tiled bitset-intersection Pallas kernel
   over bit-packed read/write sets), elsewhere a first-writer-per-
   address scatter-min with O(K·L) work — two decision-identical
   formulations of the same question.
3. **Prefix fixpoint + fused write-back** — the maximal committing
   in-order prefix (§2.2.2 "ordered commits" + §2.2.3 "multiple
   simultaneous fast transactions") is a cumulative AND over the
   matrix's masked row-reduction: ``protocol.prefix_commit`` resolves it
   in ≤⌈log₂K⌉ device steps via ``associative_scan``, where the old
   implementation scanned all K positions sequentially, probing an
   O(n_objects) bitmap per step.  The whole prefix's deferred writes
   then land in ONE flattened scatter (``protocol.fused_write_back``):
   the winning writer per address is selected by (commit-position,
   write-slot) priority, which subsumes both the per-transaction apply
   chain and per-transaction last-writer dedup.

The conflicting suffix re-executes next round against the new store
(abort & retry, overlapping its predecessors' commit wait exactly as
speculative transactions overlap waiting in the paper).

Transaction modes fall out structurally:

- the **head** of the pending prefix is the paper's *fast transaction*:
  nothing can commit before it, so row head of the matrix is all-clear
  by construction — it always commits (progress guarantee), with no
  validation work accounted;
- prefix members behind the head are *promoted* transactions
  (compatibility-checked fast commits / live promotion, §2.2.3);
- the remainder stay *speculative* and retry.  After the prefix
  commits, the next pending transaction re-executes serially against
  the fresh store and commits unconditionally (live promotion).

Determinism: the result depends only on (store, transactions, sequence
order) — never on arrival order, lane count, or timing.  ``pcc_execute``
takes an ``arrival`` permutation argument solely so tests can prove the
output is invariant to it.  The decisions are bit-identical to the
pre-vectorization scan (``repro.core.legacy_scan``, asserted in
tests/test_commit_pipeline.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import protocol
from repro.core.engine import (MODE_FAST, MODE_PREFIX, MODE_SPEC, MODE_UNSET,
                               EngineDef, ExecTrace, make_trace,
                               rank_from_order, register_engine)
from repro.core.tstore import TStore, flat_values, store_with
from repro.core.txn import TxnBatch, TxnResult, run_txn

# The old per-engine trace dataclass is now the canonical schema.
PccTrace = ExecTrace


def _pcc_execute(store: TStore, batch: TxnBatch, seq: jax.Array,
                 max_rounds: int | None = None,
                 live_promotion: bool = True,
                 incremental: bool = True,
                 compact: bool = True,
                 seed: "protocol.SpecSeed | None" = None
                 ) -> tuple[TStore, ExecTrace]:
    """Execute a batch of preordered transactions under PCC.

    Args:
      store: committed TStore.
      batch: K transactions (dynamic read/write sets).  Rows with
             ``n_ins == 0`` are *vacant* (shape-bucket padding from
             ``PotSession.submit``): never pending, never committed, no
             ``gv`` advance, ``commit_pos == -1``.  Their sequence
             numbers must sort after every real row's.
      seq:   (K,) int32 — 1-based sequence numbers from the sequencer
             (a permutation of 1..K).
      live_promotion: paper §2.2.3 — after the prefix commits, the next
             pending transaction has become the fast transaction (all
             predecessors committed); it re-executes against the updated
             store within the SAME round and commits unconditionally
             (its abort-and-retry-in-fast-mode path).  Halves the round
             count on conflict chains; False gives the Pot* ablation.
      incremental: re-execute only the pending suffix each round
             (masked ``run_live`` + carried conflict table via
             ``protocol.RoundState``); False rebuilds everything per
             round (the PR 2 behavior, kept for benchmarking and the
             incremental-smoke equivalence gate).  Decision-identical:
             committed transactions' rows are never consumed by the
             prefix decision, so both paths commit bit-identically.
      compact: run the round loop as a cascade over
             ``protocol.compact_ladder(K)`` widths — once the pending
             suffix fits a narrower rung, the read phase gathers it into
             a (C, L) block and executes THAT
             (``protocol.refresh_round_state_compact``), so the sparse
             tail of a contended batch pays device work proportional to
             the live set instead of K.  Decisions stay in rank space
             and are bit-identical to the masked loop (False; asserted
             by tests and ``scripts/ci.sh --compact-smoke``).  Only
             meaningful with ``incremental=True``.
      seed:  optional :class:`protocol.SpecSeed` — a speculative round-0
             execution of this batch against an EARLIER store snapshot
             (cross-batch pipelining, ``PotSession(pipeline_depth=...)``).
             ``protocol.seed_round_state`` re-bases it onto the current
             store (re-executing only rows whose read set went stale)
             and round 0 charges its ordinary work accounting without
             re-walking the batch — bit-identical store and trace to the
             unseeded call, except the ``spec_*`` observables.
    Returns:
      (new store, trace).  ``new_store.gv`` equals ``store.gv`` + the
      number of real (non-vacant) transactions.
    """
    k = batch.n_txns
    layout = store.layout     # static: dense or S contiguous range shards
    n_obj = layout.n_objects
    order = jnp.argsort(seq)  # order[p] = txn index at seq position p
    rank = rank_from_order(order)
    gv0 = store.gv
    seq_nos = gv0 + 1 + rank   # version stamp per txn (its seq position)
    real = batch.n_ins > 0     # vacant rows (bucket padding) never commit
    n_real = real.sum(dtype=jnp.int32)

    def round_body_at(width: int):
        full = width >= k

        def round_body(state):
            rs, gv, n_comm, rnd, tr = state

            # --- read phase: only pending txns re-execute; below the full
            # rung they execute gather-compacted at (width, L) ------------
            pending_t = real & (rank >= n_comm)
            live = pending_t if incremental else jnp.ones((k,), bool)

            def refresh(r):
                if full:
                    return protocol.refresh_round_state(r, batch, live,
                                                        layout)
                return protocol.refresh_round_state_compact(
                    r, batch, live, width, layout)[0]

            if seeded:
                # round 0's read phase already ran speculatively and was
                # re-based onto this store by seed_round_state — charge
                # the identical work accounting without re-walking
                rs = jax.lax.cond(
                    rnd == 0,
                    lambda r: protocol.charge_round_state(
                        r, batch, live, k if full else width),
                    refresh, rs)
            else:
                rs = refresh(rs)
            res: TxnResult = rs.res

            # --- carried conflict analysis + prefix fixpoint (txn space) -
            committing_t = protocol.prefix_commit(
                res, rs.conflict, order, rank, n_comm, n_obj, real)

            # --- fused write-back: the whole prefix in one scatter -------
            values, versions = protocol.fused_write_back(
                rs.values, rs.versions, res.waddrs, res.wvals, res.wn,
                committing_t, rank, seq_nos, layout)

            n_new = committing_t.sum(dtype=jnp.int32)
            gv = gv + n_new

            # ---- live promotion (paper §2.2.3): the first NON-committing
            # pending transaction is now the fast transaction — re-execute
            # it against the freshly-committed store and commit
            # unconditionally.
            promoted_pos = -jnp.ones((), jnp.int32)
            if live_promotion:
                head_pos = n_comm + n_new

                def promote(args):
                    values, versions, gv = args
                    t = order[jnp.clip(head_pos, 0, k - 1)]
                    row = jax.tree.map(lambda a: a[t], batch)
                    raddrs2, rn2, waddrs2, wvals2, wn2 = run_txn(
                        row, flat_values(values, layout), n_obj)
                    del raddrs2, rn2
                    values, versions = protocol.apply_writes(
                        values, versions, waddrs2, wvals2, wn2,
                        gv0 + head_pos + 1, layout)
                    return values, versions, gv + 1

                do_promote = head_pos < n_real
                values, versions, gv = jax.lax.cond(
                    do_promote, promote, lambda a: a,
                    (values, versions, gv))
                promoted_pos = jnp.where(do_promote, head_pos, -1)
                n_new = n_new + do_promote.astype(jnp.int32)

            # --- trace bookkeeping: all txn-space, all elementwise -------
            is_head_t = rank == n_comm
            promoted_t = rank == promoted_pos
            committing_all = committing_t | promoted_t
            mode_t = jnp.where(
                committing_all,
                jnp.where(is_head_t | promoted_t, MODE_FAST, MODE_PREFIX),
                jnp.where(pending_t, MODE_SPEC, MODE_UNSET))
            commit_round = jnp.maximum(tr["commit_round"],
                                       jnp.where(committing_all, rnd, -1))
            first_round = jnp.minimum(
                tr["first_round"],
                jnp.where(pending_t, rnd, jnp.iinfo(jnp.int32).max))
            retries = tr["retries"] + (pending_t & ~committing_all)
            mode = jnp.maximum(tr["mode"], mode_t)
            wait_rounds = tr["wait_rounds"] + (pending_t & ~committing_all)
            # validation: head (fast) validates nothing; everyone else
            # pending validates its read set this round (paper Fig. 2b
            # line 9 / 2c line 2) — a single masked reduction
            validation_words = tr["validation_words"] + jnp.where(
                pending_t & ~is_head_t, res.rn, 0).sum(dtype=jnp.int32)
            exec_ops = tr["exec_ops"] + jnp.where(
                pending_t, batch.n_ins, 0).sum(dtype=jnp.int32) \
                + jnp.where(promoted_t, batch.n_ins,
                            0).sum(dtype=jnp.int32)  # promotion re-exec
            promotions = tr["promotions"] + promoted_t.sum(dtype=jnp.int32)
            live_per_round = tr["live_per_round"].at[rnd].set(
                live.sum(dtype=jnp.int32))
            tr = dict(tr, commit_round=commit_round,
                      first_round=first_round, retries=retries, mode=mode,
                      wait_rounds=wait_rounds,
                      validation_words=validation_words, exec_ops=exec_ops,
                      promotions=promotions, live_per_round=live_per_round)
            rs = protocol.commit_round_state(rs, values, versions)
            return rs, gv, n_comm + n_new, rnd + 1, tr

        return round_body

    def cond_at(next_width: int):
        def cond(state):
            _, _, n_comm, rnd, _ = state
            go = (n_comm < n_real) & (rnd < limit)
            if next_width:
                # hand over to the narrower rung once the pending suffix
                # fits it
                go = go & (n_real - n_comm > next_width)
            return go

        return cond

    limit = max_rounds if max_rounds is not None else k + 1
    tr0 = dict(
        commit_round=jnp.full((k,), -1, jnp.int32),
        first_round=jnp.full((k,), jnp.iinfo(jnp.int32).max, jnp.int32),
        retries=jnp.zeros((k,), jnp.int32),
        mode=jnp.zeros((k,), jnp.int32),
        wait_rounds=jnp.zeros((k,), jnp.int32),
        validation_words=jnp.zeros((), jnp.int32),
        exec_ops=jnp.zeros((), jnp.int32),
        promotions=jnp.zeros((), jnp.int32),
        live_per_round=jnp.full((limit,), -1, jnp.int32),
    )
    seeded = seed is not None   # static per trace (None jits leaf-free)
    if seeded:
        rs0, spec_inv, spec_rnds = protocol.seed_round_state(
            batch, store, seed, compact=(incremental and compact))
    else:
        rs0 = protocol.init_round_state(batch, store.values,
                                        store.versions, layout=layout)
    ladder = (protocol.compact_ladder(k) if (incremental and compact)
              else [k])
    state = (rs0, store.gv, jnp.zeros((), jnp.int32),
             jnp.zeros((), jnp.int32), tr0)
    state = protocol.run_compact_cascade(ladder, state, round_body_at,
                                         cond_at)
    rs, gv, n_comm, rnd, tr = state

    trace = make_trace(
        k,
        commit_round=tr["commit_round"],
        first_round=jnp.where(real, tr["first_round"], -1),
        retries=tr["retries"], mode=tr["mode"],
        wait_rounds=tr["wait_rounds"], rounds=rnd,
        validation_words=tr["validation_words"], exec_ops=tr["exec_ops"],
        promotions=tr["promotions"],
        live_txns=rs.live_txns, live_slots=rs.live_slots,
        walked_slots=rs.walked_slots,
        live_per_round=tr["live_per_round"],
        # PCC commits in sequence order: position = rank in the order.
        # Vacant rows and rows a max_rounds cap left uncommitted
        # (commit_round < 0) are not part of the history: commit_pos -1
        commit_pos=jnp.where(real & (tr["commit_round"] >= 0), rank, -1),
        **(dict(spec_executed=n_real, spec_invalidated=spec_inv,
                spec_rounds=spec_rnds) if seeded else {}))
    return store_with(store, rs.values, rs.versions, gv), trace


pcc_execute = jax.jit(
    _pcc_execute,
    static_argnames=("max_rounds", "live_promotion", "incremental",
                     "compact"))


def _pcc_raw(store, batch, seq, lanes, n_lanes):
    del lanes, n_lanes  # PCC has no lane structure
    return _pcc_execute(store, batch, seq)


def _pcc_raw_spec(store, batch, seq, lanes, n_lanes, seed):
    del lanes, n_lanes
    return _pcc_execute(store, batch, seq, seed=seed)


register_engine(EngineDef(
    "pcc", _pcc_raw,
    doc="Pot Concurrency Control — ordered prefix commit + live promotion",
    raw_spec=_pcc_raw_spec))
