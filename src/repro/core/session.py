"""PotSession — the streaming execution layer over the unified engine API.

A session owns the three pieces Pot threads through time:

- the **store** (committed TStore image + ``gv``), carried across batches
  so a stream of batches behaves like one long preordered history;
- the **sequencer**, which keeps assigning globally increasing sequence
  numbers (round-robin over lanes by default, or any sequencer from
  :mod:`repro.core.sequencer`);
- a **cached jitted step** for its engine, with the incoming store
  buffers *donated* — on accelerators the committed image is updated in
  place instead of copied every batch.

**Shape bucketing** (PR 4): a ragged stream of batch shapes would force
one XLA compile per distinct ``(K, L)`` — a serving-workload killer.
``submit`` therefore pads every batch up to the next power-of-two bucket
with *vacant* NOP rows (``n_ins == 0``; sequence numbers past every real
row's), so the jitted step compiles once per (engine, bucket): a 32-shape
ragged stream compiles at most ladder-size (= log₂ range) steps.  The
engines guarantee vacant rows never commit — no store write, no version
stamp, no ``gv`` advance, ``commit_pos == -1`` — so fingerprints and
``replay_log()`` are bit-identical to the unpadded run (asserted in
tests/test_compact_bucket.py).  The returned traces are sliced back to
the real K, so callers never see padding.  Observables:
``compile_count()`` (distinct compiled step shapes this session
triggered) and ``bucket_counts()`` (batches per bucket).

Usage::

    session = PotSession(n_objects=1024, engine="pcc", n_lanes=8)
    for batch in batches:
        trace = session.submit(batch, lanes)       # one ExecTrace each
    session.fingerprint()                          # determinism check
    log = session.replay_log()                     # global commit order
    session.compile_count()                        # <= #buckets, not #shapes

**Deterministic ingress** (PR 6): a session can also be fed by an
:class:`~repro.core.ingress.IngressPool` — the admission + priority-
drain front-end that *forms* batches from single-transaction arrivals.
``serve(pool, budget=...)`` drains the pool to empty; the pool's drain
order is the preordered sequence (the formed batches carry their own
globally consecutive sequence numbers) and the shape bucket follows the
pool's occupancy-driven ladder recommendation::

    pool = IngressPool(capacity=4096)
    for program, lane, fee in arrivals:
        pool.admit(program, lane=lane, fee=fee)
    session.serve(pool, budget=64)

The recorded log feeds straight back into a new session for
record/replay debugging (paper §2.1)::

    replay = PotSession(n_objects=1024, engine="pcc",
                        sequencer=session.replay_sequencer())
    replay.run_stream(batches)                     # bitwise-identical

**Cross-batch speculative pipelining** (PR 7): with
``pipeline_depth=D >= 1``, ``run_stream`` / ``serve`` keep a window of
up to D batches *speculatively executed* ahead of the committed store:
each enqueued batch runs its round-0 read phase + conflict analysis
against the CURRENT store image (``protocol.spec_execute`` — a pure
read, overlappable with the predecessor batches' tail rounds), and when
its turn comes the engine re-bases that seed onto the now-committed
store: rows whose read set hit a post-snapshot write (the version-stamp
dirty predicate ``versions > snap_gv``) re-execute through the ordinary
compact ladder; everything else is already bit-identical to a fresh
round 0.  Ranks are globally consecutive across batches (the sequencer
/ ingress drain order), so the validation stays in rank space and the
pipelined stream's stores, fingerprints, traces and ``replay_log()``
are bit-identical to the serial ``D=0`` run by construction (asserted
in tests/test_pipeline.py and ``scripts/ci.sh --pipeline-smoke``); the
speculation cost is surfaced only in the new ``ExecTrace.spec_*``
observables.  ``D=0`` (default) is exactly the pre-PR path.  Since
PR 10 every registry engine has a seeded entry point (``raw_spec``),
so pipelining covers all four; an out-of-registry engine registered
without one still silently serves the (bit-identical) serial path.

**Crash-consistent checkpoints** (PR 9): ``snapshot(dir, pool=...)`` /
``PotSession.restore(dir, arrival_journal=...)`` round-trip the complete
resumable state — store image, ``gv``, sequencer cursor, submit / formed
counters, bucket bookkeeping, replay log, elastic lane-manager state,
and the ingress journal cursor — through the atomic, self-verifying
snapshot format of :mod:`repro.core.checkpoint`.  The recovery
invariant: restore(latest snapshot) + drain(arrival-journal suffix) is
bit-identical to the uninterrupted stream at any snapshot point, any
drain-budget schedule, any ``pipeline_depth`` (the speculative window
is flushed into the snapshot, never persisted speculatively).

Every engine runs through the same ``submit`` — there is no per-engine
signature anywhere above this layer.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import protocol
from repro.core.engine import EngineDef, ExecTrace, get_engine
from repro.core.sequencer import ReplaySequencer, RoundRobinSequencer
from repro.core.tstore import TStore, make_store, shard_store
from repro.core.tstore import fingerprint as store_fingerprint
from repro.core.txn import TxnBatch, next_pow2, pad_batch

# per-transaction ExecTrace fields, sliced back to the real K after a
# bucketed submit (everything else is scalar or per-round)
_PER_TXN_FIELDS = ("commit_round", "commit_pos", "first_round", "retries",
                   "mode", "wait_rounds")


def dense_bucket(k: int) -> int:
    """The denser small-K bucket ladder (ROADMAP open item): {1, 2, 4, 8}
    below 8, then multiples of 8 — serving tails with many mid-size
    batches pad to the next 8 instead of the next power of two (e.g.
    K=17 runs at 24, not 32), trading a few more compiled rungs for
    much less vacant-row padding per batch."""
    if k <= 8:
        return next_pow2(k)
    return -(-k // 8) * 8


@functools.lru_cache(maxsize=None)
def _jitted_step(engine_name: str, donate: bool):
    """One compiled step per (engine, donation) — shared by all sessions
    so repeated sessions reuse compilation caches."""
    eng = get_engine(engine_name)
    return jax.jit(eng.raw, static_argnums=(4,),
                   donate_argnums=(0,) if donate else ())


@functools.lru_cache(maxsize=None)
def _jitted_spec_step(engine_name: str, donate: bool):
    """The seeded twin of :func:`_jitted_step` (``eng.raw_spec``): same
    donation, the extra trailing ``seed`` argument traced."""
    eng = get_engine(engine_name)
    return jax.jit(eng.raw_spec, static_argnums=(4,),
                   donate_argnums=(0,) if donate else ())


# the speculative round-0 step: reads the store, never donates it — the
# same buffers are consumed later by the real (seeded) step
_spec_execute_step = jax.jit(protocol.spec_execute)


class PotSession:
    """Deterministic transactional execution over a stream of batches.

    Args:
      n_objects: size of a fresh store (ignored if ``store`` is given).
      slot / init: forwarded to :func:`make_store` for the fresh store.
      store: an existing TStore to adopt.  The session takes ownership:
        with ``donate=True`` its buffers are consumed by the first step.
      engine: engine name (``"pcc"`` / ``"pogl"`` / ``"destm"`` /
        ``"occ"``, ``"pot"`` aliases ``"pcc"``) or an
        :class:`~repro.core.engine.EngineDef`.
      sequencer: any object with ``order_for(keys) -> (K,) seq numbers``;
        defaults to a ``RoundRobinSequencer`` over ``n_lanes`` lanes.
      n_lanes: lane count (round-robin width, DeSTM round width).
      donate: donate the store buffers to the jitted step (in-place
        update on backends that support it).
      bucket: pad ragged batch shapes up to power-of-two buckets with
        vacant NOP rows so the jitted step compiles per bucket, not per
        exact shape (bit-identical outcome; see the module docstring).
        False submits exact shapes (one compile each — the pre-PR4
        behavior, kept for benchmarking the recompile cost).
      bucket_ladder: the K-axis bucket family.  ``"pow2"`` (default)
        rounds K up to the next power of two; ``"dense"`` uses the
        denser serving-tail ladder {1, 2, 4, 8} ∪ multiples of 8
        (ROADMAP open item) — less padding waste per small/mid batch at
        the cost of more rungs (compile count still bounded by the
        ladder size; asserted in tests).  The L axis always buckets to
        powers of two.
      shards: partition the store's address space into S contiguous
        range shards (:class:`~repro.core.tstore.ShardedStore`):
        per-shard conflict analysis and S independent write-back
        scatters, with every commit decision still taken in global rank
        space — fingerprints, traces and ``replay_log()`` are
        bit-identical to ``shards=1`` (the dense store).
      mesh: optional 1-axis ``jax.sharding.Mesh`` of exactly ``shards``
        devices; when given, the per-shard write-back scatters run
        one-per-device under ``jax.experimental.shard_map``.  The mesh
        travels on the store pytree as a static field, so it threads
        through the cached jitted step with no signature change.
      pipeline_depth: speculate up to D batches ahead of the committed
        store in ``run_stream`` / ``serve`` (cross-batch pipelining —
        see the module docstring).  Bit-identical to the serial stream
        for any D; 0 (default) is exactly the pre-PR serial path, as is
        any out-of-registry engine without a seeded entry point
        (``raw_spec is None`` — all four registry engines have one).
    """

    def __init__(self, n_objects: int | None = None, *, slot: int = 1,
                 init=None, store: TStore | None = None,
                 engine: str | EngineDef = "pcc", sequencer=None,
                 n_lanes: int = 1, donate: bool = True,
                 bucket: bool = True, bucket_ladder: str = "pow2",
                 shards: int = 1, mesh=None, pipeline_depth: int = 0,
                 elastic=None):
        if store is None:
            if n_objects is None:
                raise ValueError("PotSession needs n_objects or store")
            store = make_store(n_objects, slot=slot, init=init,
                               shards=shards, mesh=mesh)
        elif shards > 1 or mesh is not None:
            if not isinstance(store, TStore):
                raise ValueError(
                    "pass either an already-sharded store OR shards=/"
                    "mesh= with a dense store, not both")
            store = shard_store(store, shards, mesh=mesh)
        if bucket_ladder not in ("pow2", "dense"):
            raise ValueError(
                f"bucket_ladder must be 'pow2' or 'dense', "
                f"got {bucket_ladder!r}")
        self.bucket_ladder = bucket_ladder
        self.store = store
        self.engine = engine if isinstance(engine, EngineDef) \
            else get_engine(engine)
        self.n_lanes = n_lanes
        self.sequencer = sequencer if sequencer is not None \
            else RoundRobinSequencer(n_root_lanes=n_lanes)
        self.bucket = bucket
        self._step = _jitted_step(self.engine.name, donate)
        if pipeline_depth < 0:
            raise ValueError("pipeline_depth must be >= 0")
        self.pipeline_depth = pipeline_depth
        # pipelining needs the engine's seeded entry point; without one
        # the session silently serves the (bit-identical) serial path
        self._pipelined = (pipeline_depth > 0
                           and self.engine.raw_spec is not None)
        self._spec_step = (_jitted_spec_step(self.engine.name, donate)
                           if self._pipelined else None)
        # speculation window: (batch, seq, lane_ids, seed, k, bk) tuples
        # enqueued ahead of the committed store, oldest first
        self._window: list[tuple] = []
        self.traces: list[ExecTrace] = []
        # replay log cache, materialized lazily (device->host sync happens
        # in replay_log(), never on the hot submit path)
        self._log: list[int] = []
        self._log_batches = 0      # traces already folded into _log
        self._log_txns = 0         # Σ n_txns of those traces (id offset)
        self._n_txns = 0
        # compile-cache observables: step shapes this session triggered
        # (one XLA compile each) and batches submitted per bucket
        self._bucket_counts: dict[tuple[int, int], int] = {}
        # elastic worker pool (runtime.elastic.ElasticLaneManager or
        # None): scaling events apply at formed-batch boundaries and
        # client lanes map onto live worker lanes — snapshot-visible
        # state, so a restored replica numbers lanes identically
        self.elastic = elastic
        # failover bookkeeping (PR 9): formed-batch cursor (the budget-
        # schedule index a restored replica re-enters at), snapshot
        # chain state, and the restore observables the metrics CSV
        # surfaces (snapshots_taken / restored_from / recovery_batches)
        self._batches_formed = 0
        self.snapshots_taken = 0
        self.restored_from = -1       # snapshot id, or -1 (never restored)
        self._chain_digest = ""       # last committed snapshot's chain
        self._next_snapshot_id = 0

    # ------------------------------------------------------------- stream
    def _bucket_shape(self, batch: TxnBatch,
                      ladder: str | None = None) -> tuple[int, int]:
        """The (K, L) step shape a batch runs at: the exact shape when not
        bucketing, else K rounded up along the bucket ladder (pow2, or
        the denser {1, 2, 4, 8} ∪ 8·n serving ladder) and L to the next
        power of two.  ``ladder`` overrides the session default per batch
        (the ingress pool's occupancy-driven recommendation)."""
        if not self.bucket:
            return batch.n_txns, batch.max_ins
        ladder = ladder if ladder is not None else self.bucket_ladder
        return (dense_bucket(batch.n_txns) if ladder == "dense"
                else next_pow2(batch.n_txns)), next_pow2(batch.max_ins)

    def submit(self, batch: TxnBatch, lanes: Sequence | None = None
               ) -> ExecTrace:
        """Sequence and execute one batch against the session store.

        ``lanes`` is the per-txn sequencing key — lane ids for the
        round-robin sequencer, txn names for an ``ExplicitSequencer``,
        ignored by a ``ReplaySequencer``.  Defaults to one lane.

        With bucketing on, the batch is padded to its shape bucket with
        vacant NOP rows (sequence numbers past every real one, so they
        never commit) before hitting the jitted step; the returned trace
        is sliced back to the batch's real K rows.
        """
        k = batch.n_txns
        keys = list(lanes) if lanes is not None else [0] * k
        if len(keys) != k:
            raise ValueError(f"batch has {k} txns, got {len(keys)} lanes")
        # submit is synchronous (returns THIS batch's trace), so any
        # speculation window left pending must execute first — order is
        # the sequencer's.  run_stream/serve always flush before
        # returning, so this is a no-op there.
        self._spec_flush()
        seq = np.asarray(self.sequencer.order_for(keys), np.int64)
        return self._submit_seq(batch, seq, self._lane_ids(keys))

    def _prepare(self, batch: TxnBatch, seq: np.ndarray,
                 lane_ids: np.ndarray, ladder: str | None = None):
        """Bucket accounting + vacant-row padding for one batch, shared
        by the serial step and the speculative enqueue: pads the batch
        to its (K, L) bucket and extends ``seq`` / ``lane_ids`` over the
        vacant rows (sequence numbers past every real one).  Returns
        ``(batch, seq, lane_ids, k, bk)`` with k the real row count."""
        k = batch.n_txns
        seq = np.asarray(seq, np.int64)
        lane_ids = np.asarray(lane_ids, np.int64) % max(self.n_lanes, 1)
        bk, bl = self._bucket_shape(batch, ladder)
        self._bucket_counts[(bk, bl)] = \
            self._bucket_counts.get((bk, bl), 0) + 1
        if (bk, bl) != (k, batch.max_ins):
            batch = pad_batch(batch, bk, bl)
            base = seq.max() if k else 0
            seq = np.concatenate([seq, base + 1 + np.arange(bk - k)])
            lane_ids = np.concatenate(
                [lane_ids, np.zeros((bk - k,), lane_ids.dtype)])
        return batch, seq, lane_ids, k, bk

    def _record(self, trace: ExecTrace, k: int, bk: int) -> ExecTrace:
        """Post-step bookkeeping: slice vacant rows back off and append
        the trace (kept on device — the commit order is recorded by
        keeping the trace, and replay_log() materializes it on demand,
        so no device->host sync on the streaming hot path)."""
        if bk != k:   # slice vacant rows back off (lazy device ops)
            trace = dataclasses.replace(trace, **{
                f: getattr(trace, f)[:k] for f in _PER_TXN_FIELDS})
        self._n_txns += k
        self.traces.append(trace)
        return trace

    def _submit_seq(self, batch: TxnBatch, seq: np.ndarray,
                    lane_ids: np.ndarray,
                    ladder: str | None = None) -> ExecTrace:
        """The core of ``submit`` with the sequence numbers already
        assigned — the entry point for batch formers that ARE the
        sequencer (the ingress pool's drain order): ``seq`` ranks the
        rows, ``lane_ids`` are engine-facing lanes (reduced mod
        ``n_lanes``), ``ladder`` optionally overrides the session's
        bucket family for this batch."""
        batch, seq, lane_ids, k, bk = self._prepare(batch, seq, lane_ids,
                                                    ladder)
        self.store, trace = self._step(
            self.store, batch, jnp.asarray(seq, jnp.int32),
            jnp.asarray(lane_ids, jnp.int32), self.n_lanes)
        return self._record(trace, k, bk)

    # ------------------------------------------ cross-batch speculation
    def _spec_enqueue(self, batch: TxnBatch, seq: np.ndarray,
                      lane_ids: np.ndarray,
                      ladder: str | None = None) -> None:
        """Speculatively execute one batch's round 0 against the CURRENT
        store image (a pure read — the store buffers stay owned by the
        pending window's drains) and append it to the window."""
        batch, seq, lane_ids, k, bk = self._prepare(batch, seq, lane_ids,
                                                    ladder)
        seed = _spec_execute_step(self.store, batch)
        self._window.append((batch, seq, lane_ids, seed, k, bk))

    def _spec_drain(self) -> ExecTrace:
        """Execute the window's oldest batch for real: the engine's
        seeded step validates the speculation against the now-current
        store and re-executes only invalidated rows."""
        batch, seq, lane_ids, seed, k, bk = self._window.pop(0)
        self.store, trace = self._spec_step(
            self.store, batch, jnp.asarray(seq, jnp.int32),
            jnp.asarray(lane_ids, jnp.int32), self.n_lanes, seed)
        return self._record(trace, k, bk)

    def _spec_flush(self) -> list[ExecTrace]:
        """Drain the whole speculation window (stream end / before any
        synchronous submit)."""
        out = []
        while self._window:
            out.append(self._spec_drain())
        return out

    def _serve_formed(self, fb, ladder: str | None = None
                      ) -> list[ExecTrace]:
        """Execute one ingress-formed batch (the unit step of ``serve``
        and of the failover replica loop in ``repro.core.checkpoint``).

        Advances the elastic lane manager to this formed-batch boundary
        (scaling events are positions in the order — a restored replica
        re-applies them identically) and maps client lanes onto live
        worker lanes; bumps the formed-batch cursor; then submits —
        through the speculation window when pipelined.  Returns the
        traces completed by this step (possibly none while the window
        fills)."""
        fb_ladder = ladder if ladder is not None else fb.ladder
        lanes = fb.lanes
        if self.elastic is not None:
            self.elastic.advance_to(self._batches_formed + 1)
            lanes = np.asarray([self.elastic.worker_for(int(l))
                                for l in np.asarray(fb.lanes)], np.int64)
        self._batches_formed += 1
        if self._pipelined:
            self._spec_enqueue(fb.batch, fb.seq, lanes, ladder=fb_ladder)
            out = []
            while len(self._window) > self.pipeline_depth:
                out.append(self._spec_drain())
            return out
        return [self._submit_seq(fb.batch, fb.seq, lanes,
                                 ladder=fb_ladder)]

    def serve(self, pool, budget: int = 64, *,
              max_batches: int | None = None,
              ladder: str | None = None, elastic=None) -> list[ExecTrace]:
        """Drain an :class:`~repro.core.ingress.IngressPool` through the
        session until it is empty (or ``max_batches``): the deterministic
        ingress serve loop.

        Each iteration asks the pool to *form* the next batch
        (``pool.drain(budget)``) and executes it.  The pool's drain
        order IS the preordered sequence — the formed batch carries its
        own globally consecutive sequence numbers, so the session's
        sequencer is neither consulted nor advanced.  The (K, L) shape
        bucket follows the pool's occupancy-driven ladder recommendation
        (``FormedBatch.ladder``) unless ``ladder`` pins one, closing the
        bucket auto-selection loop: mid-size drain tails steer the step
        shapes to the dense ladder, pow2-ish drains to pow2 — with
        bit-identical commits either way (padding is vacant rows).

        Two replica sessions serving pools fed the same arrival journal
        emit bit-identical stores, fingerprints and ``replay_log()``s
        for ANY budget schedules that drain the same prefix — and for
        any ``pipeline_depth`` (speculation changes when work runs, not
        what commits; the window drains fully before returning).

        ``elastic`` optionally attaches an
        :class:`~repro.runtime.elastic.ElasticLaneManager`: worker
        join/leave events apply at formed-batch boundaries and client
        lanes map onto live worker lanes (sequenced, snapshot-visible
        scaling — see ``_serve_formed``).
        """
        if elastic is not None:
            self.elastic = elastic
        traces: list[ExecTrace] = []
        formed = 0
        while max_batches is None or formed < max_batches:
            fb = pool.drain(budget)
            if fb is None:
                break
            formed += 1
            traces.extend(self._serve_formed(fb, ladder=ladder))
        traces.extend(self._spec_flush())
        return traces

    def run_stream(self, batches: Iterable[TxnBatch],
                   lanes: Sequence[Sequence] | None = None
                   ) -> list[ExecTrace]:
        """Submit a whole stream of batches; returns one trace each.

        The stream may be ragged — batches of arbitrary (K, L) shapes —
        and still compiles at most one step per shape bucket (the
        bucketed ``submit`` path; ``compile_count()`` proves it).

        With ``pipeline_depth=D >= 1`` this is the pipelined loop: each
        batch speculates against the current store at enqueue time and
        the window drains once it exceeds D — bit-identical traces in
        the same (submission) order, with the overlap surfaced in the
        ``spec_*`` trace fields."""
        batches = list(batches)
        lanes_list = list(lanes) if lanes is not None \
            else [None] * len(batches)
        if len(lanes_list) != len(batches):
            raise ValueError(
                f"{len(batches)} batches but {len(lanes_list)} lane lists")
        if not self._pipelined:
            return [self.submit(b, l) for b, l in zip(batches, lanes_list)]
        traces: list[ExecTrace] = []
        for b, l in zip(batches, lanes_list):
            k = b.n_txns
            keys = list(l) if l is not None else [0] * k
            if len(keys) != k:
                raise ValueError(
                    f"batch has {k} txns, got {len(keys)} lanes")
            seq = np.asarray(self.sequencer.order_for(keys), np.int64)
            self._spec_enqueue(b, seq, self._lane_ids(keys))
            while len(self._window) > self.pipeline_depth:
                traces.append(self._spec_drain())
        traces.extend(self._spec_flush())
        return traces

    def _lane_ids(self, keys) -> np.ndarray:
        """Engine-facing lane array: numeric keys mod n_lanes; symbolic
        sequencing keys (e.g. ExplicitSequencer names) map to lane 0."""
        try:
            ids = np.asarray(keys, dtype=np.int64)
        except (TypeError, ValueError):
            return np.zeros((len(keys),), np.int64)
        return ids % max(self.n_lanes, 1)

    # ------------------------------------------------------ introspection
    @property
    def n_txns(self) -> int:
        """Transactions committed by this session so far."""
        return self._n_txns

    @property
    def gv(self) -> int:
        """Global version = sequence number of the last commit."""
        return int(self.store.gv)

    @property
    def batches_formed(self) -> int:
        """Ingress-formed batches executed (or enqueued) by this session
        — the deterministic cursor a restored replica re-enters its
        budget/snapshot/scaling schedules at."""
        return self._batches_formed

    @property
    def recovery_batches(self) -> int:
        """Batches this session executed SINCE restoring from a
        snapshot (0 for a session that never restored) — the recovery-
        cost observable in the metrics CSV."""
        return len(self.traces) if self.restored_from >= 0 else 0

    # --------------------------------------------------- crash recovery
    def snapshot(self, directory: str, *, pool=None,
                 _torn_hook=None) -> str:
        """Commit one crash-consistent snapshot of this session (and the
        ingress ``pool`` feeding it) under ``directory`` — the complete
        resumable state, written atomically and self-verifying; the
        speculative window is flushed first (never persisted
        speculatively).  Returns the committed snapshot path.  See
        :func:`repro.core.checkpoint.save_snapshot`."""
        from repro.core import checkpoint as _ckpt
        return _ckpt.save_snapshot(self, directory, pool=pool,
                                   _torn_hook=_torn_hook)

    @classmethod
    def restore(cls, directory: str, **overrides
                ) -> "tuple[PotSession, object]":
        """Rebuild ``(session, pool)`` from the newest complete snapshot
        under ``directory`` (self-verified before serving); restoring
        mid-stream and draining the remaining arrival-journal suffix is
        bit-identical to the uninterrupted run.  Keyword overrides
        (``step=``, ``arrival_journal=``, ``shards=``, ``engine=``,
        ``bucket_ladder=``, ``pipeline_depth=``, ...) pass through to
        :func:`repro.core.checkpoint.restore_session`."""
        from repro.core import checkpoint as _ckpt
        return _ckpt.restore_session(directory, **overrides)

    def fingerprint(self) -> int:
        """Order-sensitive hash of the committed store image."""
        return int(store_fingerprint(self.store))

    def compile_count(self) -> int:
        """Distinct compiled step shapes this session has triggered — each
        one is an XLA compilation of the engine step.  With bucketing this
        is bounded by the bucket-ladder size regardless of how ragged the
        stream is; without it, every distinct (K, L) compiles.  (Shapes
        already compiled by an earlier same-engine session are served from
        jit's cache, so this is an upper bound on compiles actually paid.)
        """
        return len(self._bucket_counts)

    def bucket_counts(self) -> dict[tuple[int, int], int]:
        """Batches submitted per (K, L) step-shape bucket — the occupancy
        observable behind :meth:`compile_count`."""
        return dict(self._bucket_counts)

    def replay_log(self) -> list[int]:
        """Global commit order across the whole stream: entry i is the
        global txn id (batch offset + index) that committed i-th.

        Materialized lazily from the recorded traces (this is where the
        device->host sync happens); incremental, so repeated calls only
        pay for batches submitted since the last call.  Rows with
        ``commit_pos < 0`` (vacant bucket padding / uncommitted) are not
        part of the history and are skipped."""
        for trace in self.traces[self._log_batches:]:
            # global txn ids offset by the txns of all PRIOR batches (not
            # by log length: a batch can log fewer entries than its k if
            # rows never committed, and ids must not shift)
            offset = self._log_txns
            cp = np.asarray(trace.commit_pos)
            order = np.argsort(cp, kind="stable")
            order = order[cp[order] >= 0]
            self._log.extend(int(t) + offset for t in order)
            self._log_batches += 1
            self._log_txns += trace.n_txns
        return list(self._log)

    def live_counts(self) -> list[np.ndarray]:
        """Per-round live (re-executed) transaction counts, one array per
        submitted batch, trimmed to the rounds each batch actually ran.

        The observable behind the incremental round loop (PR 3): at low
        contention the counts collapse after round 0 because committed
        transactions stop re-executing; engines that predate the
        RoundState loop (legacy scans) return empty arrays.  Host-syncs
        the recorded traces — keep off the streaming hot path.
        """
        return [t.live_counts() for t in self.traces]

    def wave_counts(self) -> list[np.ndarray]:
        """Per-round retry-wave counts, one array per submitted batch,
        trimmed to the rounds each batch actually ran.

        The observable behind DeSTM's wave-speculative retries (PR 10):
        every wave trip re-executes ALL of a round's conflicting members
        and commits the maximal provably-serial token prefix, so the
        per-round wave counts sit at or below the serial walk's retry
        events (equality only on fully serial conflict chains).  Engines
        that do not record waves return empty arrays.  Host-syncs the
        recorded traces — keep off the streaming hot path.
        """
        return [t.wave_counts() for t in self.traces]

    def replay_sequencer(self) -> ReplaySequencer:
        """A sequencer that replays this session's commit order — feed it
        to a fresh ``PotSession`` with the same batches (paper §2.1)."""
        return ReplaySequencer(self.replay_log())
