"""Structural cost model over engine traces.

On a 1-CPU container we cannot reproduce POWER8 wall-clock; instead we
account *instruction-slots* — the deterministic unit the engines count
exactly — and build the paper's figures from them:

- ``critical_path``: Σ over engine rounds of the most expensive
  transaction executed in that round = parallel makespan with one lane per
  transaction.  PoGL's critical path is the serial sum (global lock).
- ``wait_rounds``: rounds a transaction spent executed-but-not-committed
  (Fig. 9's "time waiting for turn").
- ``work``: total instruction-slots executed including retries
  (speculation waste).
- ``wave_trips`` / ``live_txns``: the engine-loop observables of PR 3 —
  OCC's per-round conflict-chain depth (wave_commit fixpoint trips) and
  the incremental read phase's actual re-execution count.

Speculative instrumentation overhead (read-set tracking, write buffering,
validation) is charged per tracked word, mirroring what the paper's Fig. 6
microbenchmark measures per access.
"""

from __future__ import annotations

import dataclasses

import numpy as np

SPEC_TRACK_COST = 1.0   # per tracked read/write word (buffering, logging)
VALIDATE_COST = 1.0     # per validated read word


@dataclasses.dataclass
class EngineReport:
    name: str
    rounds: int
    work_ops: float          # total executed instruction slots (w/ retries)
    critical_path: float     # parallel makespan in op-slots
    total_wait_rounds: int
    retries: int
    fast_commits: int        # MODE_FAST commits (head of prefix)
    prefix_commits: int      # simultaneous-fast (promoted) commits
    throughput: float        # txns per critical-path op-slot
    wave_trips: int = 0      # Σ wave_commit fixpoint iterations (OCC):
    #                          contention cost of the commit decision
    live_txns: int = 0       # Σ per-round re-executed (live) txns — the
    #                          incremental loop's actual read-phase work
    walked_slots: int = 0    # Σ per-round executor width × L — device slots
    #                          the read phase walked (C·L per compact
    #                          round vs K·L masked; PR 4's observable)
    compile_count: int = 0   # distinct compiled step shapes of the session
    #                          behind this trace (bucketed streaming: <=
    #                          ladder size; 0 when no session was given)
    # -- ingress observables (PR 6): filled when a pool= is given -------
    queue_depth: int = 0     # transactions still parked in the pool
    admitted: int = 0        # pool admissions accepted so far
    evicted: int = 0         # watermark evictions so far
    drained: int = 0         # transactions formed into batches so far
    backpressure: int = 0    # 1 when the pool's backpressure signal is up
    # -- cross-batch speculation observables (PR 7): nonzero only for
    #    batches executed through a pipelined session ------------------
    spec_executed: int = 0   # rows executed against the pre-state snapshot
    spec_invalidated: int = 0  # speculated rows re-executed (stale reads)
    spec_rounds: int = 0     # revalidation re-execution passes (0 or 1)
    pipeline_depth: int = 0  # the session's speculation window depth
    # -- failover observables (PR 9): filled from the session ----------
    snapshots_taken: int = 0   # crash-consistent snapshots committed
    restored_from: int = -1    # snapshot id the session restored from
    #                            (-1: never restored)
    recovery_batches: int = 0  # batches executed since the restore
    # -- DeSTM retry-wave observables (PR 10) --------------------------
    retry_waves: int = 0     # Σ token-walk trips that re-executed ≥ 1
    #                          member (wave mode: ≤ retries; serial
    #                          walk: == retry events)
    spec_engine: int = 0     # 1 when the engine behind the trace has a
    #                          seeded entry point (raw_spec) — i.e. it
    #                          can serve a pipelined session

    def row(self) -> str:
        return (f"{self.name},{self.rounds},{self.work_ops:.0f},"
                f"{self.critical_path:.0f},{self.total_wait_rounds},"
                f"{self.retries},{self.fast_commits},{self.prefix_commits},"
                f"{self.throughput:.5f},{self.wave_trips},{self.live_txns},"
                f"{self.walked_slots},{self.compile_count},"
                f"{self.queue_depth},{self.admitted},{self.evicted},"
                f"{self.drained},{self.backpressure},{self.spec_executed},"
                f"{self.spec_invalidated},{self.spec_rounds},"
                f"{self.pipeline_depth},{self.snapshots_taken},"
                f"{self.restored_from},{self.recovery_batches},"
                f"{self.retry_waves},{self.spec_engine}")


HEADER = ("engine,rounds,work_ops,critical_path,wait_rounds,retries,"
          "fast_commits,prefix_commits,throughput,wave_trips,live_txns,"
          "walked_slots,compile_count,queue_depth,admitted,evicted,"
          "drained,backpressure,spec_executed,spec_invalidated,"
          "spec_rounds,pipeline_depth,snapshots_taken,restored_from,"
          "recovery_batches,retry_waves,spec_engine")


def _txn_cost(n_ins, rn, wn, fast: bool) -> np.ndarray:
    base = np.asarray(n_ins, dtype=np.float64)
    if fast:
        return base  # direct reads/writes, no tracking, no validation
    return base + SPEC_TRACK_COST * (np.asarray(rn) + np.asarray(wn)) \
        + VALIDATE_COST * np.asarray(rn)


def report_from_trace(name: str, trace, batch, res_rn, res_wn,
                      n_lanes: int = 1, session=None,
                      pool=None) -> EngineReport:
    """Build an EngineReport from the canonical ExecTrace of any engine.

    ``name`` picks the engine's cost structure ("pot"/"pcc", "pogl",
    "destm", "occ") — the *schema* is shared, the cost model is not:
    e.g. only Pot has an uninstrumented fast path, only DeSTM pays round
    barriers.

    ``session`` optionally attaches the PotSession the trace came from,
    filling the CSV's compile-cache columns (``compile_count`` — the
    shape-bucketing observable; see PotSession.compile_count()).

    ``pool`` optionally attaches the IngressPool that formed the batch,
    filling the ingress columns (queue depth, admitted/evicted/drained
    counters and the backpressure signal — see
    ``IngressPool.observables()``).
    """
    kind = {"pot": "pot", "pcc": "pot"}.get(name, name)
    if kind == "pot":
        rep = _report_pot(trace, batch, res_rn, res_wn)
    elif kind == "pogl":
        rep = _report_pogl(batch, res_rn, res_wn)
    elif kind == "destm":
        rep = _report_destm(trace, batch, res_rn, res_wn, n_lanes)
    elif kind == "occ":
        rep = _report_occ(trace, batch, res_rn, res_wn)
    else:
        raise KeyError(f"no report model for engine {name!r}")
    if trace is not None:
        rep.walked_slots = int(trace.walked_slots)
        # PR 7 speculation observables (zero for serial runs and for
        # legacy traces, whose make_trace defaults them)
        rep.spec_executed = int(trace.spec_executed)
        rep.spec_invalidated = int(trace.spec_invalidated)
        rep.spec_rounds = int(trace.spec_rounds)
        # PR 10 retry-wave observable (zero for engines without a
        # token-walk retry loop)
        rep.retry_waves = int(trace.retry_waves)
    if session is not None:
        eng = getattr(session, "engine", None)
        rep.spec_engine = int(getattr(eng, "raw_spec", None) is not None)
        rep.compile_count = session.compile_count()
        rep.pipeline_depth = int(getattr(session, "pipeline_depth", 0))
        # PR 9 failover observables (defaulted for session-like stubs)
        rep.snapshots_taken = int(getattr(session, "snapshots_taken", 0))
        rep.restored_from = int(getattr(session, "restored_from", -1))
        rep.recovery_batches = int(getattr(session, "recovery_batches", 0))
    if pool is not None:
        obs = pool.observables()
        rep.queue_depth = obs["queue_depth"]
        rep.admitted = obs["admitted"]
        rep.evicted = obs["evicted"]
        rep.drained = obs["drained"]
        rep.backpressure = obs["backpressure"]
    return rep


def _report_pot(trace, batch, res_rn, res_wn) -> EngineReport:
    from repro.core.engine import MODE_FAST, MODE_PREFIX
    n_ins = np.asarray(batch.n_ins)
    commit_round = np.asarray(trace.commit_round)
    first_round = np.asarray(trace.first_round)
    mode = np.asarray(trace.mode)
    rounds = int(trace.rounds)
    fast = mode == MODE_FAST
    cost_final = _txn_cost(n_ins, res_rn, res_wn, fast=False)
    cost_final[fast] = n_ins[fast]  # fast path: uninstrumented
    # executions before the commit round are retries at speculative cost
    retries = np.asarray(trace.retries)
    work = float(np.sum(cost_final + retries *
                        _txn_cost(n_ins, res_rn, res_wn, fast=False)))
    # critical path: per round, max cost among txns executing that round
    cp = 0.0
    for r in range(rounds):
        in_flight = (first_round <= r) & (commit_round >= r)
        if in_flight.any():
            cp += float(np.max(cost_final[in_flight]))
    k = len(n_ins)
    return EngineReport(
        name="pot", rounds=rounds, work_ops=work, critical_path=cp,
        total_wait_rounds=int(np.sum(trace.wait_rounds)),
        retries=int(retries.sum()),
        fast_commits=int(fast.sum()),
        prefix_commits=int((mode == MODE_PREFIX).sum()),
        throughput=k / cp if cp else float("inf"),
        live_txns=int(trace.live_txns))


def _report_pogl(batch, res_rn, res_wn) -> EngineReport:
    n_ins = np.asarray(batch.n_ins, dtype=np.float64)
    k = len(n_ins)
    cp = float(n_ins.sum())  # strictly serial, uninstrumented
    return EngineReport(
        name="pogl", rounds=k, work_ops=cp, critical_path=cp,
        total_wait_rounds=0, retries=0, fast_commits=k, prefix_commits=0,
        throughput=k / cp if cp else float("inf"))


def _report_destm(trace, batch, res_rn, res_wn, n_lanes: int) -> EngineReport:
    n_ins = np.asarray(batch.n_ins)
    commit_round = np.asarray(trace.commit_round)
    retries = np.asarray(trace.retries)
    rounds = int(trace.rounds)
    cost = _txn_cost(n_ins, res_rn, res_wn, fast=False)
    # round barrier: parallel first executions (max) + token-serialized
    # re-executions of conflicting members (sum), per DeSTM's round rule.
    cp = 0.0
    wait = 0
    for r in range(rounds):
        sel = commit_round == r
        if sel.any():
            round_cost = float(np.max(cost[sel])) + float(
                np.sum(cost[sel] * retries[sel]))
            cp += round_cost
            # every member waits for the barrier: each non-slowest member
            # idles this round (Fig. 10 start/commit waiting).
            wait += int(np.sum(cost[sel] * (1 + retries[sel]) < round_cost))
    k = len(n_ins)
    return EngineReport(
        name="destm", rounds=rounds, work_ops=float(np.sum(cost * (1 + retries))),
        critical_path=cp, total_wait_rounds=wait, retries=int(retries.sum()),
        fast_commits=0, prefix_commits=0,
        throughput=k / cp if cp else float("inf"),
        live_txns=int(trace.live_txns))


def _report_occ(trace, batch, res_rn, res_wn) -> EngineReport:
    n_ins = np.asarray(batch.n_ins)
    retries = np.asarray(trace.retries)
    waves = int(trace.rounds)
    cost = _txn_cost(n_ins, res_rn, res_wn, fast=False)
    cp = 0.0
    # txn committed in wave = retries (it retried that many waves)
    commit_wave = np.asarray(trace.commit_round)
    for w in range(waves):
        in_flight = commit_wave >= w
        if in_flight.any():
            cp += float(np.max(cost[in_flight]))
    k = len(n_ins)
    return EngineReport(
        name="occ", rounds=waves, work_ops=float(np.sum(cost * (1 + retries))),
        critical_path=cp, total_wait_rounds=0, retries=int(retries.sum()),
        fast_commits=0, prefix_commits=0,
        throughput=k / cp if cp else float("inf"),
        wave_trips=int(trace.wave_trips), live_txns=int(trace.live_txns))


# -- deprecated per-engine entry points (pre-ExecTrace API) ---------------
def report_pcc(trace, batch, res_rn, res_wn) -> EngineReport:
    return report_from_trace("pot", trace, batch, res_rn, res_wn)


def report_pogl(batch, res_rn, res_wn) -> EngineReport:
    return report_from_trace("pogl", None, batch, res_rn, res_wn)


def report_destm(trace, batch, res_rn, res_wn, n_lanes: int) -> EngineReport:
    return report_from_trace("destm", trace, batch, res_rn, res_wn, n_lanes)


def report_occ(trace, batch, res_rn, res_wn) -> EngineReport:
    return report_from_trace("occ", trace, batch, res_rn, res_wn)
