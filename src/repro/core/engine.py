"""Unified engine API: one protocol, one registry, one trace schema.

Pot's pipeline is always the same — a sequencer fixes the serialization
order *before* execution, then a concurrency-control engine executes the
batch deterministically.  Every engine therefore fits one signature:

    raw(store, batch, seq, lanes, n_lanes) -> (TStore, ExecTrace)

where ``seq`` is the sequencer's output (distinct 1-based sequence
numbers; only their relative order matters) and ``lanes`` / ``n_lanes``
describe the lane (thread) structure for engines that model it (the
DeSTM analog).  Engines that don't need lanes ignore them; the OCC
baseline reinterprets the sequence order as the *arrival* interleaving
(``arrival = argsort(seq)``), which is exactly the knob its
nondeterminism depends on.

Registry:

    get_engine("pcc" | "pogl" | "destm" | "occ")   ("pot" aliases "pcc")
    ENGINES — dict of every registered engine

Engines self-register at import time (``repro.core`` imports all four),
and :func:`get_engine` lazily imports a known module on first use, so
``from repro.core.engine import get_engine`` works standalone.

The canonical :class:`ExecTrace` is the superset of the old per-engine
trace dataclasses (``PccTrace`` / ``OccTrace`` / ``DestmTrace``, now
aliases of it); engine-specific fields are defaulted via
:func:`make_trace` so a single pytree schema flows through metrics,
benchmarks, and :class:`repro.core.session.PotSession`.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.tstore import TStore
from repro.core.txn import TxnBatch

# Transaction modes (paper §2.2.3), shared by every engine's trace.
MODE_UNSET, MODE_SPEC, MODE_PREFIX, MODE_FAST = 0, 1, 2, 3


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ExecTrace:
    """Canonical per-execution trace — the superset of every engine's
    bookkeeping, one pytree schema for all of them.

    Per-transaction arrays are indexed by *txn index* (storage order),
    not sequence position.  Fields an engine does not track are left at
    their :func:`make_trace` defaults.
    """

    commit_round: jax.Array  # (K,) int32 — engine round/wave of commit
    commit_pos: jax.Array    # (K,) int32 — global commit position (0-based)
    first_round: jax.Array   # (K,) int32 — round of first speculative exec
    retries: jax.Array       # (K,) int32 — re-executions (aborts)
    mode: jax.Array          # (K,) int32 — MODE_FAST / MODE_PREFIX / MODE_SPEC
    wait_rounds: jax.Array   # (K,) int32 — rounds executed-but-waiting
    rounds: jax.Array        # ()   int32 — total engine rounds (OCC: waves)
    exec_ops: jax.Array      # ()   int32 — instruction slots incl. retries
    validation_words: jax.Array  # () int32 — read-set words validated
    promotions: jax.Array    # ()   int32 — live promotions (§2.2.3, PCC)
    barrier_ops: jax.Array   # ()   int32 — barrier idle slots (DeSTM)
    wave_trips: jax.Array    # ()   int32 — Σ wave_commit fixpoint trips (OCC)
    live_txns: jax.Array     # ()   int32 — Σ rounds re-executed (live) txns
    live_slots: jax.Array    # ()   int32 — Σ rounds live instruction slots
    walked_slots: jax.Array  # ()   int32 — Σ rounds executor width × L: the
    #   instruction slots the read phase actually WALKS on device (static
    #   shapes) — K·L per masked round, C·L per compact round.  The
    #   observable behind the gather-compacted path: live_slots is the
    #   useful work, walked_slots the device work paying for it.
    live_per_round: jax.Array  # (R,) int32 — live count per round, -1 pad
    #   (R = the engine's static round limit; entries past `rounds` stay
    #    -1.  Engines predating the RoundState loop leave it empty.)
    # -- DeSTM retry-wave observables (PR 10).  The wave-speculative
    #    retry walk is bitwise-identical to the serial token walk in
    #    every OTHER field; the whole win shows up here: retry_waves ≤
    #    retry events (= Σ retries for DeSTM), with equality exactly on
    #    fully serial conflict chains.  The serial walk records its
    #    event count, so the two modes are directly comparable.
    retry_waves: jax.Array     # () int32 — Σ token-walk trips that
    #   re-executed ≥ 1 round member (serial walk: = retry events)
    waves_per_round: jax.Array  # (R,) int32 — retry waves per round, -1
    #   pad (same static limit as live_per_round; empty when untracked)
    # -- cross-batch speculation observables (PR 7).  Zero on the serial
    #    path; every OTHER field is bit-identical between a pipelined and
    #    a serial run of the same stream (the pipelining invariant) — the
    #    speculation cost shows up ONLY here.
    spec_executed: jax.Array     # () int32 — rows executed against the
    #   pre-state snapshot before this batch's turn (the overlap work)
    spec_invalidated: jax.Array  # () int32 — speculated rows whose read
    #   set hit a post-snapshot write and were re-executed
    spec_rounds: jax.Array       # () int32 — revalidation re-execution
    #   passes (0 when the whole speculation survived)

    @property
    def n_txns(self) -> int:
        return self.commit_round.shape[0]

    @property
    def waves(self) -> jax.Array:
        """OCC-era name for :attr:`rounds` (kept for compatibility)."""
        return self.rounds

    def live_counts(self):
        """Per-round live (re-executed) transaction counts, trimmed to the
        rounds actually run.  Host-syncs; empty for engines that did not
        record them (legacy scans, PoGL)."""
        import numpy as np
        lpr = np.asarray(self.live_per_round)
        return lpr[:int(self.rounds)] if lpr.size else lpr

    def wave_counts(self):
        """Per-round retry-wave counts (DeSTM), trimmed to the rounds
        actually run.  Host-syncs; empty for engines that did not record
        them."""
        import numpy as np
        wpr = np.asarray(self.waves_per_round)
        return wpr[:int(self.rounds)] if wpr.size else wpr


def make_trace(k: int, **overrides) -> ExecTrace:
    """An ExecTrace with every field defaulted; engines override what
    they actually track."""
    fields = dict(
        commit_round=jnp.full((k,), -1, jnp.int32),
        commit_pos=jnp.full((k,), -1, jnp.int32),
        first_round=jnp.zeros((k,), jnp.int32),
        retries=jnp.zeros((k,), jnp.int32),
        mode=jnp.zeros((k,), jnp.int32),
        wait_rounds=jnp.zeros((k,), jnp.int32),
        rounds=jnp.zeros((), jnp.int32),
        exec_ops=jnp.zeros((), jnp.int32),
        validation_words=jnp.zeros((), jnp.int32),
        promotions=jnp.zeros((), jnp.int32),
        barrier_ops=jnp.zeros((), jnp.int32),
        wave_trips=jnp.zeros((), jnp.int32),
        live_txns=jnp.zeros((), jnp.int32),
        live_slots=jnp.zeros((), jnp.int32),
        walked_slots=jnp.zeros((), jnp.int32),
        live_per_round=jnp.zeros((0,), jnp.int32),
        retry_waves=jnp.zeros((), jnp.int32),
        waves_per_round=jnp.zeros((0,), jnp.int32),
        spec_executed=jnp.zeros((), jnp.int32),
        spec_invalidated=jnp.zeros((), jnp.int32),
        spec_rounds=jnp.zeros((), jnp.int32),
    )
    fields.update(overrides)
    return ExecTrace(**fields)


def rank_from_order(order: jax.Array) -> jax.Array:
    """Inverse permutation: rank[order[p]] = p.

    Engines already compute ``order = argsort(seq)``; the rank is its
    inverse, recovered with ONE scatter instead of a second argsort —
    reuse this instead of re-deriving the rank from ``seq``.
    """
    k = order.shape[0]
    return jnp.zeros((k,), jnp.int32).at[order].set(
        jnp.arange(k, dtype=jnp.int32))


def seq_rank(seq: jax.Array) -> jax.Array:
    """(K,) sequence numbers -> (K,) 0-based rank of each txn in the
    serialization order (= commit position for order-preserving engines).
    One argsort + an inverse-permutation scatter (O(K log K) + O(K)); the
    old double argsort sorted twice."""
    return rank_from_order(jnp.argsort(seq))


@runtime_checkable
class Engine(Protocol):
    """What PotSession / benchmarks need from an engine."""

    name: str

    def execute(self, store: TStore, batch: TxnBatch, seq, *,
                lanes=None, n_lanes: int = 1) -> tuple[TStore, ExecTrace]:
        ...


@dataclasses.dataclass(frozen=True)
class EngineDef:
    """A registered engine: a raw (un-jitted) uniform-signature function
    plus a cached jitted entry point.

    ``raw(store, batch, seq, lanes, n_lanes)`` must be jit-compatible
    with ``n_lanes`` static; :class:`~repro.core.session.PotSession`
    re-jits it with donated store buffers.

    ``raw_spec(store, batch, seq, lanes, n_lanes, seed)`` is the
    seeded twin behind cross-batch speculative pipelining: ``seed`` is
    a :class:`~repro.core.protocol.SpecSeed` (footprints + results of a
    speculative execution against an earlier store snapshot); the
    engine validates it against the current store, re-executes only
    the invalidated rows, and must produce a store and trace
    bit-identical to ``raw`` on the same inputs (only the ``spec_*``
    trace fields differ from zero).  All four registry engines ship
    one (pcc/occ since PR 7, destm/pogl since PR 10); ``None`` is
    still allowed for out-of-registry engines — ``PotSession`` then
    falls back to the (bit-identical) serial step.
    """

    name: str
    raw: Callable[[TStore, TxnBatch, jax.Array, jax.Array, int],
                  tuple[TStore, ExecTrace]]
    doc: str = ""
    raw_spec: Callable | None = None

    def __post_init__(self):
        object.__setattr__(
            self, "_jit", jax.jit(self.raw, static_argnums=(4,)))

    def execute(self, store: TStore, batch: TxnBatch, seq, *,
                lanes=None, n_lanes: int = 1) -> tuple[TStore, ExecTrace]:
        if lanes is None:
            lanes = jnp.zeros((batch.n_txns,), jnp.int32)
        return self._jit(store, batch, jnp.asarray(seq, jnp.int32),
                         jnp.asarray(lanes, jnp.int32), n_lanes)


ENGINES: dict[str, EngineDef] = {}

_ALIASES = {"pot": "pcc"}
# module that registers each engine (for lazy standalone imports)
_ENGINE_MODULES = {
    "pcc": "repro.core.pcc",
    "pogl": "repro.core.pogl",
    "destm": "repro.core.destm",
    "occ": "repro.core.occ",
}


def register_engine(engine: EngineDef) -> EngineDef:
    ENGINES[engine.name] = engine
    return engine


def get_engine(name: str) -> EngineDef:
    """Look up an engine by name ("pot" is an alias for "pcc")."""
    key = _ALIASES.get(name, name)
    if key not in ENGINES and key in _ENGINE_MODULES:
        importlib.import_module(_ENGINE_MODULES[key])
    if key not in ENGINES:
        known = sorted(set(ENGINES) | set(_ALIASES))
        raise KeyError(f"unknown engine {name!r}; known engines: {known}")
    return ENGINES[key]
