"""Mamba2 SSD (state-space duality) block [arXiv:2405.21060].

Chunked SSD: intra-chunk terms are dense einsums (quadratic within the
chunk only); inter-chunk state propagation is a ``jax.lax.associative_
scan`` over chunks — log-depth, fully visible to cost analysis, and the
decode path is an O(1) per-token state update (this is what makes the
``long_500k`` cell sub-quadratic).

Heads are sharded over the "model" axis (B/C projections are ngroups=1,
replicated); sequence stays unsharded inside the mixer (the recurrence is
sequential in S) — activations re-shard at block boundaries.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.blocks import C, _cast, rmsnorm
from repro.models.config import ModelConfig
from repro.runtime.shardings import Profile, cons
from jax.sharding import PartitionSpec as P


def init_mamba(key, cfg: ModelConfig):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.conv_width
    ks = jax.random.split(key, 6)
    std = d ** -0.5
    return {
        # fused in-projection: [z, x, B, C, dt]
        "w_in": jax.random.normal(
            ks[0], (d, 2 * di + 2 * n + h), jnp.float32) * std,
        "conv": jax.random.normal(
            ks[1], (w, di + 2 * n), jnp.float32) * 0.1,
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": jnp.ones((di,), jnp.float32),
        "w_out": jax.random.normal(ks[2], (di, d), jnp.float32) * std,
    }


def mamba_specs(cfg: ModelConfig, prof: Profile):
    return {
        "w_in": prof.w_in(), "conv": prof.vector(),
        "a_log": prof.vector(), "dt_bias": prof.vector(),
        "d_skip": prof.vector(), "norm": prof.bias_ff(),
        "w_out": prof.w_out(),
    }


def _split_proj(p, x, cfg):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = x @ p["w_in"]
    z = zxbcdt[..., :di]
    xin = zxbcdt[..., di:2 * di]
    b = zxbcdt[..., 2 * di:2 * di + n]
    c = zxbcdt[..., 2 * di + n:2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n:]
    return z, xin, b, c, dt


def _causal_conv(seq, weight):
    """Depthwise causal conv: seq (B, S, Ch), weight (W, Ch)."""
    w = weight.shape[0]
    pad = jnp.pad(seq, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(seq, dtype=jnp.float32)
    for i in range(w):
        out = out + pad[:, i:i + seq.shape[1]].astype(jnp.float32) \
            * weight[i].astype(jnp.float32)
    return out.astype(seq.dtype)


def mamba_apply(p, x, cfg: ModelConfig, prof: Profile, *,
                return_state=False):
    """Full-sequence SSD. x (B, S, D) -> (B, S, D).
    return_state: also return the decode cache {state, conv} after S."""
    p = _cast(p)
    bsz, s_orig, _ = x.shape
    di, n, h, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    q = min(cfg.ssm_chunk, s_orig)
    pad = (-s_orig) % q
    s = s_orig + pad
    nc = s // q

    z, xin, b, c, dt = _split_proj(p, x, cfg)
    conv_in = jnp.concatenate([xin, b, c], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv"]))
    xin, b, c = (conv_out[..., :di], conv_out[..., di:di + n],
                 conv_out[..., di + n:])

    a = -jnp.exp(p["a_log"].astype(jnp.float32))             # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(
        jnp.float32))                                        # (B,S,H)
    if pad:
        # dt=0 on padded rows: decay=1, contribution=0 -> padding is
        # invisible to both outputs and the final state.
        padw = ((0, 0), (0, pad), (0, 0))
        valid = jnp.arange(s) < s_orig
        dt = jnp.where(valid[None, :, None], jnp.pad(dt, padw), 0.0)
        xin = jnp.pad(xin, padw)
        b = jnp.pad(b, padw)
        c = jnp.pad(c, padw)
        z = jnp.pad(z, padw)
    da = dt * a                                              # <= 0

    xh = cons(xin.reshape(bsz, nc, q, h, hp), P(prof.da, None, None,
                                                prof.ma, None), prof)
    bc = b.reshape(bsz, nc, q, n).astype(jnp.float32)
    cc = c.reshape(bsz, nc, q, n).astype(jnp.float32)
    dac = da.reshape(bsz, nc, q, h)
    dtc = dt.reshape(bsz, nc, q, h)

    cums = jnp.cumsum(dac, axis=2)                           # (B,NC,Q,H)
    # intra-chunk: Y[i] = sum_{j<=i} (C_i . B_j) exp(cum_i - cum_j) dt_j x_j
    # mask BEFORE exp: exp of masked (i<j) entries overflows and poisons
    # the backward pass via inf * 0.
    diff = cums[:, :, :, None] - cums[:, :, None]            # (B,NC,Q,Q,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    diff = jnp.where(tri[None, None, :, :, None], diff, -1e9)
    decay = jnp.exp(diff)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)           # (B,NC,Q,Q)
    y_intra = jnp.einsum("bcij,bcijh,bcjh,bcjhp->bcihp",
                         scores.astype(C), decay.astype(C),
                         dtc.astype(C), xh)

    # inter-chunk: associative scan of (decay_c, S_c)
    to_end = jnp.exp(cums[:, :, -1:, :] - cums)              # (B,NC,Q,H)
    s_c = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bc.astype(C),
                     (dtc * to_end).astype(C), xh)           # (B,NC,H,P,N)
    chunk_decay = jnp.exp(cums[:, :, -1, :])                 # (B,NC,H)

    def combine(lhs, rhs):
        d1, s1 = lhs
        d2, s2 = rhs
        return d1 * d2, s1 * d2[..., None, None].astype(C) + s2

    decays, states = jax.lax.associative_scan(
        combine, (chunk_decay, s_c), axis=1)
    # incoming state for chunk c = state after chunk c-1
    state_in = jnp.concatenate(
        [jnp.zeros_like(states[:, :1]), states[:, :-1]], axis=1)
    y_inter = jnp.einsum("bcin,bchpn->bcihp", cc.astype(C), state_in) \
        * jnp.exp(cums)[..., None].astype(C)

    y = (y_intra + y_inter).reshape(bsz, s, h, hp)
    y = y + (p["d_skip"].astype(C)[None, None, :, None]
             * xin.reshape(bsz, s, h, hp))
    y = y.reshape(bsz, s, di) * jax.nn.silu(z.astype(jnp.float32)).astype(C)
    y = y[:, :s_orig]
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    out = y @ p["w_out"]
    if return_state:
        final = {"state": states[:, -1].astype(jnp.float32),
                 "conv": conv_in[:, s_orig - (cfg.conv_width - 1):].astype(
                     jnp.float32)}
        return out, final
    return out


def mamba_init_cache(cfg: ModelConfig, batch, dtype=jnp.float32):
    di, n, h, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    return {
        "state": jnp.zeros((batch, h, hp, n), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * n), dtype),
    }


def mamba_decode(p, x, cache, cfg: ModelConfig, prof: Profile):
    """One-token step. x (B, 1, D); cache {state (B,H,P,N), conv}."""
    p = _cast(p)
    bsz = x.shape[0]
    di, n, h, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xin, b, c, dt = _split_proj(p, x, cfg)
    conv_in = jnp.concatenate([xin, b, c], axis=-1)          # (B,1,Ch)
    window = jnp.concatenate(
        [cache["conv"].astype(conv_in.dtype), conv_in], axis=1)  # (B,W,Ch)
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                   p["conv"].astype(jnp.float32)))[:, None].astype(C)
    new_conv = window[:, 1:]
    xin, b, c = (conv_out[..., :di], conv_out[..., di:di + n],
                 conv_out[..., di + n:])

    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,H)
    da = jnp.exp(dt * a)                                       # (B,H)
    xh = xin.reshape(bsz, h, hp)
    state = cache["state"].astype(jnp.float32)
    state = state * da[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh.astype(jnp.float32),
        b[:, 0].astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", c[:, 0].astype(jnp.float32), state)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(bsz, 1, di).astype(C) \
        * jax.nn.silu(z.astype(jnp.float32)).astype(C)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    out = y @ p["w_out"]
    return out, {"state": state.astype(cache["state"].dtype),
                 "conv": new_conv.astype(cache["conv"].dtype)}
