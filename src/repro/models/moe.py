"""Mixture-of-Experts block (arctic-480b: 128e top-2 + dense residual;
deepseek-moe-16b: 64e top-6 + 2 shared experts, fine-grained).

Two dispatch paths:

- **shard_map path** (prof.mesh set — dry-run / production): the GShard
  schedule written explicitly.  Each (data × seq-over-model) shard routes
  its own tokens locally, scatters into per-expert capacity slots, and a
  real ``lax.all_to_all`` over the model axis exchanges expert blocks
  (EP).  Expert weights are FSDP-stored over data and ZeRO-gathered at
  use (backward of the gather = the grad reduce-scatter); each chip then
  runs its E_loc experts at full width — correct for any token layout
  (an F-Megatron split over data would psum partials from different
  token sets).  GSPMD cannot be trusted to derive this schedule from a
  scatter (it replicates the token stream); writing it with explicit
  collectives is both faster and gives the roofline true all-to-all
  byte counts.  Numeric equivalence vs. the dense path is tested on a
  real multi-device mesh in tests/test_moe_shardmap.py.
- **dense path** (no mesh — CPU smoke tests): same math, local scatter.

Dropping: per-shard capacity = ceil(tokens·k/E · capacity_factor), the
standard GShard bound (documented in DESIGN.md §6).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.blocks import C, _cast, init_mlp, mlp_apply, mlp_specs
from repro.models.config import ModelConfig
from repro.runtime.shardings import Profile, cons


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    std = d ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * std,
        "w1": jax.random.normal(ks[1], (e, d, f), jnp.float32) * std,
        "w3": jax.random.normal(ks[2], (e, d, f), jnp.float32) * std,
        "w2": jax.random.normal(ks[3], (e, f, d), jnp.float32) * std,
    }
    if cfg.n_shared_experts:
        sub = jax.random.split(ks[4], 2)[1]
        p["shared"] = init_mlp(sub, cfg, d_ff=cfg.n_shared_experts * f)
    if cfg.dense_residual:
        p["residual"] = init_mlp(ks[4], cfg, d_ff=cfg.residual_d_ff)
    return p


def moe_specs(cfg: ModelConfig, prof: Profile):
    p = {"router": P(None, None),
         "w1": prof.experts_in(), "w3": prof.experts_in(),
         "w2": prof.experts_out()}
    if cfg.n_shared_experts:
        p["shared"] = mlp_specs(cfg, prof)
    if cfg.dense_residual:
        p["residual"] = mlp_specs(cfg, prof)
    return p


def _route_and_dispatch(xt, router, e, k, cf):
    """Local routing: xt (T, D) -> (x_e (E, C, D), eidx, pos, keep, gate)."""
    t, d = xt.shape
    logits = (xt @ router.astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                    # (T, k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    cap = max(1, int(t * k / e * cf))
    flat_e = eidx.reshape(-1)                               # (T*k,)
    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos = ((jnp.cumsum(oh, axis=0) - 1) * oh).sum(-1)       # (T*k,)
    keep = pos < cap
    src = jnp.repeat(xt, k, axis=0)                         # (T*k, D)
    w8 = jnp.where(keep, 1.0, 0.0).astype(xt.dtype)[:, None]
    x_e = jnp.zeros((e, cap, d), xt.dtype)
    x_e = x_e.at[flat_e, jnp.where(keep, pos, 0)].add(src * w8,
                                                      mode="drop")
    return x_e, flat_e, pos, keep, gate


def _combine(y_e, flat_e, pos, keep, gate, t, k, d):
    gath = y_e[flat_e, jnp.where(keep, pos, 0)]             # (T*k, D)
    gath = gath * jnp.where(keep, 1.0, 0.0).astype(y_e.dtype)[:, None]
    gath = gath * gate.reshape(-1)[:, None].astype(y_e.dtype)
    return gath.reshape(t, k, d).sum(axis=1)


def _expert_ffn(x_e, w1, w3, w2):
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_e, w1)) \
        * jnp.einsum("ecd,edf->ecf", x_e, w3)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def moe_apply(p, x, cfg: ModelConfig, prof: Profile):
    """x (B, S, D) -> (B, S, D)."""
    p = _cast(p)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k

    if prof.enabled and prof.mesh is not None:
        routed = _moe_shardmap(p, x, cfg, prof)
    else:
        xt = x.reshape(b * s, d)
        x_e, flat_e, pos, keep, gate = _route_and_dispatch(
            xt, p["router"], e, k, cfg.capacity_factor)
        y_e = _expert_ffn(x_e, p["w1"], p["w3"], p["w2"])
        routed = _combine(y_e, flat_e, pos, keep, gate, b * s, k,
                          d).reshape(b, s, d)

    out = routed
    if "shared" in p:
        out = out + mlp_apply(p["shared"], x, cfg, prof)
    if "residual" in p:
        out = out + mlp_apply(p["residual"], x, cfg, prof)
    return out


def _moe_shardmap(p, x, cfg: ModelConfig, prof: Profile):
    """Explicit GShard schedule (see module docstring)."""
    from jax.experimental.shard_map import shard_map

    e, k = cfg.n_experts, cfg.top_k
    da, ma = prof.da, prof.ma
    mesh = prof.mesh

    def local(xl, router, w1, w3, w2):
        # xl (B_loc, S_loc, D) — tokens local to this (data, model) shard
        bl, sl, d = xl.shape
        xt = xl.reshape(bl * sl, d)
        x_e, flat_e, pos, keep, gate = _route_and_dispatch(
            xt, router, e, k, cfg.capacity_factor)
        # EP: exchange expert blocks over the model axis
        x_e = jax.lax.all_to_all(x_e, prof.model_axis, split_axis=0,
                                 concat_axis=1, tiled=True)
        # ZeRO: gather this chip's E_loc experts' weights over data (FSDP
        # storage); the backward of the gather is the grad reduce-scatter.
        # (An F-Megatron split over data would psum partials computed
        # from DIFFERENT data rows' tokens — incorrect in this layout.)
        ax = (prof.data_axes if len(prof.data_axes) > 1
              else prof.data_axes[0])
        w1 = jax.lax.all_gather(w1, ax, axis=1, tiled=True)
        w3 = jax.lax.all_gather(w3, ax, axis=1, tiled=True)
        w2 = jax.lax.all_gather(w2, ax, axis=1, tiled=True)
        y_e = _expert_ffn(x_e, w1.astype(C), w3.astype(C), w2.astype(C))
        y_e = jax.lax.all_to_all(y_e, prof.model_axis, split_axis=1,
                                 concat_axis=0, tiled=True)
        y = _combine(y_e, flat_e, pos, keep, gate, bl * sl, k, d)
        return y.reshape(bl, sl, d)

    fs = prof._fs(0)
    ep = prof.model_axis   # experts always EP over the model axis
    w_in_spec = (P(ep, fs, None), P(ep, fs, None), P(ep, fs, None))
    # tokens enter sequence-sharded over the model axis (when divisible):
    # every chip routes DISTINCT tokens and the all-to-all carries unique
    # blocks — replicating over model would do n_model× redundant
    # dispatch/compute.
    n_ma = mesh.shape[prof.model_axis]
    seq_ax = ma if (ma is not None and x.shape[1] % n_ma == 0
                    and x.shape[1] >= n_ma) else None
    f = shard_map(
        local, mesh=mesh,
        in_specs=(P(da, seq_ax, None), P(None, None)) + w_in_spec,
        out_specs=P(da, seq_ax, None),
        check_rep=False)
    return f(x, p["router"], p["w1"], p["w3"], p["w2"])
