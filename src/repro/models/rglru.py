"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-gated linear recurrent unit with diagonal recurrence:
    r_t = sigmoid(x_t * w_r + b_r)          (recurrence gate)
    i_t = sigmoid(x_t * w_i + b_i)          (input gate)
    a_t = exp(c * softplus(lam) * (-r_t))   (per-channel decay in (0,1))
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Full-sequence form uses ``jax.lax.associative_scan`` (log-depth, FLOPs
visible to cost analysis); decode is an O(1) state update — the hybrid
arch's ``long_500k`` cell rides on this plus windowed local attention.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.blocks import C, _cast
from repro.models.config import ModelConfig
from repro.models.ssm import _causal_conv
from repro.runtime.shardings import Profile, cons

_C_GATE = 8.0


def init_rglru(key, cfg: ModelConfig):
    d = cfg.d_model
    w = cfg.rnn_width or d
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    return {
        "w_x": jax.random.normal(ks[0], (d, w), jnp.float32) * std,
        "w_gate": jax.random.normal(ks[1], (d, w), jnp.float32) * std,
        "conv": jax.random.normal(ks[2], (4, w), jnp.float32) * 0.1,
        "w_r": jnp.zeros((w,), jnp.float32),
        "b_r": jnp.zeros((w,), jnp.float32),
        "w_i": jnp.zeros((w,), jnp.float32),
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": jnp.full((w,), 0.5, jnp.float32),
        "w_out": jax.random.normal(ks[3], (w, d), jnp.float32) * std,
    }


def rglru_specs(cfg: ModelConfig, prof: Profile):
    return {
        "w_x": prof.w_in(), "w_gate": prof.w_in(), "conv": prof.vector(),
        "w_r": prof.bias_ff(), "b_r": prof.bias_ff(),
        "w_i": prof.bias_ff(), "b_i": prof.bias_ff(),
        "lam": prof.bias_ff(), "w_out": prof.w_out(),
    }


def _gates(p, xb):
    """xb (..., W) f32 -> (a, ix) decay and gated input."""
    r = jax.nn.sigmoid(xb * p["w_r"] + p["b_r"])
    i = jax.nn.sigmoid(xb * p["w_i"] + p["b_i"])
    log_a = -_C_GATE * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    ix = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * xb)
    return a, ix


def rglru_apply(p, x, cfg: ModelConfig, prof: Profile, *,
                return_state=False):
    """Full sequence. x (B, S, D) -> (B, S, D)."""
    p = _cast(p)
    xb_raw = x @ p["w_x"]
    xb_raw = cons(xb_raw, prof.act_btf(), prof)
    xb = _causal_conv(xb_raw, p["conv"]).astype(jnp.float32)
    gate = jax.nn.gelu(
        (x @ p["w_gate"]).astype(jnp.float32))               # (B,S,W)
    a, ix = _gates(jax.tree.map(lambda v: v.astype(jnp.float32), p), xb)

    def combine(lhs, rhs):
        a1, h1 = lhs
        a2, h2 = rhs
        return a1 * a2, h1 * a2 + h2

    _, h = jax.lax.associative_scan(combine, (a, ix), axis=1)
    out = (h * gate).astype(C)
    out = out @ p["w_out"]
    if return_state:
        final = {"state": h[:, -1],
                 "conv": xb_raw[:, -3:].astype(jnp.float32)}
        return out, final
    return out


def rglru_init_cache(cfg: ModelConfig, batch, dtype=jnp.float32):
    w = cfg.rnn_width or cfg.d_model
    return {
        "state": jnp.zeros((batch, w), dtype),
        "conv": jnp.zeros((batch, 3, w), dtype),
    }


def rglru_decode(p, x, cache, cfg: ModelConfig, prof: Profile):
    """One-token step. x (B, 1, D)."""
    p = _cast(p)
    xb = x @ p["w_x"]                                        # (B,1,W)
    window = jnp.concatenate(
        [cache["conv"].astype(xb.dtype), xb], axis=1)        # (B,4,W)
    xc = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                    p["conv"].astype(jnp.float32))           # (B,W)
    gate = jax.nn.gelu((x[:, 0] @ p["w_gate"]).astype(jnp.float32))
    pf = jax.tree.map(lambda v: v.astype(jnp.float32), p)
    a, ix = _gates(pf, xc)
    h = cache["state"].astype(jnp.float32) * a + ix
    out = ((h * gate).astype(C) @ p["w_out"])[:, None]
    return out, {"state": h.astype(cache["state"].dtype),
                 "conv": window[:, 1:].astype(cache["conv"].dtype)}
