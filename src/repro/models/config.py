"""Static model configuration shared by all 10 assigned architectures."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # layer pattern: tuple of kinds, repeated to n_layers.
    # kinds: "attn" (global), "local" (sliding window), "mamba", "rglru"
    pattern: tuple = ("attn",)
    window: int = 0             # sliding window for "local" layers
    qkv_bias: bool = False
    mlp: str = "swiglu"         # swiglu | gelu | none
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    residual_d_ff: int = 0         # width of the dense-residual FFN
    capacity_factor: float = 1.25
    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    # RG-LRU (recurrentgemma)
    rnn_width: int = 0           # 0 -> d_model
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    n_frames: int = 1500         # stub audio frontend output length
    # VLM
    n_patches: int = 0           # stub vision frontend output length
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding/head tables round the vocab up to a multiple of 256
        (Megatron-style) so the vocab dim always shards evenly; labels
        never reference the padding."""
        return -(-self.vocab // 256) * 256

    @property
    def group_size(self) -> int:
        return len(self.pattern)

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail_pattern(self) -> tuple:
        """Remainder layers when n_layers % len(pattern) != 0 (e.g.
        gemma3's 62 = 10×(5 local + 1 global) + 2 local)."""
        return self.pattern[: self.n_layers % len(self.pattern)]

    @property
    def d_inner(self) -> int:    # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytical parameter count (for 6·N·D roofline)."""
        d, hd = self.d_model, self.hd
        n = 0
        n += self.vocab * d                                # embed
        if not self.tie_embeddings:
            n += self.vocab * d                            # lm head
        per_layer = {}
        for kind in set(self.pattern):
            p = 0
            if kind in ("attn", "local"):
                p += d * self.n_heads * hd                 # wq
                p += 2 * d * self.n_kv_heads * hd          # wk, wv
                p += self.n_heads * hd * d                 # wo
                if self.qkv_bias:
                    p += (self.n_heads + 2 * self.n_kv_heads) * hd
            elif kind == "mamba":
                di = self.d_inner
                p += d * (2 * di + 2 * self.ssm_state + self.ssm_heads)
                p += di * d                                # out proj
                p += self.conv_width * (di + 2 * self.ssm_state)
                p += 2 * self.ssm_heads                    # A_log, D
            elif kind == "rglru":
                w = self.rnn_width or d
                p += 2 * d * w + w * d                     # in(x2), out
                p += 2 * w                                 # gates a, input
            p += 2 * d                                     # norms
            if kind != "mamba":
                if self.n_experts:
                    p += self.n_experts * 3 * d * self.d_ff
                    p += d * self.n_experts                # router
                    if self.n_shared_experts:
                        p += self.n_shared_experts * 3 * d * self.d_ff
                    if self.dense_residual:
                        p += 3 * d * self.residual_d_ff
                elif self.mlp == "swiglu":
                    p += 3 * d * self.d_ff
                elif self.mlp == "gelu":
                    p += 2 * d * self.d_ff
            per_layer[kind] = p
        for kind in self.pattern:
            n += per_layer[kind] * self.n_groups
        for kind in self.tail_pattern:
            n += per_layer[kind]
        if self.encoder_layers:
            enc = (2 * (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd)
                   + 2 * self.d_ff * d + 4 * d)
            n += self.encoder_layers * enc
            # decoder cross-attention (already counted pattern as self-attn)
            n += self.n_layers * (d * self.n_heads * hd
                                  + 2 * d * self.n_kv_heads * hd
                                  + self.n_heads * hd * d)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k + shared experts)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        inactive = (self.n_experts - self.top_k) * 3 * self.d_model \
            * self.d_ff * self.n_layers
        return full - inactive
