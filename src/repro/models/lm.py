"""LM assembly: all 10 assigned architectures from one composable builder.

Layers are organized in *pattern groups* (e.g. gemma3 = 5 local + 1 global
per group; recurrentgemma = 2 RG-LRU + 1 local).  Parameters are stacked
per group-slot with a leading (n_groups,) dim and the trunk is a
``lax.scan`` over groups (compact HLO, fast multi-cell compiles) with
``jax.checkpoint`` for training.  ``unroll=True`` switches to a python
loop so analysis lowerings expose per-layer FLOPs (DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import blocks, moe, rglru, ssm
from repro.models.blocks import C, _cast, rmsnorm
from repro.models.config import ModelConfig
from repro.runtime.shardings import SMOKE, Profile, cons
from jax.sharding import PartitionSpec as P


# ------------------------------------------------------------------ params
def _slot_init(key, kind, cfg: ModelConfig, cross: bool):
    ks = jax.random.split(key, 4)
    p = {"ln1": jnp.ones((cfg.d_model,), jnp.float32)}
    if kind in ("attn", "local"):
        p["attn"] = blocks.init_attn(ks[0], cfg)
    elif kind == "mamba":
        p["mixer"] = ssm.init_mamba(ks[0], cfg)
    elif kind == "rglru":
        p["mixer"] = rglru.init_rglru(ks[0], cfg)
    if cross:
        p["ln_x"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["xattn"] = blocks.init_attn(ks[1], cfg, cross=True)
    if kind != "mamba" and cfg.mlp != "none":
        p["ln2"] = jnp.ones((cfg.d_model,), jnp.float32)
        if cfg.n_experts:
            p["moe"] = moe.init_moe(ks[2], cfg)
        else:
            p["mlp"] = blocks.init_mlp(ks[2], cfg)
    return p


def _slot_specs(kind, cfg: ModelConfig, prof: Profile, cross: bool):
    p = {"ln1": prof.vector()}
    if kind in ("attn", "local"):
        p["attn"] = blocks.attn_specs(cfg, prof)
    elif kind == "mamba":
        p["mixer"] = ssm.mamba_specs(cfg, prof)
    elif kind == "rglru":
        p["mixer"] = rglru.rglru_specs(cfg, prof)
    if cross:
        p["ln_x"] = prof.vector()
        p["xattn"] = blocks.attn_specs(cfg, prof, cross=True)
    if kind != "mamba" and cfg.mlp != "none":
        p["ln2"] = prof.vector()
        p["moe" if cfg.n_experts else "mlp"] = (
            moe.moe_specs(cfg, prof) if cfg.n_experts
            else blocks.mlp_specs(cfg, prof))
    return p


def init_params(key, cfg: ModelConfig, n_groups: int | None = None):
    """Stacked parameters; pass n_groups to build a truncated trunk for
    analysis lowerings."""
    g = n_groups if n_groups is not None else cfg.n_groups
    keys = jax.random.split(key, 8)
    cross = cfg.encoder_layers > 0
    params = {
        "embed": jax.random.normal(
            keys[0], (cfg.padded_vocab, cfg.d_model), jnp.float32) * 0.02,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(
            keys[1], (cfg.d_model, cfg.padded_vocab), jnp.float32) * 0.02

    def stack(fn, key, n):
        return jax.vmap(lambda k: fn(k))(jax.random.split(key, n))

    params["layers"] = {
        str(i): stack(lambda k, kind=kind: _slot_init(k, kind, cfg, cross),
                      jax.random.fold_in(keys[2], i), g)
        for i, kind in enumerate(cfg.pattern)
    }
    if cfg.tail_pattern and n_groups is None:
        params["tail"] = {
            str(i): _slot_init(jax.random.fold_in(keys[4], i), kind, cfg,
                               cross)
            for i, kind in enumerate(cfg.tail_pattern)
        }
    if cfg.encoder_layers:
        params["enc_layers"] = stack(
            lambda k: _slot_init(k, "attn", cfg, cross=False),
            keys[3], cfg.encoder_layers)
        params["enc_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    return params


def param_specs(cfg: ModelConfig, prof: Profile, include_tail: bool = True):
    cross = cfg.encoder_layers > 0

    def lead(spec_tree):  # prepend None for the stacked group dim
        return jax.tree.map(
            lambda s: P(*((None,) + tuple(s))), spec_tree,
            is_leaf=lambda s: isinstance(s, P))

    specs = {
        "embed": prof.embed(),
        "final_norm": prof.vector(),
        "layers": {
            str(i): lead(_slot_specs(kind, cfg, prof, cross))
            for i, kind in enumerate(cfg.pattern)
        },
    }
    if not cfg.tie_embeddings:
        specs["head"] = prof.head()
    if cfg.tail_pattern and include_tail:
        specs["tail"] = {
            str(i): _slot_specs(kind, cfg, prof, cross)
            for i, kind in enumerate(cfg.tail_pattern)
        }
    if cfg.encoder_layers:
        specs["enc_layers"] = lead(_slot_specs("attn", cfg, prof, False))
        specs["enc_norm"] = prof.vector()
    return specs


# ----------------------------------------------------------------- forward
def _ring_gather(k, v, window):
    """Arrange the last ``window`` rows of (B, S, KV, hd) into ring order
    (slot r holds the row whose absolute position p satisfies
    p % window == r) — the layout decode_step's local path expects."""
    s = k.shape[1]
    w = min(window, s)
    r = jnp.arange(w)
    abs_pos = (s - 1) - ((s - 1 - r) % window)
    return jnp.take(k, abs_pos, axis=1), jnp.take(v, abs_pos, axis=1)


def _sublayer(pslot, kind, x, cfg, prof, *, positions, enc=None, causal=True,
              chunk=0, unroll=False, collect=False, max_seq=0):
    new_c = None
    xg = cons(x, prof.act_gathered(), prof, barrier=True)
    h = rmsnorm(xg, pslot["ln1"], cfg.norm_eps)
    if kind in ("attn", "local"):
        out = blocks.attn_apply(pslot["attn"], h, cfg, prof, kind=kind,
                                causal=causal, positions=positions,
                                chunk=chunk, unroll=unroll,
                                return_kv=collect)
        if collect:
            h, k, v = out
            if kind == "local":
                k, v = _ring_gather(k, v, cfg.window or k.shape[1])
            elif max_seq > k.shape[1]:
                pad = max_seq - k.shape[1]
                k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            new_c = {"k": k, "v": v}
        else:
            h = out
        h = cons(h, prof.act_btd(), prof)
    elif kind == "mamba":
        out = ssm.mamba_apply(pslot["mixer"], h, cfg, prof,
                              return_state=collect)
        h, new_c = out if collect else (out, None)
    elif kind == "rglru":
        out = rglru.rglru_apply(pslot["mixer"], h, cfg, prof,
                                return_state=collect)
        h, new_c = out if collect else (out, None)
    x = x + cons(h, prof.act_btd(), prof, barrier=True)
    if "xattn" in pslot and enc is not None:
        xg = cons(x, prof.act_gathered(), prof, barrier=True)
        h = rmsnorm(xg, pslot["ln_x"], cfg.norm_eps)
        out = blocks.attn_apply(pslot["xattn"], h, cfg, prof, causal=False,
                                positions=positions, kv_src=enc,
                                use_rope=False, return_kv=collect)
        if collect:
            h, xk, xv = out
            new_c = {"self": new_c, "xk": xk, "xv": xv}
        else:
            h = out
        x = x + h
    if "mlp" in pslot or "moe" in pslot:
        xg = cons(x, prof.act_gathered(), prof, barrier=True)
        h = rmsnorm(xg, pslot["ln2"], cfg.norm_eps)
        h = (moe.moe_apply(pslot["moe"], h, cfg, prof) if "moe" in pslot
             else blocks.mlp_apply(pslot["mlp"], h, cfg, prof))
        x = x + cons(h, prof.act_btd(), prof, barrier=True)
    return cons(x, prof.act_btd(), prof), new_c


def _group_body(pgroup, x, cfg, prof, *, positions, enc, causal, chunk,
                unroll, collect=False, max_seq=0):
    caches = {}
    for i, kind in enumerate(cfg.pattern):
        x, new_c = _sublayer(pgroup[str(i)], kind, x, cfg, prof,
                             positions=positions, enc=enc, causal=causal,
                             chunk=chunk, unroll=unroll, collect=collect,
                             max_seq=max_seq)
        if collect:
            caches[str(i)] = new_c
    return x, caches


def trunk(params, x, cfg: ModelConfig, prof: Profile, *, positions,
          enc=None, causal=True, chunk=0, unroll=False, remat=False,
          layers_key="layers", collect=False, max_seq=0):
    layer_params = params[layers_key]
    n_groups = jax.tree.leaves(layer_params)[0].shape[0]

    def body(x, pgroup):
        return _group_body(pgroup, x, cfg, prof, positions=positions,
                           enc=enc, causal=causal, chunk=chunk,
                           unroll=unroll, collect=collect, max_seq=max_seq)

    if unroll:
        caches = []
        for g in range(n_groups):
            pg = jax.tree.map(lambda a: a[g], layer_params)
            x, cg = body(x, pg)
            caches.append(cg)
        if collect:
            caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    else:
        fn = jax.checkpoint(body, policy=None) if remat else body
        x, caches = jax.lax.scan(fn, x, layer_params)

    tail_caches = {}
    if layers_key == "layers" and "tail" in params:
        for i, kind in enumerate(cfg.tail_pattern):
            x, tc = _sublayer(params["tail"][str(i)], kind, x, cfg, prof,
                              positions=positions, enc=enc, causal=causal,
                              chunk=chunk, unroll=unroll, collect=collect,
                              max_seq=max_seq)
            if collect:
                tail_caches[str(i)] = tc
    if collect:
        return x, (caches, tail_caches)
    return x


def encode(params, frames, cfg: ModelConfig, prof: Profile, *, unroll=False,
           remat=False):
    """Whisper encoder over stub frame embeddings (B, F, D)."""
    b, f, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(f)[None], (b, f))
    x = cons(frames.astype(C), prof.act_btd(), prof)
    # encoder slots are plain attn layers stacked under "enc_layers"
    tmp = {"layers": {"0": params["enc_layers"]}}
    enc_cfg = dataclasses.replace(cfg, pattern=("attn",))
    x = trunk(tmp, x, enc_cfg, prof, positions=positions, causal=False,
              unroll=unroll, remat=remat)
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def forward(params, tokens, cfg: ModelConfig, prof: Profile, *,
            prefix_embeds=None, enc=None, chunk=0, unroll=False,
            remat=False):
    """tokens (B, S_t) -> logits (B, S_total, V).

    prefix_embeds: (B, Np, D) stub frontend output (vision patches),
    prepended to the token embeddings (internvl2).
    enc: (B, F, D) encoder output for cross-attention (whisper).
    """
    emb = params["embed"].astype(C)
    x = emb[tokens]                                         # (B, S_t, D)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(C), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = cons(x, prof.act_btd(), prof)
    x = trunk(params, x, cfg, prof, positions=positions, enc=enc,
              causal=True, chunk=chunk, unroll=unroll, remat=remat)
    x = rmsnorm(cons(x, prof.act_gathered(), prof),
                params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["head"]).astype(C)
    logits = x @ head
    return cons(logits, prof.act_btv(), prof)


def prefill(params, tokens, cfg: ModelConfig, prof: Profile, *,
            max_seq: int = 0, prefix_embeds=None, enc=None, chunk=0,
            unroll=False):
    """Process a full prompt; return (last-position logits, decode cache).

    max_seq: cache capacity (>= prompt length; extra slots for decoding).
    """
    emb = params["embed"].astype(C)
    x = emb[tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(C), x], axis=1)
    b, s, _ = x.shape
    max_seq = max(max_seq, s)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = cons(x, prof.act_btd(), prof)
    x, (caches, tail_caches) = trunk(
        params, x, cfg, prof, positions=positions, enc=enc, causal=True,
        chunk=chunk, unroll=unroll, collect=True, max_seq=max_seq)
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["head"]).astype(C)
    logits = x @ head

    # reshape collected caches into the init_cache layout
    cache = {}
    for i, kind in enumerate(cfg.pattern):
        slot = caches[str(i)]
        if isinstance(slot, dict) and "xk" in slot:
            cache["cross_k"] = slot["xk"]
            cache["cross_v"] = slot["xv"]
            slot = slot["self"]
        cache[str(i)] = slot
    if tail_caches:
        cache["tail"] = {
            k: (v["self"] if isinstance(v, dict) and "xk" in v else v)
            for k, v in tail_caches.items()}
    return logits, cache


# ------------------------------------------------------------------- cache
def init_cache(cfg: ModelConfig, batch: int, max_seq: int, prof: Profile,
               n_groups: int | None = None, dtype=C):
    """Decode cache: per group-slot stacked (G, ...) arrays."""
    g = n_groups if n_groups is not None else cfg.n_groups
    kv, hd = cfg.n_kv_heads, cfg.hd
    cache = {}
    for i, kind in enumerate(cfg.pattern):
        if kind == "attn":
            shape = (g, batch, max_seq, kv, hd)
            cache[str(i)] = {"k": jnp.zeros(shape, dtype),
                             "v": jnp.zeros(shape, dtype)}
        elif kind == "local":
            w = min(cfg.window or max_seq, max_seq)
            shape = (g, batch, w, kv, hd)
            cache[str(i)] = {"k": jnp.zeros(shape, dtype),
                             "v": jnp.zeros(shape, dtype)}
        elif kind == "mamba":
            one = ssm.mamba_init_cache(cfg, batch, jnp.float32)
            cache[str(i)] = jax.tree.map(
                lambda a: jnp.zeros((g,) + a.shape, a.dtype), one)
        elif kind == "rglru":
            one = rglru.rglru_init_cache(cfg, batch, jnp.float32)
            cache[str(i)] = jax.tree.map(
                lambda a: jnp.zeros((g,) + a.shape, a.dtype), one)
    if cfg.encoder_layers:
        cache["cross_k"] = jnp.zeros(
            (g, batch, cfg.n_frames, kv, hd), dtype)
        cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
    if cfg.tail_pattern and n_groups is None:
        tail = {}
        for i, kind in enumerate(cfg.tail_pattern):
            if kind in ("attn", "local"):
                s = (min(cfg.window or max_seq, max_seq)
                     if kind == "local" else max_seq)
                tail[str(i)] = {
                    "k": jnp.zeros((batch, s, kv, hd), dtype),
                    "v": jnp.zeros((batch, s, kv, hd), dtype)}
            elif kind == "mamba":
                tail[str(i)] = ssm.mamba_init_cache(cfg, batch, jnp.float32)
            elif kind == "rglru":
                tail[str(i)] = rglru.rglru_init_cache(cfg, batch,
                                                      jnp.float32)
        cache["tail"] = tail
    return cache


def cache_specs(cfg: ModelConfig, prof: Profile, model_size: int):
    """PartitionSpec tree matching init_cache."""
    kvspec = prof.cache_kv(cfg.n_kv_heads, model_size)
    full = P(*((None,) + tuple(kvspec)))
    small = P(None, prof.da)  # recurrent states: batch-sharded
    specs = {}
    for i, kind in enumerate(cfg.pattern):
        if kind in ("attn", "local"):
            specs[str(i)] = {"k": full, "v": full}
        elif kind == "mamba":
            specs[str(i)] = {"state": P(None, prof.da, prof.ma, None, None),
                             "conv": P(None, prof.da, None, None)}
        elif kind == "rglru":
            specs[str(i)] = {"state": P(None, prof.da, prof.ma),
                             "conv": P(None, prof.da, None, prof.ma)}
    if cfg.encoder_layers:
        specs["cross_k"] = full
        specs["cross_v"] = full
    if cfg.tail_pattern:
        tail = {}
        for i, kind in enumerate(cfg.tail_pattern):
            if kind in ("attn", "local"):
                tail[str(i)] = {"k": kvspec, "v": kvspec}
            elif kind == "mamba":
                tail[str(i)] = {"state": P(prof.da, prof.ma, None, None),
                                "conv": P(prof.da, None, None)}
            elif kind == "rglru":
                tail[str(i)] = {"state": P(prof.da, prof.ma),
                                "conv": P(prof.da, None, prof.ma)}
        specs["tail"] = tail
    return specs


# ------------------------------------------------------------------ decode
def _ring_mask_positions(pos, window, cache_len):
    """Absolute position held by each ring slot r: the largest p <= pos
    with p % window == r (negative -> empty)."""
    r = jnp.arange(cache_len)
    return pos[:, None] - ((pos[:, None] - r[None]) % window)


def decode_step(params, cache, tokens, pos, cfg: ModelConfig,
                prof: Profile, *, unroll=False):
    """One decode step.  tokens (B, 1) int32, pos (B,) int32 (position of
    the new token).  Returns (logits (B, 1, V), new_cache)."""
    emb = params["embed"].astype(C)
    x = emb[tokens]                                          # (B, 1, D)
    b = x.shape[0]

    def slot_step(x, pslot, kind, cslot):
        h = rmsnorm(x, pslot["ln1"], cfg.norm_eps)
        if kind == "attn":
            h, nk, nv = blocks.attn_decode(
                pslot["attn"], h, cslot["k"], cslot["v"], pos, cfg, prof,
                kind=kind)
            new_c = {"k": nk, "v": nv}
        elif kind == "local":
            w = cslot["k"].shape[1]
            slot_ids = pos % w
            pc = _cast(pslot["attn"])
            q = (h @ pc["wq"])
            if "bq" in pc:
                q = q + pc["bq"]
            q = q.reshape(b, 1, cfg.n_kv_heads,
                          cfg.n_heads // cfg.n_kv_heads, cfg.hd)
            sin, cos = blocks.rope_tables(pos[:, None], cfg.hd,
                                          cfg.rope_theta)
            q = blocks.apply_rope(q, sin, cos)
            knew = (h @ pc["wk"])
            vnew = (h @ pc["wv"])
            if "bk" in pc:
                knew, vnew = knew + pc["bk"], vnew + pc["bv"]
            knew = blocks.apply_rope(
                knew.reshape(b, 1, cfg.n_kv_heads, cfg.hd), sin, cos)
            vnew = vnew.reshape(b, 1, cfg.n_kv_heads, cfg.hd)
            idx_b = jnp.arange(b)
            nk = cslot["k"].at[idx_b, slot_ids].set(
                knew[:, 0].astype(cslot["k"].dtype))
            nv = cslot["v"].at[idx_b, slot_ids].set(
                vnew[:, 0].astype(cslot["v"].dtype))
            abs_pos = _ring_mask_positions(pos, cfg.window, w)
            mask = (abs_pos >= 0) & (abs_pos <= pos[:, None]) \
                & (abs_pos > (pos[:, None] - cfg.window))
            out = blocks._sdpa(q, nk.astype(C), nv.astype(C), mask[:, None])
            h = out.reshape(b, 1, cfg.n_heads * cfg.hd) @ pc["wo"]
            new_c = {"k": nk, "v": nv}
        elif kind == "mamba":
            h, new_c = ssm.mamba_decode(pslot["mixer"], h, cslot, cfg, prof)
        elif kind == "rglru":
            h, new_c = rglru.rglru_decode(pslot["mixer"], h, cslot, cfg,
                                          prof)
        x = x + h
        if "xattn" in pslot:
            h = rmsnorm(x, pslot["ln_x"], cfg.norm_eps)
            out, _, _ = blocks.attn_decode(
                pslot["xattn"], h, cslot["xk"], cslot["xv"], pos, cfg,
                prof, cross=True, use_rope=False)
            x = x + out
        if "mlp" in pslot or "moe" in pslot:
            h = rmsnorm(x, pslot["ln2"], cfg.norm_eps)
            h = (moe.moe_apply(pslot["moe"], h, cfg, prof)
                 if "moe" in pslot else
                 blocks.mlp_apply(pslot["mlp"], h, cfg, prof))
            x = x + h
        return x, new_c

    def group_body(x, pgroup_and_cgroup):
        pgroup, cgroup = pgroup_and_cgroup
        new_cgroup = {}
        for i, kind in enumerate(cfg.pattern):
            cslot = dict(cgroup[str(i)])
            if cfg.encoder_layers:
                cslot["xk"] = cgroup["cross_k"]
                cslot["xv"] = cgroup["cross_v"]
            x, new_c = slot_step(x, pgroup[str(i)], kind, cslot)
            new_cgroup[str(i)] = new_c
        if cfg.encoder_layers:
            new_cgroup["cross_k"] = cgroup["cross_k"]
            new_cgroup["cross_v"] = cgroup["cross_v"]
        return x, new_cgroup

    layer_cache = {k: v for k, v in cache.items() if k != "tail"}
    n_groups = jax.tree.leaves(params["layers"])[0].shape[0]
    if unroll:
        new_cache = {}
        for g in range(n_groups):
            pg = jax.tree.map(lambda a: a[g], params["layers"])
            cg = jax.tree.map(lambda a: a[g], layer_cache)
            x, ncg = group_body(x, (pg, cg))
            new_cache[g] = ncg
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs),
                                 *[new_cache[g] for g in range(n_groups)])
    else:
        x, new_cache = jax.lax.scan(group_body, x,
                                    (params["layers"], layer_cache))
    if "tail" in cache:
        new_tail = {}
        for i, kind in enumerate(cfg.tail_pattern):
            x, nc = slot_step(x, params["tail"][str(i)], kind,
                              cache["tail"][str(i)])
            new_tail[str(i)] = nc
        new_cache["tail"] = new_tail
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["head"]).astype(C)
    logits = x @ head
    return logits, new_cache
