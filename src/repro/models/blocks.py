"""Layer primitives shared by all assigned architectures.

Conventions:
- parameters are stored f32, cast to bf16 at use; softmax / norms / gates
  accumulate in f32.
- every apply function takes ``unroll``: when True, inner sequence loops
  (q-chunk attention, SSD chunk scan) run as python loops instead of
  ``lax.scan`` so the analysis lowerings expose their full FLOP count to
  ``cost_analysis()`` (which counts while-loop bodies only once — see
  DESIGN.md §7); the full-depth compiles use scans for compact HLO.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.runtime.shardings import Profile, cons

C = jnp.bfloat16  # compute dtype


def _cast(p):
    return jax.tree.map(lambda a: a.astype(C) if a.dtype == jnp.float32 else a, p)


# --------------------------------------------------------------- norms/rope
def rmsnorm(x, scale, eps):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)).astype(C) * scale.astype(C)


def rope_tables(positions, head_dim, theta):
    """positions (...,) int32 -> (…, head_dim/2) sin/cos tables."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (np.arange(0, half) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x (B, S, ..., hd); sin/cos (B, S, hd/2) broadcast over head axes."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    while sin.ndim < x.ndim:
        sin, cos = sin[..., None, :], cos[..., None, :]
    sin, cos = sin.astype(jnp.float32), cos.astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------- attention
NEG = -1e30


def _repeat_kv(k, g):
    """(B, S, KV, hd) -> (B, S, KV*g, hd): expand grouped KV to full heads
    for the train/prefill paths so scores shard cleanly over a flat head
    dim (decode keeps the grouped form — its footprint is tiny)."""
    if g == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None], (b, s, kv, g, hd)).reshape(
        b, s, kv * g, hd)


def _sdpa_flat(q, k, v, mask, prof):
    """q (B,Q,H,hd), k/v (B,S,H,hd), mask (B,Q,S) or (Q,S) bool.
    Scores are explicitly head-sharded over the model axis (TP)."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = cons(scores, jax.sharding.PartitionSpec(
        prof.da, prof.ma, None, None), prof)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None], scores, NEG)   # (B,1,Q,S) broadcast
    probs = jax.nn.softmax(scores, axis=-1).astype(C)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v)
    return cons(out, jax.sharding.PartitionSpec(
        prof.da, None, prof.ma, None), prof)


def _sdpa(q, k, v, mask):
    """Grouped decode attention: q (B,Q,KV,G,hd), k/v (B,S,KV,hd),
    mask (B,Q,S) bool."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    if mask.ndim == 2:
        mask = mask[None, None, None]
    else:
        mask = mask[:, None, None]  # (B,1,1,Q,S)
    scores = jnp.where(mask, scores, NEG)
    probs = jax.nn.softmax(scores, axis=-1).astype(C)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)


def attend_full(q, k, v, q_pos, kv_pos, prof, *, causal=True, window=0,
                chunk=0, unroll=False):
    """Exact attention; q (B,Q,H,hd) vs k/v (B,S,H,hd) (kv pre-repeated).

    q_pos (B, Q) / kv_pos (B, S) absolute positions for masking.
    chunk>0: iterate over q chunks (bounded memory); window>0: each query
    attends to keys in (pos-window, pos].
    """
    def mask_for(qp, kp):
        m = kp[:, None, :] <= qp[:, :, None] if causal else \
            jnp.ones((qp.shape[0], qp.shape[1], kp.shape[1]), bool)
        if window:
            m &= kp[:, None, :] > (qp[:, :, None] - window)
        return m

    if not chunk or q.shape[1] <= chunk:
        return _sdpa_flat(q, k, v, mask_for(q_pos, kv_pos), prof)

    nq = q.shape[1] // chunk
    assert q.shape[1] % chunk == 0

    def one(i):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i * chunk, chunk, 1)
        return _sdpa_flat(sl(q), k, v, mask_for(sl(q_pos), kv_pos), prof)

    if unroll:
        outs = [one(i) for i in range(nq)]
        return jnp.concatenate(outs, axis=1)
    outs = jax.lax.map(one, jnp.arange(nq))          # (nq, B, chunk, ...)
    return jnp.moveaxis(outs, 0, 1).reshape(q.shape)


def attend_window_banded(q, k, v, prof, *, window):
    """Sub-quadratic sliding-window attention (training/prefill):
    chunk the sequence by ``window``; each q chunk attends to (prev, self)
    kv chunks with an in-band causal mask.  FLOPs = 2·S·window per head
    pair instead of S² (local layers of gemma3 / recurrentgemma).
    q/k/v (B, S, H, hd) flat-head."""
    b, s, h, hd = q.shape
    w = window
    assert s % w == 0, (s, w)
    nc = s // w
    qc = q.reshape(b, nc, w, h, hd)
    kc = k.reshape(b, nc, w, h, hd)
    vc = v.reshape(b, nc, w, h, hd)
    # previous chunk (zero for the first)
    kp = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    vp = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    k2 = jnp.concatenate([kp, kc], axis=2)           # (b, nc, 2w, h, hd)
    v2 = jnp.concatenate([vp, vc], axis=2)
    scale = hd ** -0.5
    scores = jnp.einsum("bnqhd,bnshd->bnhqs", qc, k2,
                        preferred_element_type=jnp.float32) * scale
    scores = cons(scores, jax.sharding.PartitionSpec(
        prof.da, None, prof.ma, None, None), prof)
    qpos = jnp.arange(w)[:, None] + w                # within 2w frame
    kpos = jnp.arange(2 * w)[None, :]
    m = (kpos <= qpos) & (kpos > qpos - w)
    first = jnp.arange(nc) == 0                      # first chunk: no prev
    m_first = m & (kpos >= w)
    mask = jnp.where(first[:, None, None], m_first[None], m[None])
    scores = jnp.where(mask[None, :, None], scores, NEG)
    probs = jax.nn.softmax(scores, axis=-1).astype(C)
    out = jnp.einsum("bnhqs,bnshd->bnqhd", probs, v2)
    return out.reshape(b, s, h, hd)


def init_attn(key, cfg: ModelConfig, cross=False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, h * hd), jnp.float32) * std,
        "wk": jax.random.normal(ks[1], (d, kv * hd), jnp.float32) * std,
        "wv": jax.random.normal(ks[2], (d, kv * hd), jnp.float32) * std,
        "wo": jax.random.normal(ks[3], (h * hd, d), jnp.float32) * std,
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((kv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((kv * hd,), jnp.float32)
    return p


def attn_specs(cfg: ModelConfig, prof: Profile, cross=False):
    p = {"wq": prof.w_in(), "wk": prof.w_in(), "wv": prof.w_in(),
         "wo": prof.w_out()}
    if cfg.qkv_bias and not cross:
        p.update(bq=prof.bias_ff(), bk=prof.bias_ff(), bv=prof.bias_ff())
    return p


def attn_apply(p, x, cfg: ModelConfig, prof: Profile, *, kind="attn",
               causal=True, positions=None, kv_src=None, kv_positions=None,
               chunk=0, unroll=False, use_rope=True, return_kv=False):
    """Full-sequence attention (train / prefill).  kv_src: cross-attention
    source (B, S_kv, D); defaults to x (self-attention).
    return_kv: also return (k, v) post-RoPE — the decode cache rows."""
    p = _cast(p)
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    src = x if kv_src is None else kv_src
    q = x @ p["wq"]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = cons(q, prof.act_bthd(), prof).reshape(b, s, h, hd)
    k = k.reshape(b, src.shape[1], kv, hd)
    v = v.reshape(b, src.shape[1], kv, hd)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if kv_positions is None:
        kv_positions = positions if kv_src is None else jnp.broadcast_to(
            jnp.arange(src.shape[1])[None], (b, src.shape[1]))
    if use_rope:
        sin_q, cos_q = rope_tables(positions, hd, cfg.rope_theta)
        q = apply_rope(q, sin_q, cos_q)
        sin_k, cos_k = rope_tables(kv_positions, hd, cfg.rope_theta)
        k = apply_rope(k, sin_k, cos_k)
    k_rep = _repeat_kv(k, h // kv)
    v_rep = _repeat_kv(v, h // kv)
    if (kind == "local" and causal and cfg.window and s > cfg.window
            and s % cfg.window == 0):
        out = attend_window_banded(q, k_rep, v_rep, prof, window=cfg.window)
    else:
        win = cfg.window if kind == "local" else 0
        out = attend_full(q, k_rep, v_rep, positions, kv_positions, prof,
                          causal=causal, window=win, chunk=chunk,
                          unroll=unroll)
    out = out.reshape(b, s, h * hd)
    out = out @ p["wo"]
    if return_kv:
        return out, k, v
    return out


def _decode_attend_chunked(q, cache_k, cache_v, mask, chunk=2048):
    """Online-softmax decode attention over a long cache, one chunk at a
    time — the bf16 upcast of a quantized/large cache never materializes
    more than ``chunk`` positions (flash-decoding structure).

    q (B,1,KV,G,hd); cache (B,S,KV,hd) any dtype; mask (B,S) bool."""
    b, _, kv, g, hd = q.shape
    smax = cache_k.shape[1]
    nch = -(-smax // chunk)
    scale = hd ** -0.5
    q0 = q[:, 0].astype(jnp.float32)                       # (B,KV,G,hd)

    def body(i, carry):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(
            cache_k, i * chunk, chunk, 1).astype(jnp.float32)
        vs = jax.lax.dynamic_slice_in_dim(
            cache_v, i * chunk, chunk, 1).astype(jnp.float32)
        msk = jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, 1)
        s = jnp.einsum("bkgd,bskd->bkgs", q0, ks) * scale  # (B,KV,G,c)
        s = jnp.where(msk[:, None, None, :], s, NEG)
        m2 = jnp.maximum(m, s.max(-1))
        corr = jnp.exp(m - m2)
        pr = jnp.exp(s - m2[..., None])
        l2 = l * corr + pr.sum(-1)
        acc2 = acc * corr[..., None] + jnp.einsum(
            "bkgs,bskd->bkgd", pr, vs)
        return m2, l2, acc2

    init = (jnp.full((b, kv, g), -jnp.inf, jnp.float32),
            jnp.zeros((b, kv, g), jnp.float32),
            jnp.zeros((b, kv, g, hd), jnp.float32))
    m, l, acc = jax.lax.fori_loop(0, nch, body, init)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out[:, None].astype(C)                          # (B,1,KV,G,hd)


def attn_decode(p, x, cache_k, cache_v, pos, cfg: ModelConfig,
                prof: Profile, *, kind="attn", cross=False, use_rope=True):
    """One-token decode.  x (B, 1, D); cache_k/v (B, Smax, KV, hd);
    pos (B,) current position.  Returns (out, new_k, new_v)."""
    p = _cast(p)
    b, _, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(b, 1, kv, h // kv, hd)
    if use_rope:
        sin, cos = rope_tables(pos[:, None], hd, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
    if not cross:
        knew = x @ p["wk"]
        vnew = x @ p["wv"]
        if "bk" in p:
            knew, vnew = knew + p["bk"], vnew + p["bv"]
        knew = knew.reshape(b, 1, kv, hd)
        vnew = vnew.reshape(b, 1, kv, hd)
        if use_rope:
            knew = apply_rope(knew, sin, cos)
        # scatter the new row at pos (per batch element); .at[].set keeps
        # the donated cache buffer aliasable (a `where` copy would not)
        idx_b = jnp.arange(b)
        cache_k = cache_k.at[idx_b, pos].set(
            knew[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[idx_b, pos].set(
            vnew[:, 0].astype(cache_v.dtype))
    smax = cache_k.shape[1]
    kv_pos = jnp.broadcast_to(jnp.arange(smax)[None], (b, smax))
    mask = kv_pos <= pos[:, None] if not cross else jnp.ones_like(kv_pos,
                                                                  bool)
    if kind == "local" and cfg.window:
        mask &= kv_pos > (pos[:, None] - cfg.window)
    if smax > 8192:
        out = _decode_attend_chunked(q, cache_k, cache_v, mask)
    else:
        out = _sdpa(q, cache_k.astype(C), cache_v.astype(C), mask[:, None])
    out = out.reshape(b, 1, h * hd) @ p["wo"]
    return out, cache_k, cache_v


# --------------------------------------------------------------------- MLP
def init_mlp(key, cfg: ModelConfig, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    std = d ** -0.5
    if cfg.mlp == "swiglu":
        return {"w1": jax.random.normal(ks[0], (d, f), jnp.float32) * std,
                "w3": jax.random.normal(ks[1], (d, f), jnp.float32) * std,
                "w2": jax.random.normal(ks[2], (f, d), jnp.float32) * std}
    return {"w1": jax.random.normal(ks[0], (d, f), jnp.float32) * std,
            "w2": jax.random.normal(ks[2], (f, d), jnp.float32) * std}


def mlp_specs(cfg: ModelConfig, prof: Profile):
    if cfg.mlp == "swiglu":
        return {"w1": prof.w_in(), "w3": prof.w_in(), "w2": prof.w_out()}
    return {"w1": prof.w_in(), "w2": prof.w_out()}


def mlp_apply(p, x, cfg: ModelConfig, prof: Profile):
    p = _cast(p)
    if "w3" in p:
        h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    else:
        h = jax.nn.gelu(x @ p["w1"])
    h = cons(h, prof.act_btf(), prof)
    return h @ p["w2"]
