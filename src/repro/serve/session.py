"""Deterministic batched serving session (Pot × decoding).

Model math runs through models/lm.decode_step; the *shared serving
state* — the page table mapping decode slots to KV pages, and page
versions — is managed as preordered transactions: each decode step, every
active slot's page-append is a transaction sequenced by the round-robin
sequencer over slots; commits apply through the ordered paged-commit
kernel (kernels/kv_commit.py), stamping page versions with sequence
numbers.  Two replicas fed the same requests emit bitwise-identical
streams regardless of arrival interleavings (tests/test_serve.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sequencer import RoundRobinSequencer
from repro.kernels import ops
from repro.models import lm
from repro.models.config import ModelConfig
from repro.runtime.shardings import SMOKE, Profile


@dataclasses.dataclass
class Session:
    cfg: ModelConfig
    params: dict
    n_slots: int
    max_seq: int
    page_size: int = 16
    prof: Profile = SMOKE

    def __post_init__(self):
        self.cache = lm.init_cache(self.cfg, self.n_slots, self.max_seq,
                                   self.prof)
        self.pos = jnp.zeros((self.n_slots,), jnp.int32)
        self.tokens = jnp.zeros((self.n_slots, 1), jnp.int32)
        self.active = np.zeros((self.n_slots,), bool)
        self.seqr = RoundRobinSequencer(n_root_lanes=self.n_slots)
        # paged metadata store (shared state under Pot commit)
        n_pages = self.n_slots * (self.max_seq // self.page_size)
        self.page_meta = jnp.zeros((n_pages, self.page_size, 8),
                                   jnp.float32)
        self.page_versions = jnp.zeros((n_pages,), jnp.int32)
        self._decode = jax.jit(
            lambda p, c, t, po: lm.decode_step(p, c, t, po, self.cfg,
                                               self.prof))

    def add_request(self, slot: int, first_token: int) -> None:
        assert not self.active[slot]
        self.active[slot] = True
        self.tokens = self.tokens.at[slot, 0].set(first_token)
        self.pos = self.pos.at[slot].set(0)

    def step(self) -> np.ndarray:
        """One decode round: model math + ordered page-commit of every
        active slot's new row.  Returns the emitted tokens (greedy)."""
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.tokens, self.pos)
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)

        # ---- Pot commit of page metadata, in sequencer order ----
        slots = [s for s in range(self.n_slots) if self.active[s]]
        if slots:
            sn = self.seqr.order_for(slots)
            page_idx = jnp.asarray(
                [s * (self.max_seq // self.page_size)
                 + int(self.pos[s]) // self.page_size for s in slots],
                jnp.int32)
            row_idx = jnp.asarray(
                [int(self.pos[s]) % self.page_size for s in slots],
                jnp.int32)
            rows = jnp.stack([
                jnp.full((8,), float(nxt[s]), jnp.float32) for s in slots])
            commit = jnp.ones((len(slots),), jnp.int32)
            self.page_meta, self.page_versions = ops.kv_cache_commit(
                self.page_meta, self.page_versions, rows, page_idx,
                row_idx, jnp.asarray(sn, jnp.int32), commit)

        self.tokens = nxt[:, None]
        self.pos = self.pos + jnp.asarray(self.active, jnp.int32)
        return np.asarray(nxt)

    def generate(self, n_steps: int) -> np.ndarray:
        """Greedy-decode n_steps for all active slots; (slots, n) tokens."""
        out = []
        for _ in range(n_steps):
            out.append(self.step())
        return np.stack(out, axis=1)

    def fingerprint(self) -> int:
        """Order-sensitive hash of (page_meta, versions) — the replica
        consistency check."""
        h = 0x811C9DC5
        for x in (np.asarray(self.page_versions).tobytes(),
                  np.asarray(self.page_meta).tobytes()):
            for chunk in x[::97]:
                h = ((h ^ chunk) * 0x01000193) & 0xFFFFFFFF
        return h
