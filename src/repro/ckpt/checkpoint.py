"""Sharded checkpointing with atomic commit and deterministic restart.

Fault-tolerance contract (DESIGN.md §3): a restarted replica must rejoin
the SAME serialization order.  A checkpoint therefore stores, alongside
parameters and optimizer state, the Pot commit cursor (``gv``) and the
data-pipeline step — restoring reproduces the run bitwise (tested in
tests/test_ckpt.py).  The *session-level* snapshot of that contract —
store image + sequencer cursor + ingress journal cursor, with chained
self-verification — lives in :mod:`repro.core.checkpoint`; this module
is the trainer-facing pytree checkpoint.

Layout: <dir>/step_<n>/
    manifest.json             — tree structure, dtypes, shapes, host count
    shard_<h>.npz             — this host's param/opt leaves
Commit protocol: the shared :func:`repro.core.checkpoint.atomic_dir`
helper — stage into ``step_<n>.tmp_<host>``, fsync every file AND the
directories, atomic rename, fsync the parent — so there is exactly one
crash-safety implementation in the repo and a crash at ANY point leaves
either the previous complete checkpoint or a ``*.tmp*`` turd that
``latest_step`` never lists.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.checkpoint import atomic_dir


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, state, *, host_id: int = 0,
         n_hosts: int = 1, extra: dict | None = None) -> str:
    """Atomically save a pytree ``state`` for ``step``."""
    leaves, treedef = _flatten(state)
    final = os.path.join(directory, f"step_{step}")
    with atomic_dir(final, suffix=f".tmp_{host_id}") as tmp:
        np.savez(os.path.join(tmp, f"shard_{host_id}.npz"),
                 **{f"leaf_{i}": np.asarray(x)
                    for i, x in enumerate(leaves)})
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": str(treedef),
            "n_hosts": n_hosts,
            "dtypes": [str(np.asarray(x).dtype) for x in leaves],
            "shapes": [list(np.asarray(x).shape) for x in leaves],
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and "tmp" not in d]
    return max(steps) if steps else None


def restore(directory: str, step: int, like, *, host_id: int = 0):
    """Restore into the structure of ``like`` (a pytree template)."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, f"shard_{host_id}.npz"))
    leaves_like, treedef = _flatten(like)
    assert manifest["n_leaves"] == len(leaves_like), (
        f"checkpoint has {manifest['n_leaves']} leaves, "
        f"template has {len(leaves_like)}")
    leaves = [jnp.asarray(data[f"leaf_{i}"]) for i in range(len(leaves_like))]
    return jax.tree.unflatten(treedef, leaves), manifest["extra"]


def prune(directory: str, keep: int = 3) -> None:
    """Retain only the newest ``keep`` checkpoints."""
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(directory)
        if d.startswith("step_") and "tmp" not in d)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"))
