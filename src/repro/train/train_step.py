"""Training step: baseline (traditional) vs Pot (preordered commits).

Gradient application is the framework's highest-volume transaction.  Two
step flavors:

- ``baseline``: one global-batch gradient; GSPMD chooses the cross-shard
  reduction schedule (the *traditional transactions* regime — outcome
  bitwise-depends on reduction scheduling/timing on real fleets).
- ``pot``: every microbatch gradient is a preordered transaction.
  In-chip, microbatch grads accumulate by ordered commits (fixed
  sequence order, ``lax.scan`` + ordered pairwise tree).  Cross-shard,
  when ``det_reduce`` is on (pure-DP meshes), the reduction runs on the
  fixed-ring schedule of optim/ordered_reduce.py inside shard_map.  The
  optimizer apply is the fast-mode direct commit (kernels/fused_adamw on
  TPU; the jnp twin here), and ``gv`` stamps the commit — checkpoint/
  restart resumes the same serialization order (ckpt/checkpoint.py).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, ordered_ring_reduce)
from repro.runtime.shardings import Profile


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: dict
    opt: dict
    gv: jax.Array      # () int32 — global version (last committed txn)
    step: jax.Array    # () int32


def init_state(params, optimizer="adamw"):
    init = adamw_init if optimizer == "adamw" else adafactor_init
    return TrainState(params=params, opt=init(params),
                      gv=jnp.zeros((), jnp.int32),
                      step=jnp.zeros((), jnp.int32))


def loss_fn(params, batch, cfg: ModelConfig, prof: Profile, *, chunk=0,
            unroll=False, remat=True):
    """Next-token CE.  batch: {tokens (B,S), labels (B,S)} plus optional
    {frames} (whisper) / {patches} (internvl)."""
    enc = None
    prefix = batch.get("patches")
    if cfg.encoder_layers:
        enc = lm.encode(params, batch["frames"], cfg, prof, unroll=unroll,
                        remat=remat)
    logits = lm.forward(params, batch["tokens"], cfg, prof,
                        prefix_embeds=prefix, enc=enc, chunk=chunk,
                        unroll=unroll, remat=remat)
    off = logits.shape[1] - batch["labels"].shape[1]
    logits = logits[:, off:].astype(jnp.float32)
    labels = batch["labels"]
    mask = labels >= 0
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.clip(labels, 0)[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def _split_microbatches(batch, n):
    return jax.tree.map(
        lambda a: a.reshape((n, a.shape[0] // n) + a.shape[1:]), batch)


def make_train_step(cfg: ModelConfig, prof: Profile, *, optimizer="adamw",
                    mode: str = "baseline", n_microbatches: int = 1,
                    chunk=0, unroll=False, remat=True, lr=1e-3, wd=0.01,
                    grad_specs=None, accum_dtype=jnp.float32):
    """Build a jittable train step.  mode: "baseline" | "pot".
    grad_specs: optional PartitionSpec tree matching params — pins the
    gradient (and microbatch accumulator) sharding to the parameter
    sharding so the accumulation scan never carries replicated leaves."""
    upd = adamw_update if optimizer == "adamw" else adafactor_update
    kwargs = {"lr": lr, "wd": wd} if optimizer == "adamw" else {"lr": lr}
    grad_fn = jax.value_and_grad(
        partial(loss_fn, cfg=cfg, prof=prof, chunk=chunk, unroll=unroll,
                remat=remat))

    def pin(grads):
        if grad_specs is None:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, grad_specs)

    def baseline_step(state: TrainState, batch):
        loss, grads = grad_fn(state.params, batch)
        grads = pin(grads)
        params, opt = upd(state.params, grads, state.opt, **kwargs)
        return dataclasses.replace(
            state, params=params, opt=opt, step=state.step + 1), loss

    def pot_step(state: TrainState, batch):
        if n_microbatches > 1:
            mbs = _split_microbatches(batch, n_microbatches)

            # ordered commits: microbatch transactions accumulate in the
            # sequencer-fixed order (scan order == sequence order); every
            # commit is a fixed-order float add -> bitwise deterministic.
            def commit(carry, mb):
                acc, loss_acc = carry
                loss, g = grad_fn(state.params, mb)
                acc = pin(jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), acc, g))
                return (acc, loss_acc + loss), None

            zeros = pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), state.params))
            (gsum, loss_sum), _ = jax.lax.scan(
                commit, (zeros, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / n_microbatches, gsum)
            loss = loss_sum / n_microbatches
        else:
            loss, grads = grad_fn(state.params, batch)
            grads = pin(grads)

        # fast-mode direct commit (kernels/fused_adamw on TPU)
        params, opt = upd(state.params, grads, state.opt, **kwargs)
        return dataclasses.replace(
            state, params=params, opt=opt, gv=state.gv + 1,
            step=state.step + 1), loss

    return pot_step if mode == "pot" else baseline_step


def make_pot_dp_step(cfg: ModelConfig, mesh, *, axis="data",
                     optimizer="adamw", n_microbatches: int = 1,
                     lr=1e-3, wd=0.01, remat=False):
    """Fully-deterministic pure-DP Pot step (the end-to-end configuration
    of examples/train_lm.py).

    The entire step runs inside shard_map over ``axis``: each shard
    computes its local-batch gradient (a preordered transaction; the
    sequencer order is the ring position), gradients cross shards via the
    fixed-ring ordered reduction (bitwise deterministic regardless of
    arrival order / stragglers), and every shard applies the identical
    fast-mode commit.  Params/opt replicated (pure DP)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    prof = Profile(enabled=False)
    upd = adamw_update if optimizer == "adamw" else adafactor_update
    kwargs = {"lr": lr, "wd": wd} if optimizer == "adamw" else {"lr": lr}
    n_shards = mesh.shape[axis]
    grad_fn = jax.value_and_grad(
        partial(loss_fn, cfg=cfg, prof=prof, remat=remat))

    def local_step(state: TrainState, batch):
        if n_microbatches > 1:
            mbs = _split_microbatches(batch, n_microbatches)

            def commit(carry, mb):
                acc, la = carry
                loss, g = grad_fn(state.params, mb)
                return (jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g),
                    la + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, ls), _ = jax.lax.scan(
                commit, (zeros, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / n_microbatches, gsum)
            loss = ls / n_microbatches
        else:
            loss, grads = grad_fn(state.params, batch)
        # ordered commit across shards: fixed-ring deterministic sum
        grads = jax.tree.map(
            lambda g: ordered_ring_reduce(g, axis) / n_shards, grads)
        loss = ordered_ring_reduce(loss[None], axis)[0] / n_shards
        params, opt = upd(state.params, grads, state.opt, **kwargs)
        return dataclasses.replace(
            state, params=params, opt=opt, gv=state.gv + 1,
            step=state.step + 1), loss

    def step(state: TrainState, batch):
        sspec = jax.tree.map(lambda _: P(), state)
        bspec = jax.tree.map(lambda _: P(axis), batch)
        f = shard_map(local_step, mesh=mesh, in_specs=(sspec, bspec),
                      out_specs=(sspec, P()), check_rep=False)
        return f(state, batch)

    return step
