"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def validate_bitsets_ref(read_bits: jax.Array,
                         written_bits: jax.Array) -> jax.Array:
    """conflict (K,) bool."""
    hit = (read_bits & written_bits[None, :]) != 0
    return hit.any(axis=1)


def conflict_matrix_bits_ref(foot_bits: jax.Array,
                             write_bits: jax.Array) -> jax.Array:
    """conflict (K, K) bool: any(foot_bits[i] & write_bits[j])."""
    hit = (foot_bits[:, None, :] & write_bits[None, :, :]) != 0
    return hit.any(axis=2)


def adamw_ref(p, m, v, g, *, step, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8,
              wd=0.01):
    g = g.astype(jnp.float32)
    step = jnp.asarray(step, jnp.float32)
    m2 = b1 * m + (1.0 - b1) * g
    v2 = b2 * v + (1.0 - b2) * g * g
    bc1 = 1.0 - jnp.power(jnp.float32(b1), step)
    bc2 = 1.0 - jnp.power(jnp.float32(b2), step)
    mhat = m2 / bc1
    vhat = v2 / bc2
    p2 = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    return p2, m2, v2


def adamw_speculative_ref(p, m, v, g, versions, rv, *, step, lr=1e-3,
                          b1=0.9, b2=0.999, eps=1e-8, wd=0.01,
                          br=256, bc=256):
    """Per-(br, bc)-block validated update; stale blocks abort."""
    p2, m2, v2 = adamw_ref(p, m, v, g, step=step, lr=lr, b1=b1, b2=b2,
                           eps=eps, wd=wd)
    stale = versions > rv                                  # (gr, gc) bool
    big = jnp.repeat(jnp.repeat(stale, br, axis=0), bc, axis=1)
    return (jnp.where(big, p, p2), jnp.where(big, m, m2),
            jnp.where(big, v, v2), stale.astype(jnp.int32))


def kv_commit_ref(cache, versions, rows, page_idx, row_idx, sn, commit):
    """Sequential slot commits in grid order (commit order)."""
    def body(i, carry):
        cache, versions = carry
        do = commit[i] != 0
        page = cache[page_idx[i]]
        updated = jax.lax.dynamic_update_slice(
            page, rows[i][None].astype(cache.dtype), (row_idx[i], 0))
        cache = cache.at[page_idx[i]].set(jnp.where(do, updated, page))
        versions = versions.at[page_idx[i]].set(
            jnp.where(do, sn[i], versions[page_idx[i]]))
        return cache, versions

    return jax.lax.fori_loop(0, rows.shape[0], body, (cache, versions))
