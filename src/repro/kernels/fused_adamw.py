"""Fast-mode direct parameter commit: fused AdamW update kernel.

The paper's fast transaction merges read and write phases and installs
updates *in place* with no tracking (§2.2.3, Fig. 3c).  For the framework's
highest-volume transaction — committing a gradient into the parameter
store — the fast path is a fused optimizer update: one pass over
(p, m, v, g) producing (p', m', v') with all element-wise math fused, so
each parameter word moves HBM→VMEM→HBM exactly once.  Unfused XLA would
be 3 reads + 3 writes per state; the fusion is the direct-update win.

The *speculative* variant (``fused_adamw_speculative``) is the same
update guarded by TL2-style version validation: it carries the per-block
version word tile + the transaction's read version ``rv`` and applies the
update only where ``version <= rv`` (stale blocks are left untouched and
reported for retry).  The extra operands/scratch are exactly the paper's
"read set maintenance" — and the reason the fast path has a larger usable
VMEM tile budget (the ROT capacity story of Fig. 13, measured in
benchmarks/fig13_capacity.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BR = 256   # rows per block
BC = 256   # cols per block (lane multiple)


def _adamw_kernel(hp_ref, p_ref, m_ref, v_ref, g_ref,
                  po_ref, mo_ref, vo_ref):
    """hp = [lr, b1, b2, eps, wd, bc1, bc2, 0] as a (1, 8) f32 block."""
    lr, b1, b2, eps = hp_ref[0, 0], hp_ref[0, 1], hp_ref[0, 2], hp_ref[0, 3]
    wd, bc1, bc2 = hp_ref[0, 4], hp_ref[0, 5], hp_ref[0, 6]
    g = g_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    mhat = m / bc1
    vhat = v / bc2
    p = p_ref[...]
    p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    po_ref[...] = p
    mo_ref[...] = m
    vo_ref[...] = v


def _adamw_spec_kernel(hp_ref, ver_ref, p_ref, m_ref, v_ref, g_ref,
                       po_ref, mo_ref, vo_ref, abort_ref):
    """Speculative variant: validate block versions against rv before
    applying (rv passed as hp[0, 7]); stale blocks abort (left unchanged)."""
    rv = hp_ref[0, 7]
    stale = (ver_ref[...].astype(jnp.float32) > rv).sum() > 0

    lr, b1, b2, eps = hp_ref[0, 0], hp_ref[0, 1], hp_ref[0, 2], hp_ref[0, 3]
    wd, bc1, bc2 = hp_ref[0, 4], hp_ref[0, 5], hp_ref[0, 6]
    g = g_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    mhat = m / bc1
    vhat = v / bc2
    p = p_ref[...]
    pn = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)

    ok = ~stale
    po_ref[...] = jnp.where(ok, pn, p)
    mo_ref[...] = jnp.where(ok, m, m_ref[...])
    vo_ref[...] = jnp.where(ok, v, v_ref[...])
    abort_ref[...] = jnp.full_like(abort_ref, stale.astype(jnp.int32))


def _hp_vector(lr, b1, b2, eps, wd, step, rv=0.0):
    step = jnp.asarray(step, jnp.float32)
    bc1 = 1.0 - jnp.power(jnp.float32(b1), step)
    bc2 = 1.0 - jnp.power(jnp.float32(b2), step)
    return jnp.stack([
        jnp.float32(lr), jnp.float32(b1), jnp.float32(b2), jnp.float32(eps),
        jnp.float32(wd), bc1, bc2, jnp.asarray(rv, jnp.float32),
    ]).reshape(1, 8)


@functools.partial(jax.jit,
                   static_argnames=("lr", "b1", "b2", "eps", "wd",
                                    "interpret"))
def fused_adamw(p, m, v, g, *, step, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8,
                wd=0.01, interpret: bool = True):
    """Fast-mode (direct update) fused AdamW.  p/m/v f32 (R, C), g f32/bf16.

    R % BR == 0 and C % BC == 0 (ops.py pads/reshapes arbitrary pytrees).
    """
    r, c = p.shape
    assert r % BR == 0 and c % BC == 0, (r, c)
    hp = _hp_vector(lr, b1, b2, eps, wd, step)
    grid = (r // BR, c // BC)
    return pl.pallas_call(
        _adamw_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 8), lambda i, j: (0, 0)),
            pl.BlockSpec((BR, BC), lambda i, j: (i, j)),
            pl.BlockSpec((BR, BC), lambda i, j: (i, j)),
            pl.BlockSpec((BR, BC), lambda i, j: (i, j)),
            pl.BlockSpec((BR, BC), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((BR, BC), lambda i, j: (i, j)),
            pl.BlockSpec((BR, BC), lambda i, j: (i, j)),
            pl.BlockSpec((BR, BC), lambda i, j: (i, j)),
        ],
        out_shape=[jax.ShapeDtypeStruct((r, c), jnp.float32)] * 3,
        interpret=interpret,
    )(hp, p, m, v, g)


@functools.partial(jax.jit,
                   static_argnames=("lr", "b1", "b2", "eps", "wd",
                                    "interpret"))
def fused_adamw_speculative(p, m, v, g, versions, rv, *, step, lr=1e-3,
                            b1=0.9, b2=0.999, eps=1e-8, wd=0.01,
                            interpret: bool = True):
    """Speculative-mode update: per-block version validation against rv.

    versions: (R//BR, C//BC) int32 block versions.  Returns
    (p', m', v', abort (R//BR, C//BC) int32).
    """
    r, c = p.shape
    assert r % BR == 0 and c % BC == 0, (r, c)
    gr, gc = r // BR, c // BC
    hp = _hp_vector(lr, b1, b2, eps, wd, step, rv=rv)
    outs = pl.pallas_call(
        _adamw_spec_kernel,
        grid=(gr, gc),
        in_specs=[
            pl.BlockSpec((1, 8), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((BR, BC), lambda i, j: (i, j)),
            pl.BlockSpec((BR, BC), lambda i, j: (i, j)),
            pl.BlockSpec((BR, BC), lambda i, j: (i, j)),
            pl.BlockSpec((BR, BC), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((BR, BC), lambda i, j: (i, j)),
            pl.BlockSpec((BR, BC), lambda i, j: (i, j)),
            pl.BlockSpec((BR, BC), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[jax.ShapeDtypeStruct((r, c), jnp.float32)] * 3
        + [jax.ShapeDtypeStruct((gr, gc), jnp.int32)],
        interpret=interpret,
    )(hp, versions, p, m, v, g)
    return outs
