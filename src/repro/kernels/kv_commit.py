"""Ordered KV-cache page commit kernel (serving-side Pot).

During batched decoding, request slots append their new token's K/V rows
to shared cache pages.  Under Pot, slot commits are preordered: the head
slot writes directly (fast), later slots' writes land in sequence order
and stamp the page version so speculative readers can validate
(kernels/validate.py).

TPU formulation: grid over *pages* (each page block visited exactly once —
no output-block revisit hazard); the per-slot routing metadata
(page_idx, row_idx, sn, commit) arrives as scalar-prefetch operands and
the kernel folds all S slots over its page in sequence order (grid-order-
independent, deterministic).  Slot rows live in a VMEM block; the fold is
S dynamic row updates — S is the decode batch (small), pages are the
large axis, so work is dominated by the single page-block pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kv_commit_kernel(page_idx_ref, row_idx_ref, sn_ref, commit_ref,
                      rows_ref, cache_ref, ver_ref,
                      cache_out_ref, ver_out_ref):
    p = pl.program_id(0)
    n_slots = rows_ref.shape[0]
    block = cache_ref[0]            # (page, H)
    ver = ver_ref[0, 0]             # ()

    def fold(s, carry):
        block, ver = carry
        hit = (page_idx_ref[s] == p) & (commit_ref[s] != 0)
        new_row = rows_ref[s][None].astype(block.dtype)   # (1, H)
        updated = jax.lax.dynamic_update_slice(
            block, new_row, (row_idx_ref[s], 0))
        block = jnp.where(hit, updated, block)
        ver = jnp.where(hit, sn_ref[s], ver)
        return block, ver

    block, ver = jax.lax.fori_loop(0, n_slots, fold, (block, ver))
    cache_out_ref[...] = block[None]
    ver_out_ref[...] = ver[None, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def kv_commit(cache, versions, rows, page_idx, row_idx, sn, commit,
              *, interpret: bool = True):
    """Apply one decode step's slot commits to the paged KV cache.

    cache:    (P, page, H)  — paged cache (one head-group flattened to H)
    versions: (P,) int32    — page versions (sequence numbers, §3.1)
    rows:     (S, H)        — new K/V rows per slot
    page_idx: (S,) int32    — target page per slot
    row_idx:  (S,) int32    — row within the page
    sn:       (S,) int32    — slot sequence numbers (commit order: ascending)
    commit:   (S,) int32    — 1 to commit, 0 to skip (aborted/speculative)

    Slots must be supplied in sequence order; within a page the fold
    applies them in that order (last = highest sn wins, matching the
    ordered write-back of core/pcc.py).
    """
    n_pages, page, h = cache.shape
    s = rows.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(n_pages,),
        in_specs=[
            pl.BlockSpec((s, h), lambda i, *pref: (0, 0)),
            pl.BlockSpec((1, page, h), lambda i, *pref: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, *pref: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, page, h), lambda i, *pref: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, *pref: (i, 0)),
        ],
    )
    cache_out, ver_out = pl.pallas_call(
        _kv_commit_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(cache.shape, cache.dtype),
            jax.ShapeDtypeStruct((n_pages, 1), jnp.int32),
        ],
        interpret=interpret,
    )(page_idx, row_idx, sn, commit, rows, cache, versions.reshape(-1, 1))
    return cache_out, ver_out[:, 0]
