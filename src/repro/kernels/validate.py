"""Read-set validation kernel (TL2 validation phase, paper Fig. 3b 23-26).

TPU adaptation (DESIGN.md §2): the paper's validation loop gathers one
version word per read address — an irregular gather that is hostile to the
TPU memory system.  The TPU-native formulation is *dense bitset
validation*: read sets are bit-packed into (K, W) int32 words (W = ceil
(n_objects/32)) and the committed-writes-since-``rv`` set into (1, W);
a transaction conflicts iff any AND of its row with the written set is
non-zero.  This turns validation into a perfectly-tiled VPU reduction:
VMEM blocks of (BK, BW) words, OR-accumulated across the W grid axis.

The fast transaction (paper §2.2.3) skips this kernel launch entirely —
that is precisely its "no validation phase".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BK = 8     # transactions per block (sublane dimension)
BW = 128   # bitset words per block (lane dimension)


def _validate_kernel(read_ref, written_ref, out_ref):
    """One (BK, BW) tile: conflict |= any(read & written) per row."""
    hit = (read_ref[...] & written_ref[...]) != 0          # (BK, BW) bool
    any_hit = hit.sum(axis=1, keepdims=True) > 0           # (BK, 1)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = any_hit.astype(jnp.int32)

    @pl.when(pl.program_id(1) != 0)
    def _accum():
        out_ref[...] = out_ref[...] | any_hit.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def validate_bitsets(read_bits: jax.Array, written_bits: jax.Array,
                     *, interpret: bool = True) -> jax.Array:
    """conflict (K,) bool — read_bits (K, W) int32, written_bits (W,) int32.

    K must be a multiple of BK and W a multiple of BW (callers pad; see
    ops.validate).
    """
    k, w = read_bits.shape
    assert k % BK == 0 and w % BW == 0, (k, w)
    grid = (k // BK, w // BW)
    out = pl.pallas_call(
        _validate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BK, BW), lambda i, j: (i, j)),
            pl.BlockSpec((1, BW), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BK, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, 1), jnp.int32),
        interpret=interpret,
    )(read_bits, written_bits.reshape(1, w))
    return out[:, 0] != 0


def pack_addr_sets(addrs: jax.Array, n: jax.Array, n_objects: int) -> jax.Array:
    """Bit-pack (K, L) masked address sets into (K, ceil(O/32)) int32.

    Pure-jnp helper (runs under jit); the scatter is regular enough for
    XLA — the hot reduction is the Pallas kernel above.
    """
    length = addrs.shape[1]
    valid = jnp.arange(length)[None, :] < n[:, None]
    return pack_addr_sets_masked(addrs, valid, n_objects)


def pack_addr_sets_masked(addrs: jax.Array, valid: jax.Array,
                          n_objects: int) -> jax.Array:
    """Bit-pack (K, L) address sets under an explicit (K, L) validity mask.

    The shard-partitioned packing primitive (PR 5): a shard packs only
    the slots whose address falls inside its range, so ``valid`` is not
    expressible as a per-row prefix count.  Addresses must already be
    shard-local (callers subtract the shard base); invalid slots may
    hold any value — they are routed to the out-of-range word and
    dropped.
    """
    k, length = addrs.shape
    w = -(-n_objects // 32)
    word = addrs // 32
    bit = (jnp.uint32(1) << (addrs % 32).astype(jnp.uint32)).astype(jnp.uint32)
    word = jnp.where(valid, word, w)  # out-of-range -> dropped

    def body(j, acc):
        cur = acc[jnp.arange(k), jnp.clip(word[:, j], 0, w - 1)]
        new = cur | jnp.where(valid[:, j], bit[:, j], jnp.uint32(0))
        return acc.at[jnp.arange(k), word[:, j]].set(new, mode="drop")

    bits = jax.lax.fori_loop(0, length, body,
                             jnp.zeros((k, w), jnp.uint32))
    return bits.astype(jnp.int32)
