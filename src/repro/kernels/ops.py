"""Jit'd public wrappers around the Pallas kernels.

Handle padding/reshaping so callers can pass arbitrary shapes; pick
interpret mode automatically (interpret=True off-TPU so the kernels
validate on CPU; compiled on real TPU backends).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import conflict as _conf
from repro.kernels import fused_adamw as _adamw
from repro.kernels import kv_commit as _kvc
from repro.kernels import validate as _val


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, mult, axis, value=0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def validate(read_addrs: jax.Array, read_n: jax.Array,
             written_addrs: jax.Array, written_n: jax.Array,
             n_objects: int) -> jax.Array:
    """Read-set validation for K transactions against a written set.

    read_addrs (K, L) + read_n (K,); written_addrs (Lw,) + written_n ().
    Returns conflict (K,) bool.
    """
    k = read_addrs.shape[0]
    read_bits = _val.pack_addr_sets(read_addrs, read_n, n_objects)
    written_bits = _val.pack_addr_sets(
        written_addrs[None, :], written_n[None], n_objects)[0]
    read_bits = _pad_to(_pad_to(read_bits, _val.BK, 0), _val.BW, 1)
    written_bits = _pad_to(written_bits, _val.BW, 0)
    out = _val.validate_bitsets(read_bits, written_bits,
                                interpret=not _on_tpu())
    return out[:k]


def _conflict_matrix_dense(raddrs, rn, waddrs, wn, n_objects):
    """Reference fallback for :func:`conflict_matrix` off-TPU: dense 0/1
    footprint masks + one matmul (BLAS-batched on CPU, exact — counts are
    small integers in float32)."""
    k, length = raddrs.shape

    def dense(addrs, n):
        valid = jnp.arange(length)[None, :] < n[:, None]
        tgt = jnp.where(valid, addrs, n_objects)  # invalid -> shadow column
        mask = jnp.zeros((k, n_objects + 1), jnp.float32)
        mask = mask.at[jnp.arange(k)[:, None], tgt].set(1.0)
        return mask[:, :n_objects]

    wmask = dense(waddrs, wn)
    fmask = jnp.maximum(dense(raddrs, rn), wmask)
    return (fmask @ wmask.T) > 0.5


def conflict_matrix(raddrs: jax.Array, rn: jax.Array, waddrs: jax.Array,
                    wn: jax.Array, n_objects: int) -> jax.Array:
    """Batched pairwise conflict analysis: (K, K) bool where entry (i, j)
    means footprint(i) = reads(i) ∪ writes(i) intersects writes(j).

    raddrs/waddrs (K, L) masked by rn/wn (K,).  On TPU this is the tiled
    bitset-intersection Pallas kernel (conflict.py) over bit-packed
    address sets; off-TPU it falls back to the dense-mask reference
    formulation (same verdicts, asserted in tests/test_kernels.py).
    """
    if not _on_tpu():
        return _conflict_matrix_dense(raddrs, rn, waddrs, wn, n_objects)
    k = raddrs.shape[0]
    read_bits = _val.pack_addr_sets(raddrs, rn, n_objects)
    write_bits = _val.pack_addr_sets(waddrs, wn, n_objects)
    foot_bits = read_bits | write_bits
    # pad rows to the larger of the two row-block sizes, words to BW
    rows = max(_conf.BI, _conf.BJ)
    foot_bits = _pad_to(_pad_to(foot_bits, rows, 0), _conf.BW, 1)
    write_bits = _pad_to(_pad_to(write_bits, rows, 0), _conf.BW, 1)
    out = _conf.conflict_matrix_bits(foot_bits, write_bits, interpret=False)
    return out[:k, :k]


def packed_footprints(raddrs: jax.Array, rn: jax.Array, waddrs: jax.Array,
                      wn: jax.Array, n_objects: int
                      ) -> tuple[jax.Array, jax.Array]:
    """Bit-pack a batch's (footprint, write-set) address sets into
    (K, ceil(O/32)) int32 words — the carried representation behind the
    incremental conflict table (protocol.RoundState)."""
    read_bits = _val.pack_addr_sets(raddrs, rn, n_objects)
    write_bits = _val.pack_addr_sets(waddrs, wn, n_objects)
    return read_bits | write_bits, write_bits


def update_packed_footprints(foot_bits: jax.Array, write_bits: jax.Array,
                             raddrs: jax.Array, rn: jax.Array,
                             waddrs: jax.Array, wn: jax.Array,
                             live: jax.Array, n_objects: int
                             ) -> tuple[jax.Array, jax.Array]:
    """Carry packed footprints across engine rounds: re-pack only the rows
    of live (re-executed) transactions, keep settled rows' words.

    Dead rows are packed with their counts masked to 0 (cheap — packing is
    O(K·L) scatter work either way) and then dropped by the merge, so the
    output rows for settled transactions are bit-identical to the carried
    state from the round they last executed in.
    """
    fresh_foot, fresh_write = packed_footprints(
        raddrs, jnp.where(live, rn, 0), waddrs, jnp.where(live, wn, 0),
        n_objects)
    keep = live[:, None]
    return (jnp.where(keep, fresh_foot, foot_bits),
            jnp.where(keep, fresh_write, write_bits))


def update_packed_footprints_compact(foot_bits: jax.Array,
                                     write_bits: jax.Array,
                                     raddrs: jax.Array, rn: jax.Array,
                                     waddrs: jax.Array, wn: jax.Array,
                                     idx: jax.Array, valid: jax.Array,
                                     n_objects: int
                                     ) -> tuple[jax.Array, jax.Array]:
    """Compact variant of :func:`update_packed_footprints`: the round's
    re-executed rows arrive as a gathered (C, L) block
    (``raddrs``/``rn``/``waddrs``/``wn`` from ``txn.run_compact``) plus
    the row indices they came from; pack just those C rows — O(C·L)
    instead of O(K·L) — and scatter them over the carried (K, W) words.
    ``valid`` masks gather padding (possibly duplicate indices), which is
    dropped rather than scattered."""
    from repro.core.txn import scatter_rows
    cfoot, cwrite = packed_footprints(
        raddrs, jnp.where(valid, rn, 0), waddrs, jnp.where(valid, wn, 0),
        n_objects)
    return (scatter_rows(foot_bits, cfoot, idx, valid),
            scatter_rows(write_bits, cwrite, idx, valid))


def conflict_matrix_delta_compact(foot_bits: jax.Array,
                                  write_bits: jax.Array, old: jax.Array,
                                  idx: jax.Array, valid: jax.Array,
                                  n_objects: int) -> jax.Array:
    """Compacted variant of :func:`conflict_matrix_delta`: instead of a
    masked pass over the full (K, K) grid, compute only the two strips the
    round actually changed — rows idx (the C live footprints against every
    write set, (C, K)) and columns idx (every footprint against the C live
    write sets, (K, C)) — and scatter them over last round's table.

    On TPU both strips come from the rectangular bitset-intersection
    Pallas kernel (conflict.conflict_matrix_bits_pair): O(C·K·W) device
    work instead of O(K²·W).  Off-TPU a dense bit-ops fallback with
    identical verdicts (asserted in tests).  ``foot_bits``/``write_bits``
    must ALREADY hold the refreshed live rows
    (:func:`update_packed_footprints_compact`).
    """
    k = foot_bits.shape[0]
    c = idx.shape[0]
    cfoot = foot_bits[idx]
    cwrite = write_bits[idx]
    if _on_tpu():
        fb = _pad_to(_pad_to(foot_bits, _conf.BI, 0), _conf.BW, 1)
        wb = _pad_to(_pad_to(write_bits, _conf.BJ, 0), _conf.BW, 1)
        cf = _pad_to(_pad_to(cfoot, _conf.BI, 0), _conf.BW, 1)
        cw = _pad_to(_pad_to(cwrite, _conf.BJ, 0), _conf.BW, 1)
        row_strip = _conf.conflict_matrix_bits_pair(
            cf, wb, interpret=False)[:c, :k]
        col_strip = _conf.conflict_matrix_bits_pair(
            fb, cw, interpret=False)[:k, :c]
    else:
        row_strip = ((cfoot[:, None, :] & write_bits[None, :, :]) != 0
                     ).any(axis=2)
        col_strip = ((foot_bits[:, None, :] & cwrite[None, :, :]) != 0
                     ).any(axis=2)
    from repro.core.txn import scatter_rows
    new = scatter_rows(old, row_strip, idx, valid)
    # column twin of scatter_rows: same sentinel-drop contract, axis 1
    tgt = jnp.where(valid, idx, k)
    return new.at[:, tgt].set(col_strip, mode="drop")


def conflict_matrix_delta(foot_bits: jax.Array, write_bits: jax.Array,
                          old: jax.Array, live: jax.Array,
                          n_objects: int) -> jax.Array:
    """Incremental conflict-table update over carried packed footprints:
    entry (i, j) is recomputed iff transaction i or j re-executed this
    round (``live``), otherwise last round's verdict is carried.

    On TPU this is the masked-row variant of the bitset-intersection
    Pallas kernel (conflict.conflict_matrix_bits_delta — dead blocks skip
    the intersection); elsewhere a dense recompute-and-select fallback
    with identical verdicts (asserted in tests/test_kernels.py).
    ``old`` is (K, K) bool, ``foot_bits``/``write_bits`` are the (K, W)
    packed sets ALREADY refreshed for live rows.
    """
    k = foot_bits.shape[0]
    on_tpu = _on_tpu()
    rows = max(_conf.BI, _conf.BJ)
    fb = _pad_to(_pad_to(foot_bits, rows, 0), _conf.BW, 1)
    wb = _pad_to(_pad_to(write_bits, rows, 0), _conf.BW, 1)
    kp = fb.shape[0]
    old_p = _pad_to(_pad_to(old.astype(jnp.int32), rows, 0), rows, 1)
    live_p = _pad_to(live.astype(jnp.int32), rows, 0)
    if on_tpu:
        out = _conf.conflict_matrix_bits_delta(fb, wb, old_p, live_p,
                                               interpret=False)
        return out[:k, :k] != 0
    # dense fallback: full bitset "matmul", then carry stale entries
    hit = (fb[:, None, :] & wb[None, :, :]) != 0
    fresh = hit.any(axis=2)[:k, :k]
    refresh = live[:, None].astype(bool) | live[None, :].astype(bool)
    return jnp.where(refresh, fresh, old)


def adamw_update(p, m, v, g, *, step, lr=1e-3, b1=0.9, b2=0.999,
                 eps=1e-8, wd=0.01):
    """Fast-mode fused AdamW over an arbitrary-shaped parameter leaf."""
    shape = p.shape
    flat = lambda x: _pad_to(x.reshape(1, -1).astype(jnp.float32),
                             _adamw.BR * _adamw.BC, 1).reshape(
                                 _adamw.BR, -1)
    p2, m2, v2 = _adamw.fused_adamw(
        flat(p), flat(m), flat(v), flat(g), step=step, lr=lr, b1=b1,
        b2=b2, eps=eps, wd=wd, interpret=not _on_tpu())
    n = int(jnp.prod(jnp.asarray(shape)))
    unflat = lambda x: x.reshape(-1)[:n].reshape(shape)
    return unflat(p2), unflat(m2), unflat(v2)


def adamw_update_speculative(p, m, v, g, versions, rv, *, step, lr=1e-3,
                             b1=0.9, b2=0.999, eps=1e-8, wd=0.01):
    """Speculative fused AdamW: versions (R//BR, C//BC) int32, rv scalar."""
    return _adamw.fused_adamw_speculative(
        p, m, v, g, versions, rv, step=step, lr=lr, b1=b1, b2=b2,
        eps=eps, wd=wd, interpret=not _on_tpu())


def kv_cache_commit(cache, versions, rows, page_idx, row_idx, sn, commit):
    """Ordered paged-KV commit for one decode step (see kv_commit.py)."""
    return _kvc.kv_commit(cache, versions, rows, page_idx, row_idx, sn,
                          commit, interpret=not _on_tpu())
