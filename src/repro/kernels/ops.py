"""Jit'd public wrappers around the Pallas kernels.

Handle padding/reshaping so callers can pass arbitrary shapes; pick
interpret mode automatically (interpret=True off-TPU so the kernels
validate on CPU; compiled on real TPU backends).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import conflict as _conf
from repro.kernels import fused_adamw as _adamw
from repro.kernels import kv_commit as _kvc
from repro.kernels import validate as _val


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, mult, axis, value=0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def validate(read_addrs: jax.Array, read_n: jax.Array,
             written_addrs: jax.Array, written_n: jax.Array,
             n_objects: int) -> jax.Array:
    """Read-set validation for K transactions against a written set.

    read_addrs (K, L) + read_n (K,); written_addrs (Lw,) + written_n ().
    Returns conflict (K,) bool.
    """
    k = read_addrs.shape[0]
    read_bits = _val.pack_addr_sets(read_addrs, read_n, n_objects)
    written_bits = _val.pack_addr_sets(
        written_addrs[None, :], written_n[None], n_objects)[0]
    read_bits = _pad_to(_pad_to(read_bits, _val.BK, 0), _val.BW, 1)
    written_bits = _pad_to(written_bits, _val.BW, 0)
    out = _val.validate_bitsets(read_bits, written_bits,
                                interpret=not _on_tpu())
    return out[:k]


def _conflict_matrix_dense(raddrs, rn, waddrs, wn, n_objects):
    """Reference fallback for :func:`conflict_matrix` off-TPU: dense 0/1
    footprint masks + one matmul (BLAS-batched on CPU, exact — counts are
    small integers in float32)."""
    k, length = raddrs.shape

    def dense(addrs, n):
        valid = jnp.arange(length)[None, :] < n[:, None]
        tgt = jnp.where(valid, addrs, n_objects)  # invalid -> shadow column
        mask = jnp.zeros((k, n_objects + 1), jnp.float32)
        mask = mask.at[jnp.arange(k)[:, None], tgt].set(1.0)
        return mask[:, :n_objects]

    wmask = dense(waddrs, wn)
    fmask = jnp.maximum(dense(raddrs, rn), wmask)
    return (fmask @ wmask.T) > 0.5


def conflict_matrix(raddrs: jax.Array, rn: jax.Array, waddrs: jax.Array,
                    wn: jax.Array, n_objects: int) -> jax.Array:
    """Batched pairwise conflict analysis: (K, K) bool where entry (i, j)
    means footprint(i) = reads(i) ∪ writes(i) intersects writes(j).

    raddrs/waddrs (K, L) masked by rn/wn (K,).  On TPU this is the tiled
    bitset-intersection Pallas kernel (conflict.py) over bit-packed
    address sets; off-TPU it falls back to the dense-mask reference
    formulation (same verdicts, asserted in tests/test_kernels.py).
    """
    if not _on_tpu():
        return _conflict_matrix_dense(raddrs, rn, waddrs, wn, n_objects)
    k = raddrs.shape[0]
    read_bits = _val.pack_addr_sets(raddrs, rn, n_objects)
    write_bits = _val.pack_addr_sets(waddrs, wn, n_objects)
    foot_bits = read_bits | write_bits
    # pad rows to the larger of the two row-block sizes, words to BW
    rows = max(_conf.BI, _conf.BJ)
    foot_bits = _pad_to(_pad_to(foot_bits, rows, 0), _conf.BW, 1)
    write_bits = _pad_to(_pad_to(write_bits, rows, 0), _conf.BW, 1)
    out = _conf.conflict_matrix_bits(foot_bits, write_bits, interpret=False)
    return out[:k, :k]


def packed_footprints(raddrs: jax.Array, rn: jax.Array, waddrs: jax.Array,
                      wn: jax.Array, n_objects: int
                      ) -> tuple[jax.Array, jax.Array]:
    """Bit-pack a batch's (footprint, write-set) address sets into
    (K, ceil(O/32)) int32 words — the carried representation behind the
    incremental conflict table (protocol.RoundState)."""
    read_bits = _val.pack_addr_sets(raddrs, rn, n_objects)
    write_bits = _val.pack_addr_sets(waddrs, wn, n_objects)
    return read_bits | write_bits, write_bits


def update_packed_footprints(foot_bits: jax.Array, write_bits: jax.Array,
                             raddrs: jax.Array, rn: jax.Array,
                             waddrs: jax.Array, wn: jax.Array,
                             live: jax.Array, n_objects: int
                             ) -> tuple[jax.Array, jax.Array]:
    """Carry packed footprints across engine rounds: re-pack only the rows
    of live (re-executed) transactions, keep settled rows' words.

    Dead rows are packed with their counts masked to 0 (cheap — packing is
    O(K·L) scatter work either way) and then dropped by the merge, so the
    output rows for settled transactions are bit-identical to the carried
    state from the round they last executed in.
    """
    fresh_foot, fresh_write = packed_footprints(
        raddrs, jnp.where(live, rn, 0), waddrs, jnp.where(live, wn, 0),
        n_objects)
    keep = live[:, None]
    return (jnp.where(keep, fresh_foot, foot_bits),
            jnp.where(keep, fresh_write, write_bits))


def update_packed_footprints_compact(foot_bits: jax.Array,
                                     write_bits: jax.Array,
                                     raddrs: jax.Array, rn: jax.Array,
                                     waddrs: jax.Array, wn: jax.Array,
                                     idx: jax.Array, valid: jax.Array,
                                     n_objects: int
                                     ) -> tuple[jax.Array, jax.Array]:
    """Compact variant of :func:`update_packed_footprints`: the round's
    re-executed rows arrive as a gathered (C, L) block
    (``raddrs``/``rn``/``waddrs``/``wn`` from ``txn.run_compact``) plus
    the row indices they came from; pack just those C rows — O(C·L)
    instead of O(K·L) — and scatter them over the carried (K, W) words.
    ``valid`` masks gather padding (possibly duplicate indices), which is
    dropped rather than scattered."""
    from repro.core.txn import scatter_rows
    cfoot, cwrite = packed_footprints(
        raddrs, jnp.where(valid, rn, 0), waddrs, jnp.where(valid, wn, 0),
        n_objects)
    return (scatter_rows(foot_bits, cfoot, idx, valid),
            scatter_rows(write_bits, cwrite, idx, valid))


def conflict_matrix_delta_compact(foot_bits: jax.Array,
                                  write_bits: jax.Array, old: jax.Array,
                                  idx: jax.Array, valid: jax.Array,
                                  n_objects: int) -> jax.Array:
    """Compacted variant of :func:`conflict_matrix_delta`: instead of a
    masked pass over the full (K, K) grid, compute only the two strips the
    round actually changed — rows idx (the C live footprints against every
    write set, (C, K)) and columns idx (every footprint against the C live
    write sets, (K, C)) — and scatter them over last round's table.

    On TPU both strips come from the rectangular bitset-intersection
    Pallas kernel (conflict.conflict_matrix_bits_pair): O(C·K·W) device
    work instead of O(K²·W).  Off-TPU a dense bit-ops fallback with
    identical verdicts (asserted in tests).  ``foot_bits``/``write_bits``
    must ALREADY hold the refreshed live rows
    (:func:`update_packed_footprints_compact`).
    """
    k = foot_bits.shape[0]
    c = idx.shape[0]
    cfoot = foot_bits[idx]
    cwrite = write_bits[idx]
    if _on_tpu():
        fb = _pad_to(_pad_to(foot_bits, _conf.BI, 0), _conf.BW, 1)
        wb = _pad_to(_pad_to(write_bits, _conf.BJ, 0), _conf.BW, 1)
        cf = _pad_to(_pad_to(cfoot, _conf.BI, 0), _conf.BW, 1)
        cw = _pad_to(_pad_to(cwrite, _conf.BJ, 0), _conf.BW, 1)
        row_strip = _conf.conflict_matrix_bits_pair(
            cf, wb, interpret=False)[:c, :k]
        col_strip = _conf.conflict_matrix_bits_pair(
            fb, cw, interpret=False)[:k, :c]
    else:
        row_strip = ((cfoot[:, None, :] & write_bits[None, :, :]) != 0
                     ).any(axis=2)
        col_strip = ((foot_bits[:, None, :] & cwrite[None, :, :]) != 0
                     ).any(axis=2)
    from repro.core.txn import scatter_rows
    new = scatter_rows(old, row_strip, idx, valid)
    # column twin of scatter_rows: same sentinel-drop contract, axis 1
    tgt = jnp.where(valid, idx, k)
    return new.at[:, tgt].set(col_strip, mode="drop")


def conflict_matrix_delta(foot_bits: jax.Array, write_bits: jax.Array,
                          old: jax.Array, live: jax.Array,
                          n_objects: int) -> jax.Array:
    """Incremental conflict-table update over carried packed footprints:
    entry (i, j) is recomputed iff transaction i or j re-executed this
    round (``live``), otherwise last round's verdict is carried.

    On TPU this is the masked-row variant of the bitset-intersection
    Pallas kernel (conflict.conflict_matrix_bits_delta — dead blocks skip
    the intersection); elsewhere a dense recompute-and-select fallback
    with identical verdicts (asserted in tests/test_kernels.py).
    ``old`` is (K, K) bool, ``foot_bits``/``write_bits`` are the (K, W)
    packed sets ALREADY refreshed for live rows.
    """
    k = foot_bits.shape[0]
    on_tpu = _on_tpu()
    rows = max(_conf.BI, _conf.BJ)
    fb = _pad_to(_pad_to(foot_bits, rows, 0), _conf.BW, 1)
    wb = _pad_to(_pad_to(write_bits, rows, 0), _conf.BW, 1)
    kp = fb.shape[0]
    old_p = _pad_to(_pad_to(old.astype(jnp.int32), rows, 0), rows, 1)
    live_p = _pad_to(live.astype(jnp.int32), rows, 0)
    if on_tpu:
        out = _conf.conflict_matrix_bits_delta(fb, wb, old_p, live_p,
                                               interpret=False)
        return out[:k, :k] != 0
    # dense fallback: full bitset "matmul", then carry stale entries
    hit = (fb[:, None, :] & wb[None, :, :]) != 0
    fresh = hit.any(axis=2)[:k, :k]
    refresh = live[:, None].astype(bool) | live[None, :].astype(bool)
    return jnp.where(refresh, fresh, old)


# --------------------------------------------------------------------------
# Shard-partitioned conflict analysis (PR 5)
# --------------------------------------------------------------------------
#
# Under the sharded store layout (tstore.StoreLayout, S contiguous range
# shards of C = ceil(O/S) objects) the packed footprints decompose per
# shard: each shard packs only the addresses in its range into
# (K, ceil(C/32)) words — the conflict kernels' W axis shrinks by S —
# and the global conflict verdict is the OR over shards:
#
#     footprint(i) ∩ writes(j) ≠ ∅  ⟺  ∃s: foot_s(i) ∩ writes_s(j) ≠ ∅
#
# because the shards partition the address space.  Every function below
# is the per-shard twin of a dense one above, OR-reducing S independent
# intersections (the TPU path runs one bitset kernel per shard — each a
# candidate for its own device — and off-TPU a per-shard dense bit-ops
# fallback); verdicts are bit-identical to the dense formulation
# (asserted in tests/test_sharded_store.py).


def packed_footprints_sharded(raddrs: jax.Array, rn: jax.Array,
                              waddrs: jax.Array, wn: jax.Array, layout
                              ) -> tuple[jax.Array, jax.Array]:
    """Per-shard bit-packing of a batch's (footprint, write-set) address
    sets: (S, K, ceil(C/32)) int32 words each.  Shard s packs the slots
    whose address lies in [s*C, (s+1)*C), rebased to shard-local bits."""
    c = layout.shard_size
    length = raddrs.shape[1]
    slot = jnp.arange(length)[None, :]
    rvalid = slot < rn[:, None]
    wvalid = slot < wn[:, None]

    def per_shard(s):
        rb = _val.pack_addr_sets_masked(
            raddrs - s * c, rvalid & (raddrs // c == s), c)
        wb = _val.pack_addr_sets_masked(
            waddrs - s * c, wvalid & (waddrs // c == s), c)
        return rb | wb, wb

    return jax.vmap(per_shard)(jnp.arange(layout.shards))


def update_packed_footprints_sharded(foot_bits: jax.Array,
                                     write_bits: jax.Array,
                                     raddrs: jax.Array, rn: jax.Array,
                                     waddrs: jax.Array, wn: jax.Array,
                                     live: jax.Array, layout
                                     ) -> tuple[jax.Array, jax.Array]:
    """Sharded twin of :func:`update_packed_footprints`: re-pack only the
    live rows (every shard's row strip for a live transaction), keep the
    settled rows' words in all S shards."""
    fresh_foot, fresh_write = packed_footprints_sharded(
        raddrs, jnp.where(live, rn, 0), waddrs, jnp.where(live, wn, 0),
        layout)
    keep = live[None, :, None]
    return (jnp.where(keep, fresh_foot, foot_bits),
            jnp.where(keep, fresh_write, write_bits))


def update_packed_footprints_compact_sharded(foot_bits: jax.Array,
                                             write_bits: jax.Array,
                                             raddrs: jax.Array,
                                             rn: jax.Array,
                                             waddrs: jax.Array,
                                             wn: jax.Array,
                                             idx: jax.Array,
                                             valid: jax.Array, layout
                                             ) -> tuple[jax.Array,
                                                        jax.Array]:
    """Sharded twin of :func:`update_packed_footprints_compact`: pack the
    gathered (C_rows, L) block per shard — O(S·C_rows·L) — and scatter
    each shard's row strip over the carried (S, K, W_s) words."""
    from repro.core.txn import scatter_rows
    cfoot, cwrite = packed_footprints_sharded(
        raddrs, jnp.where(valid, rn, 0), waddrs, jnp.where(valid, wn, 0),
        layout)
    scatter = jax.vmap(scatter_rows, in_axes=(0, 0, None, None))
    return scatter(foot_bits, cfoot, idx, valid), \
        scatter(write_bits, cwrite, idx, valid)


def _shard_intersects(foot_s: jax.Array, write_s: jax.Array) -> jax.Array:
    """One shard's (K, K) intersection verdicts from packed words."""
    return ((foot_s[:, None, :] & write_s[None, :, :]) != 0).any(axis=2)


def conflict_matrix_sharded(foot_bits: jax.Array,
                            write_bits: jax.Array) -> jax.Array:
    """(K, K) conflict table from per-shard packed sets (S, K, W_s):
    the OR over shards of each shard's bitset intersection.  TPU runs
    the tiled Pallas kernel once per shard (W axis = W_s, not W);
    off-TPU a per-shard dense bit-ops reduction (looped, so peak memory
    is one shard's (K, K, W_s) tile, not S of them)."""
    s, k, _ = foot_bits.shape
    if _on_tpu():
        rows = max(_conf.BI, _conf.BJ)
        out = jnp.zeros((k, k), bool)
        for i in range(s):
            fb = _pad_to(_pad_to(foot_bits[i], rows, 0), _conf.BW, 1)
            wb = _pad_to(_pad_to(write_bits[i], rows, 0), _conf.BW, 1)
            out = out | _conf.conflict_matrix_bits(
                fb, wb, interpret=False)[:k, :k]
        return out
    out = jnp.zeros((k, k), bool)
    for i in range(s):
        out = out | _shard_intersects(foot_bits[i], write_bits[i])
    return out


def conflict_matrix_delta_sharded(foot_bits: jax.Array,
                                  write_bits: jax.Array, old: jax.Array,
                                  live: jax.Array, layout) -> jax.Array:
    """Sharded twin of :func:`conflict_matrix_delta`: recompute entry
    (i, j) iff i or j is live, as the OR over shards of per-shard
    verdicts; stale entries carry ``old``.  On TPU each shard runs the
    masked-row delta kernel against ``old`` (a stale tile ORs to itself,
    a refreshed one to the OR of shard-fresh verdicts); off-TPU the
    per-shard dense reduction + recompute-and-select."""
    s, k, _ = foot_bits.shape
    if _on_tpu():
        rows = max(_conf.BI, _conf.BJ)
        old_p = _pad_to(_pad_to(old.astype(jnp.int32), rows, 0), rows, 1)
        live_p = _pad_to(live.astype(jnp.int32), rows, 0)
        out = jnp.zeros_like(old_p)
        for i in range(s):
            fb = _pad_to(_pad_to(foot_bits[i], rows, 0), _conf.BW, 1)
            wb = _pad_to(_pad_to(write_bits[i], rows, 0), _conf.BW, 1)
            out = out | _conf.conflict_matrix_bits_delta(
                fb, wb, old_p, live_p, interpret=False)
        return out[:k, :k] != 0
    fresh = conflict_matrix_sharded(foot_bits, write_bits)
    refresh = live[:, None] | live[None, :]
    return jnp.where(refresh, fresh, old)


def conflict_matrix_delta_compact_sharded(foot_bits: jax.Array,
                                          write_bits: jax.Array,
                                          old: jax.Array, idx: jax.Array,
                                          valid: jax.Array,
                                          layout) -> jax.Array:
    """Sharded twin of :func:`conflict_matrix_delta_compact`: the round's
    two refreshed strips — rows idx (C, K) and columns idx (K, C) — are
    each the OR over shards of per-shard strips (rectangular pair kernel
    on TPU, dense bit-ops off it), scattered over last round's table.
    ``foot_bits``/``write_bits`` (S, K, W_s) must already hold the
    refreshed live rows (:func:`update_packed_footprints_compact_sharded`).
    """
    from repro.core.txn import scatter_rows
    s, k, _ = foot_bits.shape
    c = idx.shape[0]
    row_strip = jnp.zeros((c, k), bool)
    col_strip = jnp.zeros((k, c), bool)
    if _on_tpu():
        for i in range(s):
            fb = _pad_to(_pad_to(foot_bits[i], _conf.BI, 0), _conf.BW, 1)
            wb = _pad_to(_pad_to(write_bits[i], _conf.BJ, 0), _conf.BW, 1)
            cf = _pad_to(_pad_to(foot_bits[i][idx], _conf.BI, 0),
                         _conf.BW, 1)
            cw = _pad_to(_pad_to(write_bits[i][idx], _conf.BJ, 0),
                         _conf.BW, 1)
            row_strip = row_strip | _conf.conflict_matrix_bits_pair(
                cf, wb, interpret=False)[:c, :k]
            col_strip = col_strip | _conf.conflict_matrix_bits_pair(
                fb, cw, interpret=False)[:k, :c]
    else:
        for i in range(s):
            row_strip = row_strip | _shard_intersects(
                foot_bits[i][idx], write_bits[i])
            col_strip = col_strip | _shard_intersects(
                foot_bits[i], write_bits[i][idx])
    new = scatter_rows(old, row_strip, idx, valid)
    # column twin of scatter_rows: same sentinel-drop contract, axis 1
    tgt = jnp.where(valid, idx, k)
    return new.at[:, tgt].set(col_strip, mode="drop")


# --------------------------------------------------------------------------
# Cross-batch speculative validation (PR 7)
# --------------------------------------------------------------------------
#
# Cross-batch speculative pipelining (session.PotSession pipeline_depth)
# executes batch n+1 against the store image snapshotted BEFORE batch n
# committed.  Version stamps are globally monotone sequence numbers
# (every engine write-back stamps gv0 + commit position + 1), so an
# address was written after the snapshot iff versions[a] > snap_gv —
# the EXACT dirty predicate at any pipeline depth.  A speculated row
# stays valid iff none of its logged READ addresses is dirty: a row's
# execution is a pure function of its read values (read-your-writes is
# row-local), so clean reads replay bit-identically and the write set
# need not be checked.  The dirty set packs into ONE bitset row
# (word = a // 32, bit = a % 32 — validate.py's convention), turning
# the whole validation into a (K, 1) rectangular strip of the same
# bitset-intersection Pallas kernel the compact round update uses
# (conflict.conflict_matrix_bits_pair); off-TPU a dense gather
# fallback with identical verdicts (asserted in tests/test_pipeline.py).


def spec_dirty_words(versions: jax.Array, snap_gv: jax.Array,
                     n_objects: int) -> jax.Array:
    """Bit-pack the post-snapshot dirty set: word ``a // 32`` bit
    ``a % 32`` is set iff ``versions[a] > snap_gv``.  ``versions`` may
    be the dense (O,) array or the sharded (S, C) stack — the flat view
    lists addresses in order either way (contiguous range shards), and
    the padded tail rows of the last shard are never stamped (version
    0), hence never dirty.  Returns (ceil(O/32),) int32."""
    w = -(-n_objects // 32)
    dirty = versions.reshape(-1)[:n_objects] > snap_gv
    dirty = jnp.pad(dirty, (0, w * 32 - n_objects))
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    words = (dirty.reshape(w, 32).astype(jnp.uint32) * weights).sum(
        axis=1, dtype=jnp.uint32)
    return words.astype(jnp.int32)


def spec_dirty_words_sharded(versions: jax.Array, snap_gv: jax.Array,
                             layout) -> jax.Array:
    """Per-shard twin of :func:`spec_dirty_words`: shard s's words span
    only its own C-object range (shard-local bits, like
    ``packed_footprints_sharded``).  versions (S, C) -> (S, W_s) int32."""
    w = layout.words_per_shard
    c = layout.shard_size
    dirty = versions > snap_gv            # padding rows stamp 0: never dirty
    dirty = jnp.pad(dirty, ((0, 0), (0, w * 32 - c)))
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    words = (dirty.reshape(layout.shards, w, 32).astype(jnp.uint32)
             * weights).sum(axis=2, dtype=jnp.uint32)
    return words.astype(jnp.int32)


def spec_read_invalid(raddrs: jax.Array, rn: jax.Array,
                      versions: jax.Array, snap_gv: jax.Array,
                      n_objects: int) -> jax.Array:
    """Cross-batch read-set validation: (K,) bool, True where a row's
    logged read set hits an address written after the snapshot
    (``versions > snap_gv``).  On TPU the dirty words form a 1-row
    write set and the verdict is a (K, 1) strip of the rectangular
    bitset-intersection kernel; off-TPU a dense version gather."""
    k, length = raddrs.shape
    if not _on_tpu():
        valid = jnp.arange(length)[None, :] < rn[:, None]
        dirty = versions.reshape(-1)[:n_objects] > snap_gv
        return (valid & dirty[raddrs]).any(axis=1)
    read_bits = _val.pack_addr_sets(raddrs, rn, n_objects)
    dwords = spec_dirty_words(versions, snap_gv, n_objects)
    rb = _pad_to(_pad_to(read_bits, _conf.BI, 0), _conf.BW, 1)
    db = _pad_to(_pad_to(dwords[None, :], _conf.BJ, 0), _conf.BW, 1)
    return _conf.conflict_matrix_bits_pair(rb, db, interpret=False)[:k, 0]


def spec_read_invalid_sharded(raddrs: jax.Array, rn: jax.Array,
                              versions: jax.Array, snap_gv: jax.Array,
                              layout) -> jax.Array:
    """Sharded twin of :func:`spec_read_invalid`: per-shard read bits
    against per-shard dirty words, OR-reduced — the PR 5 OR-over-shards
    invariant (shards partition the address space, so a dirty read hit
    lands in exactly one shard's strip)."""
    k, length = raddrs.shape
    c = layout.shard_size
    slotv = jnp.arange(length)[None, :] < rn[:, None]
    dwords = spec_dirty_words_sharded(versions, snap_gv, layout)
    out = jnp.zeros((k,), bool)
    for s in range(layout.shards):
        rb = _val.pack_addr_sets_masked(
            raddrs - s * c, slotv & (raddrs // c == s), c)
        if _on_tpu():
            rbp = _pad_to(_pad_to(rb, _conf.BI, 0), _conf.BW, 1)
            db = _pad_to(_pad_to(dwords[s][None, :], _conf.BJ, 0),
                         _conf.BW, 1)
            out = out | _conf.conflict_matrix_bits_pair(
                rbp, db, interpret=False)[:k, 0]
        else:
            out = out | ((rb & dwords[s][None, :]) != 0).any(axis=1)
    return out


def cross_conflicts(reader_raddrs: jax.Array, reader_rn: jax.Array,
                    reader_waddrs: jax.Array, reader_wn: jax.Array,
                    writer_waddrs: jax.Array, writer_wn: jax.Array,
                    n_objects: int, reads_only: bool = False) -> jax.Array:
    """Rectangular reader × writer conflict strip: (R, C) bool where
    entry (i, j) means reader row i's footprint (reads ∪ writes, or the
    logged read set alone with ``reads_only`` — sound for execution
    validity by row purity, same argument as :func:`spec_read_invalid`)
    intersects writer row j's write set.

    The cross-result twin of :func:`conflict_matrix` behind DeSTM's
    wave-speculative retry validation (PR 10): the reader and writer
    verdicts come from DIFFERENT result blocks (speculative footprints
    vs a wave's re-executed write sets), so neither the carried table
    nor the delta strips apply.  On TPU both sides bit-pack and the
    strip is one ``conflict.conflict_matrix_bits_pair`` launch; off-TPU
    a dense bit-ops fallback with identical verdicts."""
    r = reader_raddrs.shape[0]
    c = writer_waddrs.shape[0]
    rbits = _val.pack_addr_sets(reader_raddrs, reader_rn, n_objects)
    if not reads_only:
        rbits = rbits | _val.pack_addr_sets(reader_waddrs, reader_wn,
                                            n_objects)
    wbits = _val.pack_addr_sets(writer_waddrs, writer_wn, n_objects)
    if _on_tpu():
        rb = _pad_to(_pad_to(rbits, _conf.BI, 0), _conf.BW, 1)
        wb = _pad_to(_pad_to(wbits, _conf.BJ, 0), _conf.BW, 1)
        return _conf.conflict_matrix_bits_pair(
            rb, wb, interpret=False)[:r, :c]
    return ((rbits[:, None, :] & wbits[None, :, :]) != 0).any(axis=2)


def adamw_update(p, m, v, g, *, step, lr=1e-3, b1=0.9, b2=0.999,
                 eps=1e-8, wd=0.01):
    """Fast-mode fused AdamW over an arbitrary-shaped parameter leaf."""
    shape = p.shape
    flat = lambda x: _pad_to(x.reshape(1, -1).astype(jnp.float32),
                             _adamw.BR * _adamw.BC, 1).reshape(
                                 _adamw.BR, -1)
    p2, m2, v2 = _adamw.fused_adamw(
        flat(p), flat(m), flat(v), flat(g), step=step, lr=lr, b1=b1,
        b2=b2, eps=eps, wd=wd, interpret=not _on_tpu())
    n = int(jnp.prod(jnp.asarray(shape)))
    unflat = lambda x: x.reshape(-1)[:n].reshape(shape)
    return unflat(p2), unflat(m2), unflat(v2)


def adamw_update_speculative(p, m, v, g, versions, rv, *, step, lr=1e-3,
                             b1=0.9, b2=0.999, eps=1e-8, wd=0.01):
    """Speculative fused AdamW: versions (R//BR, C//BC) int32, rv scalar."""
    return _adamw.fused_adamw_speculative(
        p, m, v, g, versions, rv, step=step, lr=lr, b1=b1, b2=b2,
        eps=eps, wd=wd, interpret=not _on_tpu())


def kv_cache_commit(cache, versions, rows, page_idx, row_idx, sn, commit):
    """Ordered paged-KV commit for one decode step (see kv_commit.py)."""
    return _kvc.kv_commit(cache, versions, rows, page_idx, row_idx, sn,
                          commit, interpret=not _on_tpu())
