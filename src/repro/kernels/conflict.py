"""K x K footprint-conflict matrix kernel (the batched validation pass
behind the vectorized commit pipeline, protocol.conflict_table).

The per-transaction validation loop (paper Fig. 2b line 9) probed one
(n_objects,) bitmap per transaction per commit step — K sequential device
steps per round.  The pipeline instead asks ONE batched question per
round: for every ordered pair (i, j), does transaction i's footprint
(read set + write set) intersect transaction j's write set?  With
bit-packed address sets (validate.pack_addr_sets) this is a boolean
"matmul" over W = ceil(n_objects/32) words:

    conflict[i, j] = any_w( foot_bits[i, w] & write_bits[j, w] )

TPU formulation: tile the (K, K) output into (BI, BJ) blocks and the
word axis into BW-word chunks; each grid step ANDs a (BI, BW) block of
footprints against a (BJ, BW) block of write sets and OR-accumulates the
(BI, BJ) any-hit tile across the W grid axis (same accumulate idiom as
validate.py, lifted from a vector to a matrix of verdicts).  The commit
decision then becomes a prefix fixpoint over this matrix
(protocol.prefix_commit / protocol.wave_commit) in O(log K) device steps
instead of a K-step `lax.scan`.

Incremental rounds (PR 3) carry the matrix across engine rounds instead
of rebuilding it: footprints change only via re-execution, so
conflict_matrix_bits_delta recomputes just the rows/columns of the
round's live transactions (masked-row variant of the same kernel —
blocks with no live row/column skip the intersection and carry last
round's tile).

Gather-compacted rounds (PR 4) shrink the delta further: with the C live
rows gathered into a compact block, the update is two *rectangular*
products (conflict_matrix_bits_pair) — the (C, K) row strip of live
footprints against every write set and the (K, C) column strip of every
footprint against the live write sets — scattered over the carried
table (ops.conflict_matrix_delta_compact): O(C·K·W) device work with no
K² term at all, vs the masked delta's K²-shaped grid whose dead blocks
skip work but still launch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BI = 8     # footprint rows per block (sublane dimension)
BJ = 128   # write-set rows per block (lane dimension of the output tile)
BW = 128   # bitset words per block


def _conflict_kernel(foot_ref, write_ref, out_ref):
    """One (BI, BJ) output tile: out[i, j] |= any_w(foot[i, w] & write[j, w])."""
    foot = foot_ref[...]                                   # (BI, BW)
    write = write_ref[...]                                 # (BJ, BW)
    hit = (foot[:, None, :] & write[None, :, :]) != 0      # (BI, BJ, BW)
    tile = hit.sum(axis=2) > 0                             # (BI, BJ)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = tile.astype(jnp.int32)

    @pl.when(pl.program_id(2) != 0)
    def _accum():
        out_ref[...] = out_ref[...] | tile.astype(jnp.int32)


def _conflict_delta_kernel(rowlive_ref, collive_ref, foot_ref, write_ref,
                           old_ref, out_ref):
    """Masked-row variant of :func:`_conflict_kernel` for the incremental
    round update: only entries whose row OR column transaction re-executed
    this round are recomputed; the rest of the tile is carried over from
    ``old_ref``.  Blocks with no live row/column skip the bitset
    intersection entirely (`pl.when` on the tile's refresh mask) — the
    device-work saving that makes carrying the table across rounds pay.
    """
    rl = rowlive_ref[...] != 0                             # (BI, 1)
    cl = collive_ref[...] != 0                             # (BJ, 1)
    refresh = rl | cl.reshape(1, -1)                       # (BI, BJ)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        # stale entries keep the carried verdict; refreshed entries start
        # from 0 and OR-accumulate across the word grid axis below
        out_ref[...] = jnp.where(refresh, 0, old_ref[...])

    @pl.when(refresh.sum() > 0)
    def _accum():
        foot = foot_ref[...]                               # (BI, BW)
        write = write_ref[...]                             # (BJ, BW)
        hit = (foot[:, None, :] & write[None, :, :]) != 0  # (BI, BJ, BW)
        tile = (hit.sum(axis=2) > 0).astype(jnp.int32)     # (BI, BJ)
        out_ref[...] = out_ref[...] | jnp.where(refresh, tile, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def conflict_matrix_bits_delta(foot_bits: jax.Array, write_bits: jax.Array,
                               old: jax.Array, live: jax.Array,
                               *, interpret: bool = False) -> jax.Array:
    """Incremental (K, K) conflict update: recompute only the rows and
    columns of live (re-executed) transactions, carry ``old`` elsewhere.

    foot_bits / write_bits (K, W) int32 must already hold the CURRENT
    round's packed sets (live rows refreshed, settled rows carried —
    see ops.update_packed_footprints); ``old`` (K, K) int32 is last
    round's table and ``live`` (K,) int32 flags the re-executed rows.
    Same padding contract as :func:`conflict_matrix_bits`.
    """
    k, w = foot_bits.shape
    assert k % BI == 0 and k % BJ == 0 and w % BW == 0, (k, w)
    grid = (k // BI, k // BJ, w // BW)
    live_col = live.astype(jnp.int32).reshape(k, 1)
    out = pl.pallas_call(
        _conflict_delta_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BI, 1), lambda i, j, v: (i, 0)),
            pl.BlockSpec((BJ, 1), lambda i, j, v: (j, 0)),
            pl.BlockSpec((BI, BW), lambda i, j, v: (i, v)),
            pl.BlockSpec((BJ, BW), lambda i, j, v: (j, v)),
            pl.BlockSpec((BI, BJ), lambda i, j, v: (i, j)),
        ],
        out_specs=pl.BlockSpec((BI, BJ), lambda i, j, v: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, k), jnp.int32),
        interpret=interpret,
    )(live_col, live_col, foot_bits, write_bits, old)
    return out


@functools.partial(jax.jit, static_argnames=("interpret",))
def conflict_matrix_bits_pair(foot_bits: jax.Array, write_bits: jax.Array,
                              *, interpret: bool = False) -> jax.Array:
    """Rectangular bitset intersection: out (M, N) bool with
    out[i, j] = any_w(foot_bits[i, w] & write_bits[j, w]), for
    foot_bits (M, W) vs write_bits (N, W) over DIFFERENT row sets.

    The gather-compacted round update (ops.conflict_matrix_delta_compact)
    asks exactly this twice per round: a (C, K) row strip — the C live
    footprints against every write set — and a (K, C) column strip — every
    footprint against the C live write sets — instead of the full (K, K)
    product.  M must be a multiple of BI, N of BJ, W of BW (callers pad).
    """
    m, w = foot_bits.shape
    n = write_bits.shape[0]
    assert m % BI == 0 and n % BJ == 0 and w % BW == 0, (m, n, w)
    grid = (m // BI, n // BJ, w // BW)
    out = pl.pallas_call(
        _conflict_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BI, BW), lambda i, j, v: (i, v)),
            pl.BlockSpec((BJ, BW), lambda i, j, v: (j, v)),
        ],
        out_specs=pl.BlockSpec((BI, BJ), lambda i, j, v: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(foot_bits, write_bits)
    return out != 0


@functools.partial(jax.jit, static_argnames=("interpret",))
def conflict_matrix_bits(foot_bits: jax.Array, write_bits: jax.Array,
                         *, interpret: bool = False) -> jax.Array:
    """conflict (K, K) bool — foot_bits (K, W) int32, write_bits (K, W) int32.

    K must be a multiple of lcm(BI, BJ) and W a multiple of BW (callers
    pad; see ops.conflict_matrix).  Row i / column j of the result refer
    to the same transaction ordering as the input rows.  The square case
    of :func:`conflict_matrix_bits_pair`.
    """
    return conflict_matrix_bits_pair(foot_bits, write_bits,
                                     interpret=interpret)
