"""Deterministic data pipeline.

Replica determinism starts at the input: every batch is a pure function
of (seed, step, shard) — no queue timing, no host races.  The stream is
a seeded synthetic token source (Zipf-ish unigram mixture with local
n-gram structure so losses actually decrease) sharded by host; restart
at step k reproduces the identical batch k (checkpoint stores only the
step counter)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


def _fold(seed, *xs) -> np.random.Generator:
    mask = (1 << 64) - 1
    s = int(seed) & mask
    for x in xs:
        s = (s * 6364136223846793005 + int(x)
             + 1442695040888963407) & mask
    return np.random.default_rng(s)


def batch_at(cfg: DataConfig, step: int) -> dict:
    """Batch for ``step`` on this host: {tokens (b, S), labels (b, S)}."""
    assert cfg.global_batch % cfg.n_hosts == 0
    b = cfg.global_batch // cfg.n_hosts
    rng = _fold(cfg.seed, step, cfg.host_id)
    # unigram zipf base
    ranks = rng.zipf(1.3, size=(b, cfg.seq_len))
    tokens = np.minimum(ranks - 1, cfg.vocab - 1).astype(np.int32)
    # inject learnable bigram structure: even positions predict +1
    tokens[:, 1::2] = (tokens[:, 0::2] + 1) % cfg.vocab
    labels = np.concatenate(
        [tokens[:, 1:], np.full((b, 1), -1, np.int32)], axis=1)
    return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}


def stream(cfg: DataConfig, start_step: int = 0):
    step = start_step
    while True:
        yield step, batch_at(cfg, step)
        step += 1
