"""Elastic scaling, deterministically (paper §2.1 applied to workers).

Pot treats thread start/stop as sequenced events; we treat WORKER
join/leave the same way.  The ElasticLaneManager wraps the round-robin
sequencer's lane tree: a joining worker is spawned as a child lane of the
coordinator lane and only starts receiving sequence numbers at a
deterministic point in the order; a leaving worker's lane is stopped the
same way.  Two runs with the same join/leave schedule (in *logical* time,
i.e. sequence positions — not wall-clock) produce identical transaction
orders, so scaling events never fork replicas.

Since PR 9 the manager is wired through ``PotSession`` (the session's
``elastic`` attribute / ``serve(..., elastic=...)``): before executing
the batch formed at index b the session calls ``advance_to(b + 1)`` —
scaling events take effect at *formed-batch boundaries*, which are
positions in the deterministic order — and maps each row's client lane
to a live worker lane via :meth:`worker_for`.  The manager's state
(events + the round cursor) is snapshot-visible
(:meth:`state_dict` / :meth:`from_state`, carried by
``repro.core.checkpoint`` manifests), so a replica restored across a
scaling event numbers lanes identically to the uninterrupted run.
"""

from __future__ import annotations

import dataclasses

from repro.core.sequencer import RoundRobinSequencer


@dataclasses.dataclass
class ScalingEvent:
    at_round: int          # logical round when the event takes effect
    action: str            # "join" | "leave"
    lane_id: int | None = None
    parent: int = 0


class ElasticLaneManager:
    """Deterministic worker pool: schedule(events) -> per-round lane sets
    and a sequencer whose numbering reflects joins/leaves."""

    def __init__(self, n_initial: int, events: list[ScalingEvent] = ()):
        self.n_initial = int(n_initial)
        self.seq = RoundRobinSequencer(n_root_lanes=n_initial)
        self.events = sorted(events, key=lambda e: (e.at_round, e.action,
                                                    e.lane_id or -1))
        self._round = 0

    def advance_to(self, round_idx: int) -> None:
        """Apply all scaling events up to ``round_idx`` (deterministic
        order: sorted by (round, action, lane))."""
        for ev in self.events:
            if self._round < ev.at_round <= round_idx:
                if ev.action == "join":
                    ev.lane_id = self.seq.spawn_lane(ev.parent,
                                                     lane_id=ev.lane_id)
                else:
                    self.seq.stop_lane(ev.lane_id)
        self._round = max(self._round, round_idx)

    def live_lanes(self) -> list[int]:
        return self.seq.lane_order()

    def assign(self, txn_lanes) -> "list[int]":
        return self.seq.order_for(txn_lanes)

    def worker_for(self, key: int) -> int:
        """Deterministically place a client key on a live worker lane:
        modular assignment over the post-order lane traversal.  Pure in
        (key, lane-tree state), so two replicas at the same round map
        every key identically — including across join/leave events."""
        order = self.live_lanes()
        if not order:
            raise RuntimeError(
                "no live worker lanes: every lane has left the pool")
        return order[int(key) % len(order)]

    # ------------------------------------------------- snapshot state
    def state_dict(self) -> dict:
        """JSON-clean state: initial width, the round cursor, and the
        full event schedule (applied join events carry their assigned
        lane ids, so re-application is exact)."""
        return {
            "n_initial": self.n_initial,
            "round": self._round,
            "events": [[e.at_round, e.action, e.lane_id, e.parent]
                       for e in self.events],
        }

    @classmethod
    def from_state(cls, state: dict) -> "ElasticLaneManager":
        """Rebuild a manager at the same round: replays the event
        schedule through a fresh lane tree (spawn/stop are deterministic,
        so the tree — and therefore :meth:`worker_for` — is identical)."""
        mgr = cls(state["n_initial"],
                  [ScalingEvent(int(r), a,
                                None if l is None else int(l), int(p))
                   for r, a, l, p in state["events"]])
        mgr.advance_to(int(state["round"]))
        return mgr
