"""Elastic scaling, deterministically (paper §2.1 applied to workers).

Pot treats thread start/stop as sequenced events; we treat WORKER
join/leave the same way.  The ElasticLaneManager wraps the round-robin
sequencer's lane tree: a joining worker is spawned as a child lane of the
coordinator lane and only starts receiving sequence numbers at a
deterministic point in the order; a leaving worker's lane is stopped the
same way.  Two runs with the same join/leave schedule (in *logical* time,
i.e. sequence positions — not wall-clock) produce identical transaction
orders, so scaling events never fork replicas.
"""

from __future__ import annotations

import dataclasses

from repro.core.sequencer import RoundRobinSequencer


@dataclasses.dataclass
class ScalingEvent:
    at_round: int          # logical round when the event takes effect
    action: str            # "join" | "leave"
    lane_id: int | None = None
    parent: int = 0


class ElasticLaneManager:
    """Deterministic worker pool: schedule(events) -> per-round lane sets
    and a sequencer whose numbering reflects joins/leaves."""

    def __init__(self, n_initial: int, events: list[ScalingEvent] = ()):
        self.seq = RoundRobinSequencer(n_root_lanes=n_initial)
        self.events = sorted(events, key=lambda e: (e.at_round, e.action,
                                                    e.lane_id or -1))
        self._round = 0

    def advance_to(self, round_idx: int) -> None:
        """Apply all scaling events up to ``round_idx`` (deterministic
        order: sorted by (round, action, lane))."""
        for ev in self.events:
            if self._round < ev.at_round <= round_idx:
                if ev.action == "join":
                    ev.lane_id = self.seq.spawn_lane(ev.parent,
                                                     lane_id=ev.lane_id)
                else:
                    self.seq.stop_lane(ev.lane_id)
        self._round = max(self._round, round_idx)

    def live_lanes(self) -> list[int]:
        return self.seq.lane_order()

    def assign(self, txn_lanes) -> "list[int]":
        return self.seq.order_for(txn_lanes)
