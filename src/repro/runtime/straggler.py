"""Straggler mitigation under Pot semantics.

The paper's structure gives stragglers for free: the order-head (fast
transaction) never waits on anyone, and late transactions are speculative
— their work overlaps the wait instead of blocking the commit stream.
This module provides:

- ``simulate_arrivals``: a seeded arrival-delay model (exp-tail) that
  produces arrival permutations for determinism tests — Pot's output must
  be invariant to ALL of them (tests/test_runtime.py).
- ``commit_deadline_policy``: bounded-staleness policy for the training
  integration: a gradient transaction arriving more than ``max_stale``
  sequence positions late is re-based (recomputed against the current
  version) rather than validated — the PCC abort/retry path, surfaced as
  a runtime knob.
"""

from __future__ import annotations

import numpy as np


def simulate_arrivals(n_txns: int, *, n_stragglers: int = 0,
                      tail_factor: float = 10.0, seed: int = 0) -> np.ndarray:
    """Return an arrival permutation: txn indices in arrival order.
    ``n_stragglers`` transactions get an exp-tail delay."""
    rng = np.random.default_rng(seed)
    delay = rng.exponential(1.0, size=n_txns)
    if n_stragglers:
        worst = rng.choice(n_txns, size=n_stragglers, replace=False)
        delay[worst] *= tail_factor
    return np.argsort(delay, kind="stable")


def commit_deadline_policy(seq_no: int, gv: int, *, max_stale: int = 8):
    """Decide how a late transaction commits.

    Returns "fast" (it is the order head), "validate" (speculative,
    within staleness budget — validate read versions and commit), or
    "rebase" (too stale — recompute against the current store)."""
    lag = seq_no - gv - 1
    if lag <= 0:
        return "fast"
    if lag <= max_stale:
        return "validate"
    return "rebase"
