"""Sharding profiles: how (DP/FSDP/TP/EP/SP) map onto the mesh axes.

Axes (launch/mesh.py):
  single-pod  (16, 16)    -> ("data", "model")
  multi-pod   (2, 16, 16) -> ("pod", "data", "model")

The profile below is MaxText-style 2D/3D sharding:
  - DP/FSDP over ("pod", "data"): batch + parameter/optimizer-state
    storage (ZeRO-3 — GSPMD inserts per-layer all-gathers).
  - TP over "model": attention heads, MLP hidden, vocab, experts (EP).
  - SP over "model": sequence dim of activations at layer boundaries
    (Megatron-SP style), and of the KV cache for long-context decode
    when kv_heads < model axis size.
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Profile:
    """Activation/parameter PartitionSpec factory for one mesh shape."""

    data_axes: tuple = ("data",)      # ("pod", "data") when multi-pod
    model_axis: str = "model"
    enabled: bool = True              # False -> no constraints (smoke tests)
    fsdp: bool = True                 # shard params over data axes too
    seq_shard: bool = True            # SP at layer boundaries
    replicated_batch: bool = False    # batch too small to shard (long_500k)
    mesh: object = None               # concrete Mesh for shard_map regions
    pure_dp: bool = False             # use the model axis as extra data:
    # 256-way FSDP, no TP/SP — no activation gathers or partial-sum
    # reductions at all; the winning schedule for <=32B dense at 4k
    # (see EXPERIMENTS.md §Perf)

    @property
    def da(self):
        if self.replicated_batch:
            return None
        if self.pure_dp:
            return tuple(self.data_axes) + (self.model_axis,)
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

    @property
    def ma(self):
        if self.pure_dp:
            return None               # activations never use the TP axis
        return self.model_axis

    # ---- activations ----
    def act_btd(self) -> P:           # (B, S, D) at block boundaries
        return P(self.da, self.ma if self.seq_shard else None, None)

    def act_gathered(self) -> P:      # (B, S, D) sublayer entry: the SP
        # all-gather before column-parallel projections (Megatron-SP)
        return P(self.da, None, None)

    def act_bthd(self) -> P:          # (B, S, H*hd) flat, pre-head-split
        # constrain on the FLAT head dim (always divisible — d_model
        # scale); per-head dims (e.g. arctic's 56 heads, stablelm's kv=8)
        # rarely divide the model axis, GSPMD re-infers after reshape.
        return P(self.da, None, self.ma)

    def act_btf(self) -> P:           # (B, S, F) MLP hidden
        return P(self.da, None, self.ma)

    def act_btv(self) -> P:           # (B, S, V) logits: vocab over TP
        return P(self.da, None, self.ma)

    def batch(self) -> P:             # (B, S) tokens
        return P(self.da, None)

    # ---- parameters (never affected by replicated_batch) ----
    def _fs(self, axis):
        if not self.fsdp:
            return None
        if self.pure_dp:
            return tuple(self.data_axes) + (self.model_axis,)
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

    def embed(self) -> P:             # (V, D): vocab over the model axis
        # (storage; a FSDP'd vocab table would need a full gather at the
        # logit matmul).  Under pure_dp the model axis is free for this.
        return P(self.model_axis, None)

    def head(self) -> P:              # (D, V)
        return P(None, self.model_axis)

    def w_in(self) -> P:              # (D, F) / (D, H*hd)
        if self.pure_dp:
            return P(self._fs(0), None)
        return P(self._fs(0), self.ma)

    def w_out(self) -> P:             # (F, D) / (H*hd, D)
        if self.pure_dp:
            return P(None, self._fs(1))
        return P(self.ma, self._fs(1))

    def bias_ff(self) -> P:           # (F,)
        return P(self.ma)

    def experts_in(self) -> P:       # (E, D, F): EP over model, FSDP
        # storage over data on D, ZeRO-gathered inside the MoE shard_map
        # (the gather's backward is the grad reduce-scatter)
        return P(self.model_axis, self._fs(1), None)

    def experts_out(self) -> P:       # (E, F, D): F over data either way
        # (Megatron contraction split, or FSDP storage to gather at use)
        return P(self.model_axis, self._fs(1), None)

    def vector(self) -> P:            # (D,) norm scales
        return P(None)

    # ---- KV cache (decode) ----
    def cache_kv(self, n_kv: int, model_size: int) -> P:
        # (B, S, KV, hd): shard KV heads over model when divisible,
        # else shard the sequence (context parallelism for long decode).
        if n_kv % model_size == 0 and n_kv >= model_size:
            return P(self.da, None, self.ma, None)
        return P(self.da, self.ma, None, None)


def cons(x, spec: P, profile: Profile, barrier: bool = False):
    """with_sharding_constraint if profile is enabled, else identity.

    barrier=True pins the reshard to THIS value's dtype: XLA otherwise
    commutes dtype converts across collectives and can put f32 on the
    wire where bf16 was annotated (2x collective bytes)."""
    if not profile.enabled:
        return x
    out = jax.lax.with_sharding_constraint(x, spec)
    if barrier:
        out = jax.lax.optimization_barrier(out)
    return out


SMOKE = Profile(enabled=False)
