"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT frontend is a STUB (input_specs provides
precomputed patch embeddings) + InternLM2-20B backbone
[arXiv:2404.16821]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab=92553,
    pattern=("attn",), mlp="swiglu", n_patches=256,
)

SMOKE_CONFIG = ModelConfig(
    name="internvl2-smoke", family="vlm", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
    pattern=("attn",), mlp="swiglu", n_patches=8,
)
