"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000 — RG-LRU + local attention, 2 recurrent : 1
local, window 2048 [arXiv:2402.19427].  38 = 12 groups of
(rglru, rglru, local) + a 2-rglru tail (exact layer count)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, d_ff=12288, vocab=256000, head_dim=256,
    pattern=("rglru", "rglru", "local"), window=2048, mlp="swiglu",
    rnn_width=4096,
)

SMOKE_CONFIG = ModelConfig(
    name="recurrentgemma-smoke", family="hybrid", n_layers=5, d_model=64,
    n_heads=4, n_kv_heads=1, d_ff=128, vocab=128, head_dim=16,
    pattern=("rglru", "rglru", "local"), window=16, mlp="swiglu",
    rnn_width=64,
)
