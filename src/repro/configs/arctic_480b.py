"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128 experts top-2 + dense residual FFN
[hf:Snowflake/snowflake-arctic-base]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=4864, vocab=32000, head_dim=128,
    pattern=("attn",), mlp="swiglu",
    n_experts=128, top_k=2, dense_residual=True, residual_d_ff=4864,
)

SMOKE_CONFIG = ModelConfig(
    name="arctic-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=96, vocab=128, head_dim=16,
    pattern=("attn",), mlp="swiglu",
    n_experts=8, top_k=2, dense_residual=True, residual_d_ff=96,
)
