"""mamba2-370m [ssm]: 48L d_model=1024, attn-free (d_ff=0), vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm", n_layers=48, d_model=1024,
    n_heads=32, n_kv_heads=32, d_ff=0, vocab=50280,
    pattern=("mamba",), mlp="none",
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-smoke", family="ssm", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=128,
    pattern=("mamba",), mlp="none",
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=8,
)
