"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global sliding-window pattern, window=1024,
128k design context.  62 = 10 groups of (5 local + 1 global) + a
2-local-layer tail (exact layer count preserved via the tail mechanism,
models/lm.py)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense", n_layers=62, d_model=5376,
    n_heads=32, n_kv_heads=16, d_ff=21504, vocab=262144, head_dim=128,
    pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024, mlp="swiglu", rope_theta=1_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma3-smoke", family="dense", n_layers=8, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=128, head_dim=16,
    pattern=("local", "local", "local", "local", "local", "attn"),
    window=16, mlp="swiglu",
)
