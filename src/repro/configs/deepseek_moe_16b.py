"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16, MHA) d_ff=1408
per expert, vocab=102400, 64 routed experts top-6 + 2 shared
(fine-grained) [arXiv:2401.06066]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=102400,
    pattern=("attn",), mlp="swiglu",
    n_experts=64, top_k=6, n_shared_experts=2,
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=48, vocab=128,
    pattern=("attn",), mlp="swiglu",
    n_experts=8, top_k=3, n_shared_experts=2, capacity_factor=8.0,
)
