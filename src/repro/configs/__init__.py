"""Assigned architecture configs (--arch <id>) + input shapes.

Each module exports CONFIG (the exact assigned configuration) and
SMOKE_CONFIG (a reduced same-family config for CPU smoke tests).
"""

from __future__ import annotations

import dataclasses
import importlib

ARCHS = [
    "mamba2_370m", "stablelm_12b", "gemma3_27b", "qwen15_32b",
    "starcoder2_15b", "arctic_480b", "deepseek_moe_16b", "whisper_medium",
    "recurrentgemma_9b", "internvl2_26b",
]

# canonical ids (hyphenated) -> module names
IDS = {a.replace("_", "-"): a for a in ARCHS}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    mode: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# long_500k needs sub-quadratic attention: run only for SSM/hybrid archs
LONG_OK = {"mamba2_370m", "recurrentgemma_9b"}


def get_config(arch: str):
    mod = IDS.get(arch, arch)
    return importlib.import_module(f"repro.configs.{mod}").CONFIG


def get_smoke_config(arch: str):
    mod = IDS.get(arch, arch)
    return importlib.import_module(f"repro.configs.{mod}").SMOKE_CONFIG


def cells():
    """All 40 (arch, shape) cells; (runnable, skip_reason) flags."""
    out = []
    for arch in ARCHS:
        for sname, sh in SHAPES.items():
            skip = None
            if sname == "long_500k" and arch not in LONG_OK:
                skip = "full-attention arch: 500k exceeds design envelope"
            out.append((arch, sname, skip))
    return out
