"""whisper-medium [audio]: 24 enc + 24 dec layers, d_model=1024 16H
(kv=16) d_ff=4096 vocab=51865 — enc-dec; conv frontend is a STUB
(input_specs provides precomputed frame embeddings) [arXiv:2212.04356]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=51865,
    pattern=("attn",), mlp="gelu", encoder_layers=24, n_frames=1504,
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-smoke", family="encdec", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
    pattern=("attn",), mlp="gelu", encoder_layers=2, n_frames=16,
)
