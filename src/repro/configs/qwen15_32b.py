"""qwen1.5-32b [dense]: 64L d_model=5120 40H (GQA kv=40 = MHA) d_ff=27392
vocab=152064 — QKV bias [hf:Qwen/Qwen1.5-32B family]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=40, n_kv_heads=40, d_ff=27392, vocab=152064,
    pattern=("attn",), mlp="swiglu", qkv_bias=True,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
    pattern=("attn",), mlp="swiglu", qkv_bias=True,
)
