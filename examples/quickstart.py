"""Quickstart: preordered transactions in 60 seconds, via ``PotSession``.

One API for every engine: a session owns the store and the sequencer,
``session.submit(batch, lanes)`` executes a batch, and every engine —
Pot's PCC, the PoGL serial oracle, the DeSTM analog, the OCC baseline —
returns the same ``ExecTrace`` schema.  The demo shows the paper's core
claims on a toy bank-transfer workload:

1. traditional OCC is nondeterministic — different interleavings
   (modelled as different sequencer orders feeding the ``occ`` engine),
   different final balances;
2. Pot (PCC) is deterministic — any storage permutation of the batch,
   same outcome, equal to the serial PoGL oracle;
3. record/replay — capture an OCC run's commit order with
   ``session.replay_log()``, replay it exactly through Pot.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.core import (READ, RMW, WRITE, PotSession, ReplaySequencer,
                        make_batch)

# 8 accounts, each starting with 100 units
INIT_BALANCES = np.full(8, 100)

# 6 transfer transactions from 3 "threads" (lanes): move 10 from a to b,
# where the destination of the last transfer is data-dependent (indirect)
progs = [
    [(RMW, 0, False, -10), (RMW, 1, False, 10)],     # t0: 0 -> 1
    [(RMW, 1, False, -10), (RMW, 2, False, 10)],     # t1: 1 -> 2
    [(RMW, 2, False, -10), (RMW, 3, False, 10)],     # t2: 2 -> 3
    [(RMW, 3, False, -10), (RMW, 4, False, 10)],     # t0: 3 -> 4
    [(RMW, 4, False, -10), (RMW, 5, False, 10)],     # t1: 4 -> 5
    [(READ, 5, False, 0), (WRITE, 1, True, 0)],      # t2: read 5, write
                                                     # to a dep. address
]
batch = make_batch(progs)
lanes = [0, 1, 2, 0, 1, 2]


def session(engine, sequencer=None, n_lanes=1) -> PotSession:
    return PotSession(8, init=INIT_BALANCES, engine=engine,
                      sequencer=sequencer, n_lanes=n_lanes)


# --- 1. traditional transactions: outcome depends on the interleaving.
# OCC's "order" is whatever arrival interleaving the runtime produced —
# we feed each interleaving in as a replayed order, same submit() call.
fps = set()
for seed in range(6):
    arrival = np.random.default_rng(seed).permutation(6)
    s = session("occ", sequencer=ReplaySequencer(arrival.tolist()))
    s.submit(batch)
    fps.add(s.fingerprint())
print(f"OCC outcomes across 6 interleavings : {len(fps)} distinct")

# --- 2. Pot: the sequencer fixes the order BEFORE execution
pot = session("pcc", n_lanes=3)
trace = pot.submit(batch, lanes)
commit_order = pot.replay_log()   # committed txn order (= sequencer order)

# permuting the *storage order* of the batch must not change the outcome
fps = set()
for seed in range(6):
    perm = np.random.default_rng(seed).permutation(6)
    inv = np.argsort(perm)
    batch_p = jax.tree.map(lambda a: a[perm], batch)
    order_p = [int(inv[t]) for t in commit_order]   # same logical order
    s = session("pcc", sequencer=ReplaySequencer(order_p))
    s.submit(batch_p)
    fps.add(s.fingerprint())
oracle = session("pogl", n_lanes=3)
oracle.submit(batch, lanes)
print(f"Pot outcomes across 6 interleavings : {len(fps)} distinct")
print(f"Pot == serial oracle                : "
      f"{fps == {oracle.fingerprint()}}")
print(f"Pot engine rounds (parallelism)     : {int(trace.rounds)} "
      f"(vs {batch.n_txns} serial steps)")

# --- 3. record/replay (paper §2.1): one line each way
rec = session("occ", sequencer=ReplaySequencer([5, 3, 1, 0, 2, 4]))
rec.submit(batch)
rep = session("pcc", sequencer=rec.replay_sequencer())
rep.submit(batch)
print(f"record/replay reproduces OCC run    : "
      f"{rep.fingerprint() == rec.fingerprint()}")
print(f"final balances                      : "
      f"{np.asarray(rep.store.values)[:, 0].tolist()}")
