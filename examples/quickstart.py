"""Quickstart: preordered transactions in 60 seconds.

Demonstrates the paper's core claims on a toy bank-transfer workload:
1. traditional OCC is nondeterministic — different interleavings,
   different final balances;
2. Pot (PCC) is deterministic — any interleaving, same outcome, equal to
   the serial execution in sequencer order;
3. record/replay — capture an OCC run's commit order, replay it exactly.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (READ, RMW, WRITE, ReplaySequencer,
                        RoundRobinSequencer, fingerprint, make_batch,
                        make_store, occ_execute, pcc_execute, pogl_execute)

# 8 accounts, each starting with 100 units
store = make_store(8, init=np.full(8, 100))

# 6 transfer transactions from 3 "threads" (lanes): move 10 from a to b,
# where the destination of the last transfer is data-dependent (indirect)
progs = [
    [(RMW, 0, False, -10), (RMW, 1, False, 10)],     # t0: 0 -> 1
    [(RMW, 1, False, -10), (RMW, 2, False, 10)],     # t1: 1 -> 2
    [(RMW, 2, False, -10), (RMW, 3, False, 10)],     # t2: 2 -> 3
    [(RMW, 3, False, -10), (RMW, 4, False, 10)],     # t0: 3 -> 4
    [(RMW, 4, False, -10), (RMW, 5, False, 10)],     # t1: 4 -> 5
    [(READ, 5, False, 0), (WRITE, 1, True, 0)],      # t2: read 5, write
                                                     # to a dep. address
]
batch = make_batch(progs)
lanes = [0, 1, 2, 0, 1, 2]

# --- 1. traditional transactions: outcome depends on the interleaving
fps = set()
for seed in range(6):
    arrival = jnp.asarray(np.random.default_rng(seed).permutation(6),
                          jnp.int32)
    out, _ = occ_execute(store, batch, arrival)
    fps.add(int(fingerprint(out)))
print(f"OCC outcomes across 6 interleavings : {len(fps)} distinct")

# --- 2. Pot: sequencer fixes the order BEFORE execution
seqr = RoundRobinSequencer(n_root_lanes=3)
seq = jnp.asarray(seqr.order_for(lanes), jnp.int32)
fps = set()
for seed in range(6):
    perm = np.random.default_rng(seed).permutation(6)
    import jax
    batch_p = jax.tree.map(lambda a: a[perm], batch)
    out, trace = pcc_execute(store, batch_p,
                             jnp.asarray(np.asarray(seq)[perm], jnp.int32))
    fps.add(int(fingerprint(out)))
serial = pogl_execute(store, batch, seq)
print(f"Pot outcomes across 6 interleavings : {len(fps)} distinct")
print(f"Pot == serial oracle                : "
      f"{fps == {int(fingerprint(serial))}}")
print(f"Pot engine rounds (parallelism)     : {int(trace.rounds)} "
      f"(vs {batch.n_txns} serial steps)")

# --- 3. record/replay (paper §2.1)
arrival = jnp.asarray([5, 3, 1, 0, 2, 4], jnp.int32)
occ_out, occ_tr = occ_execute(store, batch, arrival)
order = np.argsort(np.asarray(occ_tr.commit_pos))
replay_seq = jnp.asarray(
    ReplaySequencer(order.tolist()).order_for(lanes), jnp.int32)
replay_out, _ = pcc_execute(store, batch, replay_seq)
print(f"record/replay reproduces OCC run    : "
      f"{int(fingerprint(replay_out)) == int(fingerprint(occ_out))}")
print(f"final balances                      : "
      f"{np.asarray(replay_out.values)[:, 0].tolist()}")
