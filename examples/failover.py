"""Crash a replica mid-stream, restore it, and watch it reconverge
bitwise (PR 9 — the paper's replica-fault-tolerance claim, §1, made
executable).

Pot's determinism is the whole fault-tolerance story: because the
serialization order is fixed BEFORE execution, a replica's state is a
pure function of (arrival journal, drain schedule).  So recovery needs
no coordination protocol — restore the latest crash-consistent snapshot,
feed the arrival-journal suffix the snapshot had not seen, and the
restarted replica lands on the SAME store fingerprint, the SAME commit
log, the SAME formed batches as a replica that never crashed.

Three acts:

1. **Replica A** serves the whole journal uninterrupted (in-process),
   snapshotting after every 2nd formed batch.
2. **Replica B** runs as a real subprocess (``python -m
   repro.core.checkpoint``) with a deterministic :class:`FaultPlan`:
   at formed batch 4, phase "execute", the process SIGKILLs itself —
   no cleanup, no goodbye (rc = -9).
3. **Replica B restarts** (a second subprocess) from B's snapshot
   directory + the shared arrival journal, and its summary payload —
   fingerprint, replay log, per-batch trace digests — is asserted
   bit-identical to A's.

Run:  PYTHONPATH=src python examples/failover.py
"""

import json
import os
import subprocess
import sys
import tempfile

from repro.core import IngressPool, run_replica, trace_digest
from repro.core import workloads as W
from repro.core.checkpoint import snapshot_ids
from repro.core.ingress import programs_from_batch

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_OBJECTS, N_LANES = 64, 6

# -- the shared arrival stream: what replication actually ships ----------
wl = W.counters(n_txns=60, n_objects=N_OBJECTS, n_reads=2, n_writes=2,
                n_lanes=N_LANES, skew=0.7, seed=3)
source = IngressPool(capacity=512)
for i, program in enumerate(programs_from_batch(wl.batch)):
    source.admit(program, lane=i % N_LANES, fee=i % 5)
journal = source.arrival_journal()

kw = dict(n_objects=N_OBJECTS, engine="pcc", n_lanes=N_LANES,
          budgets=[7, 11], snapshot_every=2)

workdir = tempfile.mkdtemp(prefix="pot_failover_")
print(f"workdir: {workdir}")

# -- act 1: replica A, uninterrupted -------------------------------------
a = run_replica(journal, directory=os.path.join(workdir, "a"), **kw)
a_digests = [trace_digest(t) for t in a.session.traces]
print(f"\nreplica A (uninterrupted): {a.session.batches_formed} batches, "
      f"{a.session.snapshots_taken} snapshots, "
      f"fingerprint 0x{a.session.fingerprint():08x}")

# -- act 2: replica B takes a SIGKILL at (batch 4, phase execute) --------
bdir = os.path.join(workdir, "b")
env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
env.setdefault("JAX_COMPILATION_CACHE_DIR",
               os.path.join(tempfile.gettempdir(), "repro_jax_pcache"))
env.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")


def drive(cfg, tag):
    cfg_path = os.path.join(workdir, f"{tag}.json")
    out_path = os.path.join(workdir, f"{tag}_out.json")
    with open(cfg_path, "w") as f:
        json.dump(cfg, f)
    r = subprocess.run(
        [sys.executable, "-m", "repro.core.checkpoint", cfg_path, out_path],
        env=env, cwd=REPO, capture_output=True, text=True)
    return r, out_path


victim = dict(kw, journal=journal, directory=bdir,
              fault={"kill_batch": 4, "kill_phase": "execute"})
r, out_path = drive(victim, "victim")
assert r.returncode == -9 and not os.path.exists(out_path), r.stderr[-2000:]
print(f"\nreplica B: SIGKILLed at (batch 4, 'execute') — rc {r.returncode}, "
      f"snapshots on disk: {snapshot_ids(bdir)}")

# -- act 3: replica B restarts from its latest complete snapshot ---------
r, out_path = drive(dict(kw, journal=journal, directory=bdir, resume=True),
                    "recovery")
assert r.returncode == 0, r.stderr[-2000:]
out = json.loads(open(out_path).read())
print(f"replica B restarted: restored from snapshot {out['restored_from']}, "
      f"replayed {out['recovery_batches']} batches from the journal suffix, "
      f"fingerprint 0x{out['fingerprint'] & 0xffffffff:08x}")

assert out["fingerprint"] == a.session.fingerprint()
assert out["replay_log"] == a.session.replay_log()
assert out["trace_digests"] == \
    a_digests[len(a_digests) - len(out["trace_digests"]):]
assert out["pool_depth"] == 0
print("\nrecovery ≡ uninterrupted: fingerprint, commit log and per-batch "
      "trace digests all bitwise identical — determinism IS the "
      "fault-tolerance protocol")
