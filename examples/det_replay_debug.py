"""Concurrency-bug reproduction via deterministic replay (paper §1).

An order-violation bug (Fig. 1b): one transaction initializes a resource,
another uses it.  Under traditional OCC the bug manifests only in SOME
interleavings — the debugging nightmare Pot removes.  We (1) hunt the bug
under OCC, (2) capture the failing commit order, (3) replay it through
Pot — the failure now reproduces on EVERY run.

Run:  PYTHONPATH=src python examples/det_replay_debug.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (READ, RMW, WRITE, ReplaySequencer, make_batch,
                        make_store, occ_execute, pcc_execute)

# object 0: the resource (0 = uninitialized); object 1: consumer's result
INIT = [(WRITE, 0, False, 42)]          # thread 1: initialize
USE = [(READ, 0, False, 0),             # thread 2: use (assumes init!)
       (WRITE, 1, False, 0)]            # result = resource value
batch = make_batch([INIT, USE])
store = make_store(4)


def buggy(values) -> bool:
    return int(values[1, 0]) != 42      # consumer saw uninitialized 0


# --- 1. bug hunt under traditional transactions
seen = []
for seed in range(8):
    arrival = jnp.asarray(np.random.default_rng(seed).permutation(2),
                          jnp.int32)
    out, tr = occ_execute(store, batch, arrival)
    seen.append((seed, buggy(out.values), np.asarray(tr.commit_pos)))
fails = [s for s in seen if s[1]]
print(f"OCC: bug manifested in {len(fails)}/8 interleavings "
      f"(flaky — {[s[0] for s in fails]})")

# --- 2. capture the failing order, 3. replay deterministically
seed, _, commit_pos = fails[0]
order = np.argsort(commit_pos)
seq = jnp.asarray(ReplaySequencer(order.tolist()).order_for([0, 1]),
                  jnp.int32)
repro = [buggy(pcc_execute(store, batch, seq)[0].values)
         for _ in range(5)]
print(f"Pot replay of failing order: bug reproduces {sum(repro)}/5 runs")
assert all(repro)

# and the FIXED order (init before use) never fails:
seq_fixed = jnp.asarray([1, 2], jnp.int32)
ok = [not buggy(pcc_execute(store, batch, seq_fixed)[0].values)
      for _ in range(5)]
print(f"Pot with init-before-use order:  correct {sum(ok)}/5 runs")
assert all(ok)
