"""Concurrency-bug reproduction via deterministic replay (paper §1).

An order-violation bug (Fig. 1b): one transaction initializes a resource,
another uses it.  Under traditional OCC the bug manifests only in SOME
interleavings — the debugging nightmare Pot removes.  We (1) hunt the bug
under OCC sessions, (2) capture the failing commit order with
``session.replay_log()``, (3) replay it through a Pot session — the
failure now reproduces on EVERY run.  Every step uses the same
``PotSession.submit`` API; only the engine name changes.

Run:  PYTHONPATH=src python examples/det_replay_debug.py
"""

import numpy as np

from repro.core import (READ, WRITE, PotSession, ReplaySequencer, make_batch)

# object 0: the resource (0 = uninitialized); object 1: consumer's result
INIT = [(WRITE, 0, False, 42)]          # thread 1: initialize
USE = [(READ, 0, False, 0),             # thread 2: use (assumes init!)
       (WRITE, 1, False, 0)]            # result = resource value
batch = make_batch([INIT, USE])


def buggy(session: PotSession) -> bool:
    return int(session.store.values[1, 0]) != 42  # saw uninitialized 0


# --- 1. bug hunt under traditional transactions
seen = []
for seed in range(8):
    arrival = np.random.default_rng(seed).permutation(2)
    s = PotSession(4, engine="occ",
                   sequencer=ReplaySequencer(arrival.tolist()))
    s.submit(batch)
    seen.append((seed, buggy(s), s.replay_log()))
fails = [s for s in seen if s[1]]
print(f"OCC: bug manifested in {len(fails)}/8 interleavings "
      f"(flaky — {[s[0] for s in fails]})")

# --- 2. capture the failing order, 3. replay deterministically
seed, _, commit_log = fails[0]
repro = []
for _ in range(5):
    s = PotSession(4, engine="pcc", sequencer=ReplaySequencer(commit_log))
    s.submit(batch)
    repro.append(buggy(s))
print(f"Pot replay of failing order: bug reproduces {sum(repro)}/5 runs")
assert all(repro)

# and the FIXED order (init before use) never fails:
ok = []
for _ in range(5):
    s = PotSession(4, engine="pcc", sequencer=ReplaySequencer([0, 1]))
    s.submit(batch)
    ok.append(not buggy(s))
print(f"Pot with init-before-use order:  correct {sum(ok)}/5 runs")
assert all(ok)
