"""Serve a small model with batched requests under deterministic
commits (the paper's replica-fault-tolerance use case, §1).

Two replica Sessions receive the same requests in DIFFERENT submission
interleavings; because slot commits are preordered (sequencer over slots,
ordered paged commits with version stamps), both replicas emit identical
token streams and identical page-version state.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serve.session import Session

cfg = get_smoke_config("stablelm_12b")
params = lm.init_params(jax.random.PRNGKey(42), cfg)
requests = [(0, 7), (1, 23), (2, 5), (3, 99)]   # (slot, first token)

streams = []
for replica, order in enumerate([requests, requests[::-1]]):
    sess = Session(cfg, params, n_slots=4, max_seq=64)
    for slot, tok in order:              # different arrival interleaving
        sess.add_request(slot, tok)
    toks = sess.generate(12)
    streams.append((toks, sess.fingerprint()))
    print(f"replica {replica}: state fingerprint 0x{sess.fingerprint():08x}")
    for slot, tok in requests:
        print(f"  slot {slot} <- {tok}: {toks[slot].tolist()}")

identical = (np.array_equal(streams[0][0], streams[1][0])
             and streams[0][1] == streams[1][1])
print(f"replicas bitwise identical: {identical}")
assert identical
