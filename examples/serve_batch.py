"""Serve a small model with batched requests under deterministic
commits (the paper's replica-fault-tolerance use case, §1).

Two demos:

1. **Replicated LM serving** — two replica Sessions receive the same
   requests in DIFFERENT submission interleavings; because slot commits
   are preordered (sequencer over slots, ordered paged commits with
   version stamps), both replicas emit identical token streams and
   identical page-version state.

2. **Ragged transactional streaming** (PR 4) — a serving frontend never
   sees neat fixed-size batches: every tick hands the engine however
   many transactions arrived.  ``PotSession`` pads each ragged batch up
   to a power-of-two shape bucket with vacant NOP rows (which provably
   never commit), so the whole stream runs on a handful of compiled
   steps instead of one compile per distinct shape — with a bitwise
   identical store, and replica determinism preserved across different
   raggedness.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import PotSession
from repro.core import workloads as W
from repro.models import lm
from repro.serve.session import Session

cfg = get_smoke_config("stablelm_12b")
params = lm.init_params(jax.random.PRNGKey(42), cfg)
requests = [(0, 7), (1, 23), (2, 5), (3, 99)]   # (slot, first token)

streams = []
for replica, order in enumerate([requests, requests[::-1]]):
    sess = Session(cfg, params, n_slots=4, max_seq=64)
    for slot, tok in order:              # different arrival interleaving
        sess.add_request(slot, tok)
    toks = sess.generate(12)
    streams.append((toks, sess.fingerprint()))
    print(f"replica {replica}: state fingerprint 0x{sess.fingerprint():08x}")
    for slot, tok in requests:
        print(f"  slot {slot} <- {tok}: {toks[slot].tolist()}")

identical = (np.array_equal(streams[0][0], streams[1][0])
             and streams[0][1] == streams[1][1])
print(f"replicas bitwise identical: {identical}")
assert identical

# ---------------------------------------------------------------------------
# Ragged transactional streaming: bucketed submit, no per-shape recompiles
# ---------------------------------------------------------------------------
print("\nragged streaming (PR 4): 16 ticks of 1..48 txns each")
rng = np.random.default_rng(7)
ticks = []
for i in range(16):
    k = int(rng.integers(1, 49))                 # whatever arrived this tick
    wl = W.counters(n_txns=k, n_objects=256, n_reads=2, n_writes=2,
                    n_lanes=4, skew=0.6, seed=50 + i)
    ticks.append((wl.batch, wl.lanes.tolist()))

shapes = sorted({(b.n_txns, b.max_ins) for b, _ in ticks})
sessions = {}
for mode, bucket in (("bucketed", True), ("exact-shape", False)):
    sess = PotSession(256, engine="pcc", n_lanes=4, bucket=bucket)
    for batch, lanes in ticks:
        sess.submit(batch, lanes)
    sessions[mode] = sess
    print(f"  {mode:12s}: {sess.compile_count():2d} compiled steps for "
          f"{len(shapes)} distinct shapes "
          f"(buckets: {sorted(sess.bucket_counts())})")

assert sessions["bucketed"].fingerprint() == \
    sessions["exact-shape"].fingerprint()
assert sessions["bucketed"].replay_log() == \
    sessions["exact-shape"].replay_log()
assert sessions["bucketed"].compile_count() < len(shapes)
print("  bucketed store + commit log bitwise identical to exact-shape run")
