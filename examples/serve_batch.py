"""Serve a small model with batched requests under deterministic
commits (the paper's replica-fault-tolerance use case, §1).

Two demos:

1. **Replicated LM serving** — two replica Sessions receive the same
   requests in DIFFERENT submission interleavings; because slot commits
   are preordered (sequencer over slots, ordered paged commits with
   version stamps), both replicas emit identical token streams and
   identical page-version state.

2. **Ragged transactional streaming** (PR 4) — a serving frontend never
   sees neat fixed-size batches: every tick hands the engine however
   many transactions arrived.  ``PotSession`` pads each ragged batch up
   to a power-of-two shape bucket with vacant NOP rows (which provably
   never commit), so the whole stream runs on a handful of compiled
   steps instead of one compile per distinct shape — with a bitwise
   identical store, and replica determinism preserved across different
   raggedness.

3. **Deterministic ingress** (PR 6) — upstream of the batches: clients
   submit single transactions with fees on per-client lanes into an
   ``IngressPool`` (bounded capacity, logical stamps, no wall-clock).
   The pool's priority drain FORMS the batches, and the drain order is
   a pure function of pool state — so two replicas fed the same arrival
   journal, each draining under its own budget schedule (different
   batch boundaries, different bucket shapes), still emit bit-identical
   stores and commit logs through ``PotSession.serve``.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import PotSession
from repro.core import workloads as W
from repro.models import lm
from repro.serve.session import Session

cfg = get_smoke_config("stablelm_12b")
params = lm.init_params(jax.random.PRNGKey(42), cfg)
requests = [(0, 7), (1, 23), (2, 5), (3, 99)]   # (slot, first token)

streams = []
for replica, order in enumerate([requests, requests[::-1]]):
    sess = Session(cfg, params, n_slots=4, max_seq=64)
    for slot, tok in order:              # different arrival interleaving
        sess.add_request(slot, tok)
    toks = sess.generate(12)
    streams.append((toks, sess.fingerprint()))
    print(f"replica {replica}: state fingerprint 0x{sess.fingerprint():08x}")
    for slot, tok in requests:
        print(f"  slot {slot} <- {tok}: {toks[slot].tolist()}")

identical = (np.array_equal(streams[0][0], streams[1][0])
             and streams[0][1] == streams[1][1])
print(f"replicas bitwise identical: {identical}")
assert identical

# ---------------------------------------------------------------------------
# Ragged transactional streaming: bucketed submit, no per-shape recompiles
# ---------------------------------------------------------------------------
print("\nragged streaming (PR 4): 16 ticks of 1..48 txns each")
rng = np.random.default_rng(7)
ticks = []
for i in range(16):
    k = int(rng.integers(1, 49))                 # whatever arrived this tick
    wl = W.counters(n_txns=k, n_objects=256, n_reads=2, n_writes=2,
                    n_lanes=4, skew=0.6, seed=50 + i)
    ticks.append((wl.batch, wl.lanes.tolist()))

shapes = sorted({(b.n_txns, b.max_ins) for b, _ in ticks})
sessions = {}
for mode, bucket in (("bucketed", True), ("exact-shape", False)):
    sess = PotSession(256, engine="pcc", n_lanes=4, bucket=bucket)
    for batch, lanes in ticks:
        sess.submit(batch, lanes)
    sessions[mode] = sess
    print(f"  {mode:12s}: {sess.compile_count():2d} compiled steps for "
          f"{len(shapes)} distinct shapes "
          f"(buckets: {sorted(sess.bucket_counts())})")

assert sessions["bucketed"].fingerprint() == \
    sessions["exact-shape"].fingerprint()
assert sessions["bucketed"].replay_log() == \
    sessions["exact-shape"].replay_log()
assert sessions["bucketed"].compile_count() < len(shapes)
print("  bucketed store + commit log bitwise identical to exact-shape run")

# ---------------------------------------------------------------------------
# Deterministic ingress (PR 6): one arrival journal, two drain schedules
# ---------------------------------------------------------------------------
from repro.core import READ, WRITE, IngressPool

print("\ndeterministic ingress (PR 6): 60 client txns, 6 lanes, "
      "fee/age priority")
rng = np.random.default_rng(29)
source = IngressPool(capacity=256)
for i in range(60):
    # order-sensitive programs: distinct writes to a hot 16-object set —
    # any drain-order divergence between replicas flips the store
    program = ((READ, int(rng.integers(0, 16)), False, 0),
               (WRITE, int(rng.integers(0, 16)), False, 1 + i))
    source.admit(program, lane=int(rng.integers(0, 6)),
                 fee=int(rng.integers(0, 9)))
arrivals = source.arrival_journal()   # what replication actually ships

replica_runs = []
for name, budgets in (("A: one big drain", [60]),
                      ("B: bursty drains ", [9, 21, 5, 25]),
                      ("C: trickle       ", [8] * 8)):
    pool, _ = IngressPool.replay(arrivals)
    sess = PotSession(16, engine="pcc", n_lanes=6)
    n_batches = 0
    while (fb := pool.drain(budgets[min(n_batches, len(budgets) - 1)])) \
            is not None:
        sess._submit_seq(fb.batch, fb.seq, fb.lanes, ladder=fb.ladder)
        n_batches += 1
    # every admitted transaction was formed into a batch: a non-empty
    # pool here would mean the replicas compared different prefixes
    assert pool.depth == 0, f"replica {name} left {pool.depth} txns parked"
    replica_runs.append((sess.fingerprint(), sess.replay_log()))
    print(f"  replica {name}: {n_batches} batches, "
          f"fingerprint 0x{sess.fingerprint():08x}")

assert replica_runs[0] == replica_runs[1] == replica_runs[2]
print("  all replicas bitwise identical: same drain order, same store, "
      "same commit log — batch boundaries don't matter")
