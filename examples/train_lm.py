"""End-to-end driver: deterministic data-parallel training of a ~100M LM.

The full Pot configuration on a host-device mesh:
- every microbatch gradient is a preordered transaction (ordered commits);
- cross-shard reduction uses the fixed-ring deterministic schedule
  (optim/ordered_reduce.py) inside shard_map — bitwise-reproducible
  regardless of stragglers or restarts;
- checkpoints carry (params, opt, gv, data_step); restart resumes the
  identical serialization order;
- the run verifies determinism live: it re-executes step 1 at the end and
  asserts the recomputed parameters are bitwise identical.

Run (8 simulated devices):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import os
import sys
import time

if "--xla-devices" in sys.argv:
    n = sys.argv[sys.argv.index("--xla-devices") + 1]
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n}")

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ck
from repro.data.pipeline import DataConfig, batch_at
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.config import ModelConfig
from repro.train.train_step import init_state, make_pot_dp_step


def build_config(scale: str) -> ModelConfig:
    if scale == "100m":
        return ModelConfig(
            name="pot-lm-100m", family="dense", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32000,
            pattern=("attn",), mlp="swiglu")
    return ModelConfig(  # ~25m — quick CPU runs
        name="pot-lm-25m", family="dense", n_layers=8, d_model=512,
        n_heads=8, n_kv_heads=4, d_ff=1408, vocab=16384,
        pattern=("attn",), mlp="swiglu")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--scale", choices=["25m", "100m"], default="25m")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/pot_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--xla-devices", type=int, default=None)
    args = ap.parse_args()

    cfg = build_config(args.scale)
    n_dev = len(jax.devices())
    mesh = make_host_mesh(n_dev)
    print(f"model={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"devices={n_dev}")

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    state = init_state(params)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    n_mb = max(1, min(args.microbatches, args.batch // n_dev))
    step_fn = jax.jit(make_pot_dp_step(
        cfg, mesh, n_microbatches=n_mb, lr=3e-4))

    start = 0
    if args.resume and (last := ck.latest_step(args.ckpt_dir)) is not None:
        state, extra = ck.restore(args.ckpt_dir, last, state)
        start = extra["data_step"]
        print(f"resumed from step {start} (gv={int(state.gv)})")

    state_after_1 = None
    t0 = time.time()
    for i in range(start, args.steps):
        state, loss = step_fn(state, batch_at(dcfg, i))
        if i == 0:
            state_after_1 = jax.tree.map(np.asarray, state.params)
        if (i + 1) % 10 == 0 or i == start:
            dt = time.time() - t0
            print(f"step {i+1:4d}  loss {float(loss):.4f}  gv {int(state.gv)}"
                  f"  ({dt/(i-start+1):.2f}s/step)", flush=True)
        if (i + 1) % args.ckpt_every == 0:
            ck.save(args.ckpt_dir, i + 1, state,
                    extra={"data_step": i + 1})
            ck.prune(args.ckpt_dir, keep=2)

    # ---- live determinism audit: replay step 1 from scratch ----
    if start == 0 and state_after_1 is not None:
        replay = init_state(lm.init_params(jax.random.PRNGKey(0), cfg))
        replay, _ = step_fn(replay, batch_at(dcfg, 0))
        same = all(
            np.asarray(a).tobytes() == np.asarray(b).tobytes()
            for a, b in zip(jax.tree.leaves(state_after_1),
                            jax.tree.leaves(replay.params)))
        print(f"replayed step 1 bitwise-identical: {same}")
        assert same


if __name__ == "__main__":
    main()
