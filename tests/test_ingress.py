"""Deterministic ingress: admission pool + priority-drain batch former.

Properties (PR 6):
  I1  Admission: stamps are logical and monotone, per-lane sequence
      numbers preserve program order, empty programs are rejected (the
      vacant-row convention is reserved for bucket padding).
  I2  Drain determinism: the drain order is a pure function of pool
      state — (priority, lane, lane_seq) with only lane heads eligible —
      so it matches an independent oracle, preserves per-lane order,
      is invariant to admission-order permutations within a stamp, and
      is invariant to how a drain prefix is partitioned into budgets.
  I3  Capacity: watermark eviction drops worst-priority lane tails
      deterministically, occupancy never exceeds capacity, and the
      backpressure signal raises at the configured mark.
  I4  Journal: replaying the event journal through a fresh pool
      reproduces the exact FormedBatch stream; the arrival journal fed
      to replicas draining under different budgets yields bit-identical
      stores and replay logs through PotSession.
  I5  serve(): the drain order is the preordered sequence — a served
      stream equals one big submit of the flat drain order.
"""

import numpy as np
import pytest

from repro.core import (RMW, WRITE, IngressPool, JournalError,
                        PotSession, ReplaySequencer)
from repro.core import workloads as W
from repro.core.ingress import dense_bucket, programs_from_batch
from repro.core.txn import next_pow2

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st


def _prog(payload: int, addr: int = 0):
    """A one-write program whose committed value identifies the txn —
    order-sensitive when programs share ``addr`` (last writer wins)."""
    return ((WRITE, addr, False, payload),)


def _payload(program) -> int:
    return program[-1][3]


def _drain_payloads(pool, budgets):
    """Flat drained payload sequence under a budget schedule."""
    out = []
    for b in budgets:
        fb = pool.drain(b)
        if fb is None:
            break
        out.extend(_payload(p) for p in programs_from_batch(fb.batch))
    return out


def _oracle_drain(specs, pool_kwargs):
    """Independent greedy reference: specs = [(lane, fee, program)],
    admitted in order with auto stamps; returns payload drain order.

    Re-implements the documented rule from scratch: only lane heads are
    eligible; best head = smallest (-eff_priority, lane, lane_seq) where
    lane_seq is the per-lane admission index (any per-lane increasing
    numbering is equivalent inside the key, which only compares seqs
    within one lane).
    """
    p = IngressPool(**pool_kwargs)   # only for the priority knobs
    latest = len(specs)              # auto stamps: 1..n
    queues = {}
    for i, (lane, fee, prog) in enumerate(specs):
        queues.setdefault(lane, []).append((i, lane, fee, prog))
    order = []
    while any(queues.values()):
        best, best_key = None, None
        for lane in sorted(queues):
            if not queues[lane]:
                continue
            i, _, fee, prog = queues[lane][0]
            age = ((latest - (i + 1)) // p.age_unit if p.age_unit > 0
                   else 0)
            eff = (fee * p.fee_weight - len(prog) * p.size_weight
                   + age * p.age_weight)
            key = (-eff, lane, i)
            if best_key is None or key < best_key:
                best_key, best = key, lane
        order.append(_payload(queues[best].pop(0)[3]))
    return order


# --------------------------------------------------------- admission (I1)
def test_admit_basic_and_stamps_monotone():
    pool = IngressPool(capacity=16)
    r0 = pool.admit(_prog(1), lane=0, fee=2)
    r1 = pool.admit(_prog(2), lane=0, fee=9)
    assert r0.admitted and r1.admitted
    assert r0.txn_id == 0 and r1.txn_id == 1
    assert r1.stamp > r0.stamp
    assert pool.depth == 2
    # explicit stamps: equal OK (a group), regression is an error
    r2 = pool.admit(_prog(3), lane=1, stamp=r1.stamp)
    assert r2.stamp == r1.stamp
    with pytest.raises(ValueError, match="non-decreasing"):
        pool.admit(_prog(4), lane=1, stamp=r1.stamp - 1)


def test_empty_program_rejected():
    pool = IngressPool(capacity=4)
    with pytest.raises(ValueError, match="vacant"):
        pool.admit((), lane=0)


def test_stopped_lane_rejects_but_parked_txns_drain():
    pool = IngressPool(capacity=16)
    pool.admit(_prog(1), lane=0)
    pool.admit(_prog(2), lane=0)
    pool.stop_lane(0)
    r = pool.admit(_prog(3), lane=0)
    assert not r.admitted and r.reason == "lane stopped"
    assert pool.stats.rejected == 1
    assert _drain_payloads(pool, [8]) == [1, 2]   # program order survives


def test_spawn_lane_tree_and_duplicate_guard():
    pool = IngressPool(capacity=16)
    pool.spawn_lane(0)
    pool.spawn_lane(7, parent=0)
    with pytest.raises(ValueError, match="already exists"):
        pool.spawn_lane(7)
    pool.admit(_prog(1), lane=7)
    assert pool.depth == 1


# ----------------------------------------------------- drain order (I2)
def test_drain_is_priority_order_with_lane_seq_tiebreak():
    pool = IngressPool(capacity=64, age_unit=0)
    # fees pick the order; equal fees tie-break by (lane, lane_seq)
    pool.admit(_prog(10), lane=2, fee=1)
    pool.admit(_prog(11), lane=1, fee=5)
    pool.admit(_prog(12), lane=3, fee=5)
    pool.admit(_prog(13), lane=1, fee=5)
    assert _drain_payloads(pool, [8]) == [11, 13, 12, 10]


def test_per_lane_program_order_preserved():
    rng = np.random.default_rng(5)
    pool = IngressPool(capacity=512)
    lanes_of = {}
    for i in range(120):
        lane = int(rng.integers(0, 5))
        pool.admit(_prog(i), lane=lane, fee=int(rng.integers(0, 6)))
        lanes_of[i] = lane
    flat = _drain_payloads(pool, [7] * 64)
    assert sorted(flat) == list(range(120))
    for lane in range(5):
        mine = [p for p in flat if lanes_of[p] == lane]
        assert mine == sorted(mine)   # admission order within the lane


def test_within_stamp_permutation_invariance():
    """Admitting a group of distinct-lane txns under one stamp in any
    order drains identically: the drain key never consults arrival
    interleaving (per-lane order only binds txns of the SAME lane)."""
    group = [(_prog(100 + i), i, (i * 7) % 4) for i in range(12)]
    rng = np.random.default_rng(11)
    ref = None
    for trial in range(4):
        pool = IngressPool(capacity=64)
        pool.admit(_prog(0), lane=0, fee=1)          # pre-existing txn
        perm = rng.permutation(len(group)) if trial else range(len(group))
        pool.admit_many([group[j] for j in perm], stamp=5)
        flat = _drain_payloads(pool, [5] * 8)
        if ref is None:
            ref = flat
        assert flat == ref


def test_budget_partition_invariance():
    """drain(3); drain(5) == drain(8): partitioning a drain prefix into
    budgets cannot change the flat sequence (the greedy key is pure in
    pool state and stamps don't advance on drain)."""
    def fill(pool):
        rng = np.random.default_rng(23)
        for i in range(60):
            pool.admit(_prog(i), lane=int(rng.integers(0, 7)),
                       fee=int(rng.integers(0, 9)))
    a, b, c = (IngressPool(capacity=256) for _ in range(3))
    for p in (a, b, c):
        fill(p)
    flat_a = _drain_payloads(a, [60])
    flat_b = _drain_payloads(b, [3, 5, 8, 13, 21, 34])
    flat_c = _drain_payloads(c, [1] * 60)
    assert flat_a == flat_b == flat_c
    assert sorted(flat_a) == list(range(60))


def test_drain_matches_independent_oracle():
    rng = np.random.default_rng(31)
    specs = [(int(rng.integers(0, 5)), int(rng.integers(0, 7)),
              _prog(i, addr=i % 3) * int(rng.integers(1, 4)))
             for i in range(40)]
    kwargs = dict(capacity=256, fee_weight=16, age_weight=1, age_unit=8,
                  size_weight=1)
    pool = IngressPool(**kwargs)
    for lane, fee, prog in specs:
        pool.admit(prog, lane=lane, fee=fee)
    assert _drain_payloads(pool, [9] * 8) == _oracle_drain(specs, kwargs)


def test_age_pressure_promotes_starving_txns():
    """A parked low-fee txn outranks a fresh higher-fee one once enough
    logical time (stamps) has passed — anti-starvation, no wall-clock."""
    kwargs = dict(capacity=512, fee_weight=2, age_weight=1, age_unit=10,
                  size_weight=0)
    fresh = IngressPool(**kwargs)
    fresh.admit(_prog(1), lane=0, fee=0, stamp=1)
    fresh.admit(_prog(2), lane=1, fee=3, stamp=2)
    # barely aged: eff(1) = (2-1)//10 = 0 < eff(2) = 6 -> fee wins
    assert _drain_payloads(fresh, [2]) == [2, 1]
    aged = IngressPool(**kwargs)
    aged.admit(_prog(1), lane=0, fee=0, stamp=1)
    aged.admit(_prog(2), lane=1, fee=3, stamp=100)
    # starved: eff(1) = (100-1)//10 = 9 > eff(2) = 6 -> age wins
    assert _drain_payloads(aged, [2]) == [1, 2]


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 5),
                          st.integers(1, 3)),
                min_size=1, max_size=40),
       st.integers(1, 9))
def test_drain_tiebreak_property(specs_raw, budget):
    """Hypothesis: drain == oracle for arbitrary (lane, fee, size)
    mixes and budgets, and per-lane order is always preserved."""
    specs = [(lane, fee, _prog(100 + i) * size)
             for i, (lane, fee, size) in enumerate(specs_raw)]
    kwargs = dict(capacity=1024, age_unit=4)
    pool = IngressPool(**kwargs)
    for lane, fee, prog in specs:
        pool.admit(prog, lane=lane, fee=fee)
    flat = _drain_payloads(pool, [budget] * len(specs))
    assert flat == _oracle_drain(specs, kwargs)
    for lane in {l for l, _, _ in specs}:
        mine = [p for p in flat
                if specs[p - 100][0] == lane]
        assert mine == sorted(mine)


# ------------------------------------------- capacity + watermark (I3)
def test_watermark_eviction_drops_worst_tails():
    pool = IngressPool(capacity=8, evict_to=6, age_unit=0)
    for i in range(8):
        pool.admit(_prog(i), lane=i % 2, fee=5)
    assert pool.depth == 8
    r = pool.admit(_prog(99), lane=0, fee=9)      # 9th: evict down to 6
    assert pool.depth == 6
    assert r.admitted                              # high fee: it survives
    assert len(r.evicted) == 3 and pool.stats.evicted == 3
    # evicted are the worst lane tails (fee 5, latest per-lane seqs):
    # lane 1 lost 7 then 5 then 3; survivors keep program order, and 99
    # — despite top priority — drains AFTER its lane-0 predecessors
    # (only lane heads are eligible: program order beats priority)
    assert _drain_payloads(pool, [16]) == [0, 2, 4, 6, 99, 1]


def test_incoming_txn_can_lose_the_eviction():
    pool = IngressPool(capacity=4, evict_to=4, age_unit=0)
    for i in range(4):
        pool.admit(_prog(i), lane=0, fee=9)
    r = pool.admit(_prog(99), lane=1, fee=0)       # worst of the five
    assert not r.admitted and r.evicted == (r.txn_id,)
    assert r.reason == "evicted at admission"
    assert pool.depth == 4
    assert 99 not in _drain_payloads(pool, [8])


def test_depth_never_exceeds_capacity_and_backpressure_signal():
    pool = IngressPool(capacity=16, evict_to=12, backpressure_at=10)
    saw_bp = False
    for i in range(40):
        assert pool.depth <= pool.capacity
        r = pool.admit(_prog(i), lane=i % 3, fee=i % 5)
        saw_bp |= r.backpressure
    assert pool.depth <= pool.capacity
    assert saw_bp and pool.backpressure
    assert pool.stats.backpressure_admits > 0
    assert pool.observables()["backpressure"] == 1


def test_eviction_is_deterministic_across_replicas():
    def run():
        pool = IngressPool(capacity=12, evict_to=9)
        rng = np.random.default_rng(7)
        for i in range(50):
            pool.admit(_prog(i), lane=int(rng.integers(0, 4)),
                       fee=int(rng.integers(0, 8)))
        return _drain_payloads(pool, [4] * 8), pool.stats.evicted
    (flat_a, ev_a), (flat_b, ev_b) = run(), run()
    assert flat_a == flat_b and ev_a == ev_b and ev_a > 0


# -------------------------------------------------------- journal (I4)
def _interleaved_pool():
    pool = IngressPool(capacity=24, evict_to=18)
    rng = np.random.default_rng(13)
    formed = []
    pool.spawn_lane(0)
    pool.spawn_lane(1)
    for step in range(6):
        if step == 2:
            pool.spawn_lane(5, parent=0)          # lane joins mid-stream
        if step == 4:
            pool.stop_lane(1)                     # lane leaves mid-stream
        for i in range(8):
            pool.admit(_prog(100 * step + i),
                       lane=int(rng.integers(0, 2)) if step < 2 else
                       int(rng.choice([0, 1, 5])),
                       fee=int(rng.integers(0, 6)))
        fb = pool.drain(int(rng.integers(3, 9)))
        if fb is not None:
            formed.append(fb)
    formed.extend(pool.drain_all(16))
    return pool, formed


def test_journal_replay_reproduces_formed_batches_exactly():
    pool, formed = _interleaved_pool()
    replayed_pool, replayed = IngressPool.replay(pool.journal())
    assert len(replayed) == len(formed)
    for a, b in zip(formed, replayed):
        np.testing.assert_array_equal(a.txn_ids, b.txn_ids)
        np.testing.assert_array_equal(a.seq, b.seq)
        np.testing.assert_array_equal(a.lanes, b.lanes)
        np.testing.assert_array_equal(a.stamps, b.stamps)
        assert a.ladder == b.ladder
        assert programs_from_batch(a.batch) == programs_from_batch(b.batch)
    assert replayed_pool.depth == pool.depth
    # rejected admissions are non-events (never journaled), so they are
    # the one observable a replay cannot — and need not — reproduce
    obs_a, obs_b = pool.observables(), replayed_pool.observables()
    obs_a.pop("rejected"), obs_b.pop("rejected")
    assert obs_a == obs_b
    # the replayed pool's journal is the original journal
    assert replayed_pool.journal() == pool.journal()


def test_journal_requires_config_head():
    pool, _ = _interleaved_pool()
    with pytest.raises(ValueError, match="config"):
        IngressPool.replay(pool.journal()[1:])


def test_two_replicas_same_arrivals_different_budgets_bitwise():
    """The acceptance property: replicas fed the same arrival journal,
    drained under different budget schedules covering the same (full)
    prefix, produce bit-identical stores and replay logs through
    PotSession.  Programs write distinct values to a shared address, so
    any order divergence would flip the fingerprint."""
    src = IngressPool(capacity=256)
    rng = np.random.default_rng(17)
    for i in range(48):
        src.admit(((RMW, int(rng.integers(0, 8)), False, i),
                   (WRITE, int(rng.integers(0, 8)), False, 1000 + i)),
                  lane=int(rng.integers(0, 6)), fee=int(rng.integers(0, 9)))
    arrivals = src.arrival_journal()
    results = []
    for budgets in ([48], [5, 9, 3, 31], [7] * 7):
        pool, _ = IngressPool.replay(arrivals)
        session = PotSession(16, engine="pcc", n_lanes=6)
        n = 0
        for b in budgets:
            fb = pool.drain(b)
            if fb is None:
                break
            session._submit_seq(fb.batch, fb.seq, fb.lanes,
                                ladder=fb.ladder)
            n += fb.n_txns
        assert n == 48 and pool.depth == 0
        results.append((session.fingerprint(), session.replay_log()))
    assert results[0] == results[1] == results[2]


# ---------------------------------------------------------- serve (I5)
def test_serve_equals_flat_submit_of_drain_order():
    wl = W.counters(n_txns=30, n_objects=32, n_reads=2, n_writes=2,
                    n_lanes=4, skew=0.8, seed=9)
    progs = programs_from_batch(wl.batch)
    rng = np.random.default_rng(2)
    fees = [int(rng.integers(0, 5)) for _ in progs]

    pool = IngressPool(capacity=64)
    for p, lane, fee in zip(progs, wl.lanes.tolist(), fees):
        pool.admit(p, lane=lane, fee=fee)
    # the flat drain order, from an identically-fed twin
    twin, _ = IngressPool.replay(pool.arrival_journal())
    fb = twin.drain(64)
    assert fb.n_txns == 30

    served = PotSession(32, engine="pcc", n_lanes=4)
    traces = served.serve(pool, budget=11)
    assert len(traces) == 3 and pool.depth == 0
    # one big submit of the drain order == the served stream
    flat = PotSession(32, engine="pcc", n_lanes=4,
                      sequencer=ReplaySequencer(
                          np.argsort(fb.seq, kind="stable").tolist()))
    flat.submit(fb.batch, fb.lanes.tolist())
    assert flat.fingerprint() == served.fingerprint()
    assert served.n_txns == 30


def test_serve_max_batches_and_empty_pool():
    pool = IngressPool(capacity=16)
    session = PotSession(8, engine="pcc")
    assert session.serve(pool, budget=4) == []      # empty pool: no-op
    for i in range(10):
        pool.admit(_prog(i, addr=i % 8), lane=0, fee=0)
    traces = session.serve(pool, budget=4, max_batches=2)
    assert len(traces) == 2 and pool.depth == 2


def test_occupancy_driven_ladder_selection():
    # mid-size tails (pow2 waste >= 2x dense waste) steer to dense
    pool = IngressPool(capacity=2048)
    for i in range(33 * 4):
        pool.admit(_prog(i), lane=0, fee=0)
    for fb in pool.drain_all(33):                  # 33 pads to 64 vs 40
        assert fb.ladder == "dense"
    assert next_pow2(33) - 33 >= 2 * (dense_bucket(33) - 33)
    # pow2-sized drains stay pow2 (zero waste either way)
    pool2 = IngressPool(capacity=2048)
    for i in range(64 * 3):
        pool2.admit(_prog(i), lane=0, fee=0)
    for fb in pool2.drain_all(64):
        assert fb.ladder == "pow2"


def test_serve_uses_ladder_recommendation_in_bucket_counts():
    pool = IngressPool(capacity=2048)
    for i in range(33):
        pool.admit(_prog(i, addr=i % 16), lane=0, fee=0)
    session = PotSession(16, engine="pcc")
    session.serve(pool, budget=33)
    assert (40, 1) in session.bucket_counts()      # dense bucket, not 64
    # pinning the ladder overrides the recommendation
    pool2, _ = IngressPool.replay(pool.arrival_journal())
    session2 = PotSession(16, engine="pcc")
    session2.serve(pool2, budget=33, ladder="pow2")
    assert (64, 1) in session2.bucket_counts()
    assert session.fingerprint() == session2.fingerprint()


# ------------------------------------------------- hygiene + metrics
def test_no_wall_clock_or_rng_in_ingress_module():
    """The no-wall-clock rule, mechanically: the ingress module must not
    import time/random sources — all ordering is logical."""
    import inspect

    import repro.core.ingress as ingress
    src = inspect.getsource(ingress)
    for needle in ("import time", "import random", "datetime",
                   "perf_counter", "default_rng"):
        assert needle not in src, needle


def test_metrics_csv_carries_ingress_observables():
    from repro.core import make_store, run_all
    from repro.core import metrics as M

    wl = W.counters(n_txns=12, n_objects=32, n_lanes=4, seed=4)
    pool = IngressPool(capacity=64)
    for p, lane in zip(programs_from_batch(wl.batch), wl.lanes.tolist()):
        pool.admit(p, lane=lane, fee=1)
    session = PotSession(32, engine="pcc", n_lanes=4)
    fb = pool.drain(12)
    trace = session._submit_seq(fb.batch, fb.seq, fb.lanes,
                                ladder=fb.ladder)
    res = run_all(fb.batch, make_store(32).values)
    rep = M.report_from_trace("pcc", trace, fb.batch,
                              np.asarray(res.rn), np.asarray(res.wn),
                              session=session, pool=pool)
    assert rep.admitted == 12 and rep.drained == 12
    assert rep.queue_depth == 0 and rep.evicted == 0
    row = rep.row()
    assert len(row.split(",")) == len(M.HEADER.split(","))


# -- defensive journal loading (PR 9): corrupt/reordered journals are a
#    JournalError with a pointed message, never a silent divergence ------
def _good_journal():
    pool, _ = _interleaved_pool()
    return pool.journal()


def test_replay_rejects_empty_journal():
    with pytest.raises(JournalError, match="empty"):
        IngressPool.replay([])


def test_replay_rejects_config_key_mismatch():
    j = _good_journal()
    kind, cfg = j[0]
    bad = dict(cfg)
    bad.pop(next(iter(cfg)))
    with pytest.raises(JournalError, match="config"):
        IngressPool.replay([(kind, bad)] + list(j[1:]))
    bad = dict(cfg, bogus_knob=1)
    with pytest.raises(JournalError, match="config"):
        IngressPool.replay([(kind, bad)] + list(j[1:]))


def test_replay_rejects_mid_journal_config():
    j = list(_good_journal())
    j.insert(len(j) // 2, j[0])
    with pytest.raises(JournalError, match="concatenated|reordered"):
        IngressPool.replay(j)


def test_replay_rejects_truncated_and_unknown_events():
    j = list(_good_journal())
    i = next(k for k, ev in enumerate(j) if ev[0] == "admit")
    with pytest.raises(JournalError, match="field"):
        IngressPool.replay(j[:i] + [j[i][:3]] + j[i + 1:])
    with pytest.raises(JournalError, match="unknown"):
        IngressPool.replay(j[:i] + [("commit", 0)] + j[i + 1:])
    with pytest.raises(JournalError, match="event"):
        IngressPool.replay(j[:i] + ["admit"] + j[i + 1:])


def test_replay_rejects_non_int_fields_and_bad_programs():
    j = list(_good_journal())
    i = next(k for k, ev in enumerate(j) if ev[0] == "admit")
    kind, stamp, lane, fee, program = j[i]

    def swap(ev):
        return j[:i] + [ev] + j[i + 1:]

    with pytest.raises(JournalError, match="int"):
        IngressPool.replay(swap((kind, "soon", lane, fee, program)))
    with pytest.raises(JournalError, match="int"):
        IngressPool.replay(swap((kind, stamp, True, fee, program)))
    with pytest.raises(JournalError, match="no program"):
        IngressPool.replay(swap((kind, stamp, lane, fee, ())))
    torn = (program[0][:3],) + tuple(program[1:])
    with pytest.raises(JournalError, match="instruction"):
        IngressPool.replay(swap((kind, stamp, lane, fee, torn)))


def test_replay_wraps_semantic_errors_as_journal_error():
    """A structurally well-formed event that the pool itself rejects
    (decreasing stamp, unknown lane) marks a reordered/corrupted
    journal — surfaced as JournalError, not a bare internal error."""
    j = list(_good_journal())
    idx = [k for k, ev in enumerate(j) if ev[0] == "admit"]
    i, l = idx[0], idx[-1]
    reordered = list(j)
    reordered[i], reordered[l] = reordered[l], reordered[i]
    with pytest.raises(JournalError, match="reordered|corrupted"):
        IngressPool.replay(reordered)
    # a lane event against an impossible lane tree (stop of a lane that
    # was never spawned) is wrapped too, not a bare KeyError
    with pytest.raises(JournalError, match="reordered|corrupted"):
        IngressPool.replay(j[:i] + [("stop", 999)] + j[i:])


def test_admit_rejects_malformed_program_instruction():
    pool = IngressPool(capacity=8)
    with pytest.raises(ValueError, match="instruction"):
        pool.admit(((RMW, 0, 1),), lane=0)


def test_journal_error_is_a_value_error():
    # callers that predate PR 9 catch ValueError; keep them working
    assert issubclass(JournalError, ValueError)
