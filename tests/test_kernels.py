"""Per-kernel allclose sweeps against the pure-jnp oracles (ref.py),
executed in interpret mode on CPU (kernels target TPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.conflict import conflict_matrix_bits
from repro.kernels import conflict as conflict_mod
from repro.kernels.validate import BK, BW, pack_addr_sets, validate_bitsets


# ---------------------------------------------------------------- validate
@pytest.mark.parametrize("k,l,n_objects", [
    (1, 1, 32), (8, 4, 64), (13, 6, 300), (32, 16, 4096), (40, 3, 8192),
])
def test_validate_sweep(k, l, n_objects):
    rng = np.random.default_rng(k * 31 + l)
    ra = np.asarray(rng.integers(0, n_objects, (k, l)), np.int32)
    rn = np.asarray(rng.integers(0, l + 1, (k,)), np.int32)
    wa = np.asarray(rng.integers(0, n_objects, (max(2 * l, 4),)), np.int32)
    wn = int(rng.integers(0, len(wa) + 1))
    out = np.asarray(ops.validate(
        jnp.asarray(ra), jnp.asarray(rn), jnp.asarray(wa),
        jnp.asarray(wn, jnp.int32), n_objects))
    exp = np.array([
        bool(set(ra[i, :rn[i]].tolist()) & set(wa[:wn].tolist()))
        for i in range(k)])
    np.testing.assert_array_equal(out, exp)


def test_validate_kernel_vs_ref_dense():
    rng = np.random.default_rng(0)
    k, w = 4 * BK, 2 * BW
    read_bits = jnp.asarray(rng.integers(0, 2**31, (k, w)), jnp.int32)
    written = jnp.asarray(rng.integers(0, 2, (w,)) *
                          rng.integers(0, 2**31, (w,)), jnp.int32)
    out = validate_bitsets(read_bits, written, interpret=True)
    exp = ref.validate_bitsets_ref(read_bits, written)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_fast_mode_skips_validation_semantics():
    """The head transaction needs no validation: with an empty written set
    nothing ever conflicts (progress guarantee of ordered commits)."""
    ra = jnp.asarray(np.arange(24).reshape(8, 3), jnp.int32)
    rn = jnp.full((8,), 3, jnp.int32)
    wa = jnp.zeros((4,), jnp.int32)
    out = ops.validate(ra, rn, wa, jnp.asarray(0, jnp.int32), 64)
    assert not np.asarray(out).any()


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 20), st.integers(1, 8), st.sampled_from([33, 64, 257]))
def test_validate_property(k, l, n_objects):
    rng = np.random.default_rng(k * 131 + l * 7 + n_objects)
    ra = np.asarray(rng.integers(0, n_objects, (k, l)), np.int32)
    rn = np.asarray(rng.integers(0, l + 1, (k,)), np.int32)
    wa = np.asarray(rng.integers(0, n_objects, (l,)), np.int32)
    wn = int(rng.integers(0, l + 1))
    out = np.asarray(ops.validate(
        jnp.asarray(ra), jnp.asarray(rn), jnp.asarray(wa),
        jnp.asarray(wn, jnp.int32), n_objects))
    exp = np.array([
        bool(set(ra[i, :rn[i]].tolist()) & set(wa[:wn].tolist()))
        for i in range(k)])
    np.testing.assert_array_equal(out, exp)


# --------------------------------------------------------- conflict matrix
def test_conflict_matrix_kernel_vs_bits_ref():
    rng = np.random.default_rng(5)
    k = max(conflict_mod.BI, conflict_mod.BJ)
    w = conflict_mod.BW
    foot = jnp.asarray(rng.integers(0, 2**31, (k, w)), jnp.int32)
    write = jnp.asarray((rng.random((k, w)) < 0.05) *
                        rng.integers(0, 2**31, (k, w)), jnp.int32)
    foot = foot | write  # footprints include the write set
    out = conflict_matrix_bits(foot, write, interpret=True)
    exp = ref.conflict_matrix_bits_ref(foot, write)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_conflict_matrix_kernel_multiblock_accumulate():
    """Conflicts living in different word blocks must OR across the W grid
    axis (2 * BW words => two accumulation steps per tile)."""
    k = max(conflict_mod.BI, conflict_mod.BJ)
    w = 2 * conflict_mod.BW
    foot = np.zeros((k, w), np.int32)
    write = np.zeros((k, w), np.int32)
    foot[3, conflict_mod.BW + 7] = 1 << 11      # hit only in the 2nd block
    write[5, conflict_mod.BW + 7] = 1 << 11
    out = np.asarray(conflict_matrix_bits(
        jnp.asarray(foot), jnp.asarray(write), interpret=True))
    exp = np.zeros((k, k), bool)
    exp[3, 5] = True
    np.testing.assert_array_equal(out, exp)


@pytest.mark.parametrize("k,l,n_objects", [
    (1, 1, 32), (7, 4, 64), (20, 6, 300), (33, 3, 4096),
])
def test_conflict_matrix_op_vs_sets(k, l, n_objects):
    """ops.conflict_matrix (whichever backend path) == set intersection."""
    rng = np.random.default_rng(k * 13 + l)
    ra = np.asarray(rng.integers(0, n_objects, (k, l)), np.int32)
    rn = np.asarray(rng.integers(0, l + 1, (k,)), np.int32)
    wa = np.asarray(rng.integers(0, n_objects, (k, l)), np.int32)
    wn = np.asarray(rng.integers(0, l + 1, (k,)), np.int32)
    out = np.asarray(ops.conflict_matrix(
        jnp.asarray(ra), jnp.asarray(rn), jnp.asarray(wa), jnp.asarray(wn),
        n_objects))
    foot = [set(ra[i, :rn[i]].tolist()) | set(wa[i, :wn[i]].tolist())
            for i in range(k)]
    writes = [set(wa[j, :wn[j]].tolist()) for j in range(k)]
    exp = np.array([[bool(foot[i] & writes[j]) for j in range(k)]
                    for i in range(k)])
    np.testing.assert_array_equal(out, exp)


def test_conflict_matrix_paths_agree():
    """The dense-mask fallback and the bit-packed kernel formulation give
    identical verdicts."""
    rng = np.random.default_rng(11)
    k, l, n_objects = 17, 5, 130
    ra = jnp.asarray(rng.integers(0, n_objects, (k, l)), jnp.int32)
    rn = jnp.asarray(rng.integers(0, l + 1, (k,)), jnp.int32)
    wa = jnp.asarray(rng.integers(0, n_objects, (k, l)), jnp.int32)
    wn = jnp.asarray(rng.integers(0, l + 1, (k,)), jnp.int32)
    dense = np.asarray(ops._conflict_matrix_dense(ra, rn, wa, wn, n_objects))
    read_bits = pack_addr_sets(ra, rn, n_objects)
    write_bits = pack_addr_sets(wa, wn, n_objects)
    foot_bits = read_bits | write_bits
    rows = max(conflict_mod.BI, conflict_mod.BJ)
    pad_r = (-k) % rows
    pad_w = (-foot_bits.shape[1]) % conflict_mod.BW
    pad = lambda x: jnp.pad(x, ((0, pad_r), (0, pad_w)))
    packed = np.asarray(conflict_matrix_bits(
        pad(foot_bits), pad(write_bits), interpret=True))[:k, :k]
    np.testing.assert_array_equal(dense, packed)


@pytest.mark.parametrize("live_frac", [0.0, 0.3, 1.0])
def test_conflict_matrix_delta_kernel_vs_full(live_frac):
    """The masked-row delta kernel recomputes exactly the live rows and
    columns and carries the stale entries (incl. the all-dead and
    all-live extremes)."""
    rng = np.random.default_rng(int(live_frac * 10) + 1)
    k = max(conflict_mod.BI, conflict_mod.BJ)
    w = 2 * conflict_mod.BW   # two word blocks: delta must OR-accumulate
    mk = lambda d: jnp.asarray((rng.random((k, w)) < d) *
                               rng.integers(0, 2**31, (k, w)), jnp.int32)
    old_write = mk(0.05)
    old_foot = mk(0.2) | old_write
    old = conflict_matrix_bits(old_foot, old_write,
                               interpret=True).astype(jnp.int32)
    live = jnp.asarray(rng.random(k) < live_frac, jnp.int32)
    keep = live[:, None].astype(bool)
    new_write = jnp.where(keep, mk(0.05), old_write)
    new_foot = jnp.where(keep, mk(0.2) | new_write, old_foot)
    got = np.asarray(conflict_mod.conflict_matrix_bits_delta(
        new_foot, new_write, old, live, interpret=True)) != 0
    full = np.asarray(conflict_matrix_bits(new_foot, new_write,
                                           interpret=True))
    lv = np.asarray(live).astype(bool)
    exp = np.where(lv[:, None] | lv[None, :], full, np.asarray(old) != 0)
    np.testing.assert_array_equal(got, exp)


def test_conflict_matrix_pair_kernel_rectangular():
    """The rectangular pair kernel (the compacted delta's strip primitive)
    over different row sets == the pure-jnp reference."""
    rng = np.random.default_rng(21)
    m, n = 2 * conflict_mod.BI, conflict_mod.BJ
    w = 2 * conflict_mod.BW
    mk = lambda rows, d: jnp.asarray(
        (rng.random((rows, w)) < d) * rng.integers(0, 2**31, (rows, w)),
        jnp.int32)
    foot = mk(m, 0.2)
    write = mk(n, 0.05)
    out = np.asarray(conflict_mod.conflict_matrix_bits_pair(
        foot, write, interpret=True))
    exp = ((np.asarray(foot)[:, None, :]
            & np.asarray(write)[None, :, :]) != 0).any(axis=2)
    assert out.shape == (m, n)
    np.testing.assert_array_equal(out, exp)


def test_conflict_matrix_delta_compact_vs_masked_delta():
    """The compacted strip-scatter delta == the masked-row delta for a
    gathered live set (both backend paths share this op-level contract;
    off-TPU this exercises the dense strips)."""
    from repro.core.txn import gather_live_indices
    rng = np.random.default_rng(31)
    k, l, n_objects = 19, 4, 80
    mk = lambda: (jnp.asarray(rng.integers(0, n_objects, (k, l)), jnp.int32),
                  jnp.asarray(rng.integers(0, l + 1, (k,)), jnp.int32))
    ra, rn = mk()
    wa, wn = mk()
    foot, write = ops.packed_footprints(ra, rn, wa, wn, n_objects)
    old = jnp.asarray(rng.random((k, k)) < 0.2)
    for n_live in (0, 1, 7, k):
        live = np.zeros(k, bool)
        live[rng.choice(k, n_live, replace=False)] = True
        live = jnp.asarray(live)
        idx, valid = gather_live_indices(live, max(1, int(n_live)))
        ref = np.asarray(ops.conflict_matrix_delta(foot, write, old, live,
                                                   n_objects))
        got = np.asarray(ops.conflict_matrix_delta_compact(
            foot, write, old, idx, valid, n_objects))
        np.testing.assert_array_equal(got, ref, err_msg=f"n_live={n_live}")


def test_update_packed_footprints_compact_matches_masked():
    from repro.core.txn import gather_live_indices
    rng = np.random.default_rng(44)
    k, l, n_objects = 14, 5, 90
    mk = lambda: (jnp.asarray(rng.integers(0, n_objects, (k, l)), jnp.int32),
                  jnp.asarray(rng.integers(0, l + 1, (k,)), jnp.int32))
    ra0, rn0 = mk()
    wa0, wn0 = mk()
    foot0, write0 = ops.packed_footprints(ra0, rn0, wa0, wn0, n_objects)
    ra1, rn1 = mk()
    wa1, wn1 = mk()
    live = jnp.asarray(rng.random(k) < 0.4)
    width = max(1, int(live.sum()))
    idx, valid = gather_live_indices(live, width)
    ref = ops.update_packed_footprints(foot0, write0, ra1, rn1, wa1, wn1,
                                       live, n_objects)
    got = ops.update_packed_footprints_compact(
        foot0, write0, ra1[idx], rn1[idx], wa1[idx], wn1[idx], idx, valid,
        n_objects)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(ref[1]))


def test_update_packed_footprints_refreshes_live_rows_only():
    rng = np.random.default_rng(8)
    k, l, n_objects = 12, 5, 100
    mk = lambda: (jnp.asarray(rng.integers(0, n_objects, (k, l)), jnp.int32),
                  jnp.asarray(rng.integers(0, l + 1, (k,)), jnp.int32))
    ra0, rn0 = mk()
    wa0, wn0 = mk()
    foot0, write0 = ops.packed_footprints(ra0, rn0, wa0, wn0, n_objects)
    ra1, rn1 = mk()
    wa1, wn1 = mk()
    live = jnp.asarray(rng.random(k) < 0.4)
    foot, write = ops.update_packed_footprints(
        foot0, write0, ra1, rn1, wa1, wn1, live, n_objects)
    exp_foot, exp_write = ops.packed_footprints(ra1, rn1, wa1, wn1, n_objects)
    lv = np.asarray(live)
    np.testing.assert_array_equal(np.asarray(foot)[lv],
                                  np.asarray(exp_foot)[lv])
    np.testing.assert_array_equal(np.asarray(write)[lv],
                                  np.asarray(exp_write)[lv])
    np.testing.assert_array_equal(np.asarray(foot)[~lv],
                                  np.asarray(foot0)[~lv])
    np.testing.assert_array_equal(np.asarray(write)[~lv],
                                  np.asarray(write0)[~lv])


def test_conflict_matrix_delta_op_dense_fallback():
    """ops.conflict_matrix_delta's dense fallback (the off-TPU path)
    matches the where-select semantics on unpadded shapes."""
    rng = np.random.default_rng(13)
    k, l, n_objects = 17, 4, 70
    mk = lambda: (jnp.asarray(rng.integers(0, n_objects, (k, l)), jnp.int32),
                  jnp.asarray(rng.integers(0, l + 1, (k,)), jnp.int32))
    ra, rn = mk()
    wa, wn = mk()
    foot, write = ops.packed_footprints(ra, rn, wa, wn, n_objects)
    old = jnp.asarray(rng.random((k, k)) < 0.2)
    live = jnp.asarray(rng.random(k) < 0.5)
    got = np.asarray(ops.conflict_matrix_delta(foot, write, old, live,
                                               n_objects))
    full = np.asarray(ops._conflict_matrix_dense(ra, rn, wa, wn, n_objects))
    lv = np.asarray(live)
    exp = np.where(lv[:, None] | lv[None, :], full, np.asarray(old))
    np.testing.assert_array_equal(got, exp)


# ------------------------------------------------------------- fused adamw
@pytest.mark.parametrize("shape", [(256, 256), (3, 700), (1, 1), (512, 512),
                                   (1000,)])
@pytest.mark.parametrize("gdtype", [jnp.float32, jnp.bfloat16])
def test_adamw_sweep(shape, gdtype):
    rng = np.random.default_rng(sum(shape))
    p = jnp.asarray(rng.normal(size=shape), jnp.float32)
    m = jnp.asarray(rng.normal(size=shape) * 0.1, jnp.float32)
    v = jnp.asarray(np.abs(rng.normal(size=shape)) * 0.01, jnp.float32)
    g = jnp.asarray(rng.normal(size=shape), gdtype)
    got = ops.adamw_update(p, m, v, g, step=7, lr=3e-4, wd=0.1)
    exp = ref.adamw_ref(p, m, v, g, step=7, lr=3e-4, wd=0.1)
    for a, b in zip(got, exp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-5, atol=1e-7)


def test_adamw_no_nan_large_steps():
    p = jnp.ones((256, 256)) * 1e3
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    g = jnp.ones_like(p) * 1e3
    p2, m2, v2 = ops.adamw_update(p, m, v, g, step=1)
    assert np.isfinite(np.asarray(p2)).all()


@pytest.mark.parametrize("stale_frac", [0.0, 0.5, 1.0])
def test_adamw_speculative_aborts_stale_blocks(stale_frac):
    rng = np.random.default_rng(int(stale_frac * 10))
    r = c = 512
    p = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)
    m = jnp.zeros((r, c))
    v = jnp.zeros((r, c))
    g = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)
    gr, gc = r // 256, c // 256
    versions = jnp.asarray(
        (rng.random((gr, gc)) < stale_frac).astype(np.int32) * 10, jnp.int32)
    rv = jnp.asarray(5, jnp.int32)
    got = ops.adamw_update_speculative(p, m, v, g, versions, rv, step=2)
    exp = ref.adamw_speculative_ref(p, m, v, g, versions, rv, step=2)
    for a, b in zip(got, exp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-5, atol=1e-7)
    n_stale = int((np.asarray(versions) > 5).sum())
    assert int(np.asarray(got[3]).sum()) == n_stale
    if stale_frac == 1.0:  # everything aborted -> params untouched
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(p))


# --------------------------------------------------------------- kv commit
@pytest.mark.parametrize("p,page,h,s", [
    (4, 2, 8, 3), (8, 4, 16, 5), (16, 8, 128, 8), (2, 1, 8, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kv_commit_sweep(p, page, h, s, dtype):
    rng = np.random.default_rng(p * 7 + s)
    cache = jnp.asarray(rng.normal(size=(p, page, h)), dtype)
    versions = jnp.asarray(rng.integers(0, 3, (p,)), jnp.int32)
    rows = jnp.asarray(rng.normal(size=(s, h)), jnp.float32)
    page_idx = jnp.asarray(rng.integers(0, p, (s,)), jnp.int32)
    row_idx = jnp.asarray(rng.integers(0, page, (s,)), jnp.int32)
    sn = jnp.arange(10, 10 + s, dtype=jnp.int32)
    commit = jnp.asarray(rng.integers(0, 2, (s,)), jnp.int32)
    got_c, got_v = ops.kv_cache_commit(cache, versions, rows, page_idx,
                                       row_idx, sn, commit)
    exp_c, exp_v = ref.kv_commit_ref(cache, versions, rows, page_idx,
                                     row_idx, sn, commit)
    np.testing.assert_allclose(np.asarray(got_c, np.float32),
                               np.asarray(exp_c, np.float32))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(exp_v))


def test_kv_commit_order_last_writer_wins():
    """Two slots commit to the same (page, row): the higher sequence number
    (later slot) must win — ordered commit semantics."""
    cache = jnp.zeros((2, 2, 8), jnp.float32)
    versions = jnp.zeros((2,), jnp.int32)
    rows = jnp.stack([jnp.full((8,), 1.0), jnp.full((8,), 2.0)])
    page_idx = jnp.asarray([1, 1], jnp.int32)
    row_idx = jnp.asarray([0, 0], jnp.int32)
    sn = jnp.asarray([5, 6], jnp.int32)
    commit = jnp.asarray([1, 1], jnp.int32)
    got_c, got_v = ops.kv_cache_commit(cache, versions, rows, page_idx,
                                       row_idx, sn, commit)
    assert float(got_c[1, 0, 0]) == 2.0
    assert int(got_v[1]) == 6


def test_kv_commit_speculative_slots_skipped():
    cache = jnp.zeros((2, 2, 8), jnp.float32)
    versions = jnp.zeros((2,), jnp.int32)
    rows = jnp.ones((1, 8), jnp.float32)
    got_c, got_v = ops.kv_cache_commit(
        cache, versions, rows, jnp.asarray([0], jnp.int32),
        jnp.asarray([0], jnp.int32), jnp.asarray([9], jnp.int32),
        jnp.asarray([0], jnp.int32))
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(cache))
    assert int(got_v[0]) == 0
