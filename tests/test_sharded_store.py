"""Sharded store == dense store, bit for bit (PR 5).

The shard decomposition invariant: with the address space partitioned
into S contiguous range shards, conflict(t, u) is the OR over shards of
per-shard conflicts, write-back splits into S independent scatters, and
every commit decision stays in global rank space — so S is a pure
layout knob.  Layers under test:

* the store layout itself — shard/unshard round-trips, padding for
  non-dividing S, layout-blind fingerprints;
* per-shard packed footprints + OR-reduced conflict tables (full,
  masked-row delta, and compact-strip paths) against the dense
  formulation's verdicts;
* ``fused_write_back`` / ``apply_writes`` sharded scatters against the
  dense scatter;
* every engine (pcc / occ / destm / pogl), masked and compact-ladder
  paths, at S in {2, 8} / K in {1, 2, 64} / high + low contention:
  store images, versions, fingerprints and full traces bitwise equal
  to the dense run;
* ``PotSession(shards=...)`` over a bucketed ragged stream: fingerprints
  and ``replay_log()`` equal the dense session's, replay round-trips;
* the ``shard_map`` mesh path on a real 8-device host-platform mesh
  (subprocess, like test_moe_shardmap) — also exercised by
  ``scripts/ci.sh --shard-smoke``.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (PotSession, RoundRobinSequencer, ShardedStore,
                        StoreLayout, destm_execute, dense_image,
                        fingerprint, make_store, occ_execute, pcc_execute,
                        shard_store, unshard_store)
from repro.core import protocol
from repro.core import workloads as W
from repro.core.pogl import _pogl_raw
from repro.core.tstore import flat_values
from repro.core.txn import run_all
from repro.kernels import ops as kernel_ops

ENGINES = ("pcc", "occ", "destm")
TRACE_FIELDS = ("commit_round", "commit_pos", "first_round", "retries",
                "mode", "wait_rounds", "rounds", "exec_ops",
                "validation_words", "promotions", "barrier_ops",
                "wave_trips", "live_txns", "live_slots", "walked_slots",
                "live_per_round")


def _wl(k, contention, seed=0):
    if contention == "low":
        return W.counters(n_txns=k, n_objects=max(64, 8 * k), n_reads=2,
                          n_writes=2, n_lanes=min(8, k), skew=0.0,
                          seed=seed)
    return W.counters(n_txns=k, n_objects=max(4, k // 4), n_reads=2,
                      n_writes=2, n_lanes=min(8, k), skew=1.0, seed=seed)


def _seq_for(wl):
    seqr = RoundRobinSequencer(n_root_lanes=wl.n_lanes)
    return jnp.asarray(seqr.order_for(wl.lanes.tolist()), jnp.int32)


def _run(engine, store, wl, **kw):
    seq = _seq_for(wl)
    lanes = jnp.asarray(wl.lanes, jnp.int32)
    if engine == "pcc":
        return pcc_execute(store, wl.batch, seq, **kw)
    if engine == "occ":
        return occ_execute(store, wl.batch, jnp.argsort(seq), **kw)
    if engine == "destm":
        return destm_execute(store, wl.batch, seq, lanes, wl.n_lanes, **kw)
    raise ValueError(engine)


def _assert_stores_equal(dense, sharded, msg=""):
    np.testing.assert_array_equal(
        np.asarray(dense_image(dense)), np.asarray(dense_image(sharded)),
        err_msg=f"values diverged {msg}")
    dv = np.asarray(unshard_store(sharded).versions) \
        if isinstance(sharded, ShardedStore) else np.asarray(sharded.versions)
    np.testing.assert_array_equal(np.asarray(dense.versions), dv,
                                  err_msg=f"versions diverged {msg}")
    assert int(dense.gv) == int(sharded.gv), msg
    assert int(fingerprint(dense)) == int(fingerprint(sharded)), msg


def _assert_traces_equal(a, b, msg=""):
    for f in TRACE_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"trace field {f} diverged {msg}")


# ------------------------------------------------------------ store layout
class TestStoreLayout:
    def test_shard_round_trip(self):
        store = make_store(100, slot=2,
                           init=np.arange(200).reshape(100, 2))
        for s in (2, 3, 7, 8):
            sh = shard_store(store, s)
            assert sh.shards == s
            assert sh.shard_size == -(-100 // s)
            back = unshard_store(sh)
            np.testing.assert_array_equal(np.asarray(store.values),
                                          np.asarray(back.values))
            np.testing.assert_array_equal(np.asarray(store.versions),
                                          np.asarray(back.versions))
            assert int(fingerprint(sh)) == int(fingerprint(store))

    def test_one_shard_no_mesh_stays_dense(self):
        # shards=1 without a mesh IS the dense layout: no ShardedStore is
        # created (it would route (1, C, slot) arrays through the dense
        # code paths), and engines run it as the dense store
        store = make_store(32)
        assert shard_store(store, 1) is store
        assert unshard_store(store) is store
        assert not isinstance(make_store(32, shards=1), ShardedStore)
        wl = _wl(8, "med", seed=4)
        out_a, tr_a = _run("pcc", make_store(wl.n_objects), wl)
        out_b, tr_b = _run("pcc",
                           shard_store(make_store(wl.n_objects), 1), wl)
        _assert_stores_equal(out_a, out_b, "shards=1")
        _assert_traces_equal(tr_a, tr_b, "shards=1")

    def test_make_store_sharded(self):
        sh = make_store(64, shards=4)
        assert isinstance(sh, ShardedStore)
        assert sh.values.shape == (4, 16, 1)
        assert sh.layout == StoreLayout(64, 4)
        assert isinstance(make_store(64), type(unshard_store(sh)))

    def test_flat_values_is_the_dense_image(self):
        store = make_store(10, init=np.arange(10))
        sh = shard_store(store, 4)  # C=3, padded to 12
        flat = flat_values(sh.values, sh.layout)
        assert flat.shape == (12, 1)
        np.testing.assert_array_equal(np.asarray(flat[:10]),
                                      np.asarray(store.values))

    def test_layout_address_map(self):
        lay = StoreLayout(10, 4)   # C = 3
        addrs = jnp.arange(10)
        np.testing.assert_array_equal(
            np.asarray(lay.shard_of(addrs) * lay.shard_size
                       + lay.offset_of(addrs)), np.arange(10))
        assert int(lay.shard_of(jnp.asarray(9))) == 3
        assert lay.padded_objects == 12 and lay.words_per_shard == 1

    def test_mesh_validation(self):
        store = make_store(16)
        with pytest.raises(ValueError):
            PotSession(store=shard_store(store, 2), shards=4)
        with pytest.raises(ValueError):
            PotSession(16, bucket_ladder="golden")


# --------------------------------------------- per-shard conflict analysis
class TestShardedConflict:
    def _bits(self, wl, layout):
        store = make_store(wl.n_objects)
        res = run_all(wl.batch, store.values)
        return res, kernel_ops.packed_footprints_sharded(
            res.raddrs, res.rn, res.waddrs, res.wn, layout)

    @pytest.mark.parametrize("shards", [2, 3, 8])
    def test_or_reduced_table_matches_dense(self, shards):
        wl = _wl(32, "med", seed=11)
        layout = StoreLayout(wl.n_objects, shards)
        res, (foot, write) = self._bits(wl, layout)
        got = kernel_ops.conflict_matrix_sharded(foot, write)
        exp = kernel_ops._conflict_matrix_dense(
            res.raddrs, res.rn, res.waddrs, res.wn, wl.n_objects)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))

    @pytest.mark.parametrize("shards", [2, 8])
    def test_delta_matches_recompute(self, shards):
        # simulate rounds: shrinking live sets over changing store images
        wl = _wl(24, "med", seed=3)
        layout = StoreLayout(wl.n_objects, shards)
        rng = np.random.default_rng(5)
        values = jnp.asarray(
            rng.integers(0, 100, (wl.n_objects, 1)), jnp.int32)
        res = run_all(wl.batch, values)
        foot, write = kernel_ops.packed_footprints_sharded(
            res.raddrs, res.rn, res.waddrs, res.wn, layout)
        table = kernel_ops.conflict_matrix_sharded(foot, write)
        for n_live in (12, 5, 1, 0):
            live = np.zeros(24, bool)
            live[rng.choice(24, n_live, replace=False)] = True
            live = jnp.asarray(live)
            values = jnp.asarray(
                rng.integers(0, 100, (wl.n_objects, 1)), jnp.int32)
            res = run_all(wl.batch, values)
            foot, write = kernel_ops.update_packed_footprints_sharded(
                foot, write, res.raddrs, res.rn, res.waddrs, res.wn,
                live, layout)
            table = kernel_ops.conflict_matrix_delta_sharded(
                foot, write, table, live, layout)
            fresh_foot, fresh_write = kernel_ops.packed_footprints_sharded(
                res.raddrs, res.rn, res.waddrs, res.wn, layout)
            fresh = kernel_ops.conflict_matrix_sharded(fresh_foot,
                                                       fresh_write)
            refresh = np.asarray(live)[:, None] | np.asarray(live)[None, :]
            # refreshed entries fresh, stale entries carried
            np.testing.assert_array_equal(
                np.asarray(table)[refresh], np.asarray(fresh)[refresh])
            # live rows' packed words match a from-scratch pack
            for a, b in ((foot, fresh_foot), (write, fresh_write)):
                np.testing.assert_array_equal(
                    np.asarray(a)[:, np.asarray(live)],
                    np.asarray(b)[:, np.asarray(live)])

    @pytest.mark.parametrize("shards", [2, 8])
    def test_compact_strips_match_masked_delta(self, shards):
        from repro.core.txn import gather_live_indices
        wl = _wl(24, "med", seed=9)
        layout = StoreLayout(wl.n_objects, shards)
        rng = np.random.default_rng(13)
        values = jnp.asarray(
            rng.integers(0, 50, (wl.n_objects, 1)), jnp.int32)
        res0 = run_all(wl.batch, values)
        foot, write = kernel_ops.packed_footprints_sharded(
            res0.raddrs, res0.rn, res0.waddrs, res0.wn, layout)
        table = kernel_ops.conflict_matrix_sharded(foot, write)
        live = np.zeros(24, bool)
        live[rng.choice(24, 6, replace=False)] = True
        live = jnp.asarray(live)
        values2 = jnp.asarray(
            rng.integers(0, 50, (wl.n_objects, 1)), jnp.int32)
        res = run_all(wl.batch, values2)
        idx, valid = gather_live_indices(live, 8)
        cres = jax.tree.map(lambda a: a[idx], res)
        cfoot, cwrite = kernel_ops.update_packed_footprints_compact_sharded(
            foot, write, cres.raddrs, jnp.where(valid, cres.rn, 0),
            cres.waddrs, jnp.where(valid, cres.wn, 0), idx, valid, layout)
        got = kernel_ops.conflict_matrix_delta_compact_sharded(
            cfoot, cwrite, table, idx, valid, layout)
        mfoot, mwrite = kernel_ops.update_packed_footprints_sharded(
            foot, write, res.raddrs, res.rn, res.waddrs, res.wn, live,
            layout)
        exp = kernel_ops.conflict_matrix_delta_sharded(
            mfoot, mwrite, table, live, layout)
        np.testing.assert_array_equal(np.asarray(cfoot), np.asarray(mfoot))
        np.testing.assert_array_equal(np.asarray(cwrite),
                                      np.asarray(mwrite))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


# -------------------------------------------------- write-back primitives
class TestShardedWriteBack:
    @pytest.mark.parametrize("shards", [2, 3, 8])
    def test_fused_write_back_matches_dense(self, shards):
        k, length, n_obj, slot = 16, 5, 37, 2
        rng = np.random.default_rng(shards)
        waddrs = jnp.asarray(rng.integers(0, n_obj, (k, length)), jnp.int32)
        wvals = jnp.asarray(rng.integers(0, 99, (k, length, slot)),
                            jnp.int32)
        wn = jnp.asarray(rng.integers(0, length + 1, (k,)), jnp.int32)
        committing = jnp.asarray(rng.random(k) < 0.6)
        rank = jnp.asarray(rng.permutation(k), jnp.int32)
        seq_nos = rank + 5
        dense = make_store(n_obj, slot=slot)
        sh = shard_store(dense, shards)
        dv, dver = protocol.fused_write_back(
            dense.values, dense.versions, waddrs, wvals, wn, committing,
            rank, seq_nos)
        sv, sver = protocol.fused_write_back(
            sh.values, sh.versions, waddrs, wvals, wn, committing, rank,
            seq_nos, sh.layout)
        c = sh.shard_size
        np.testing.assert_array_equal(
            np.asarray(dv),
            np.asarray(sv.reshape(-1, slot)[:n_obj]))
        np.testing.assert_array_equal(
            np.asarray(dver), np.asarray(sver.reshape(-1)[:n_obj]))
        # padding rows stay untouched
        assert not np.asarray(sver.reshape(-1)[n_obj:]).any()
        assert c * shards >= n_obj

    @pytest.mark.parametrize("shards", [2, 8])
    def test_apply_writes_matches_dense(self, shards):
        length, n_obj = 6, 21
        rng = np.random.default_rng(41 + shards)
        for trial in range(5):
            waddrs = jnp.asarray(rng.integers(0, n_obj, (length,)),
                                 jnp.int32)
            wvals = jnp.asarray(rng.integers(0, 99, (length, 1)), jnp.int32)
            wn = jnp.asarray(rng.integers(0, length + 1), jnp.int32)
            dense = make_store(n_obj)
            sh = shard_store(dense, shards)
            dv, dver = protocol.apply_writes(
                dense.values, dense.versions, waddrs, wvals, wn, 7)
            sv, sver = protocol.apply_writes(
                sh.values, sh.versions, waddrs, wvals, wn, 7, sh.layout)
            np.testing.assert_array_equal(
                np.asarray(dv), np.asarray(sv.reshape(-1, 1)[:n_obj]))
            np.testing.assert_array_equal(
                np.asarray(dver), np.asarray(sver.reshape(-1)[:n_obj]))


# ------------------------------------------------------- engine equality
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("contention", ["low", "med"])
@pytest.mark.parametrize("k", [1, 2, 64])
@pytest.mark.parametrize("shards", [2, 8])
def test_engine_sharded_equals_dense(engine, contention, k, shards):
    wl = _wl(k, contention, seed=13 * k + shards)
    dense = make_store(wl.n_objects)
    sh = shard_store(dense, shards)
    out_d, tr_d = _run(engine, dense, wl)
    out_s, tr_s = _run(engine, sh, wl)
    assert isinstance(out_s, ShardedStore)
    _assert_stores_equal(out_d, out_s, f"{engine} K={k} {contention} "
                                       f"S={shards}")
    _assert_traces_equal(tr_d, tr_s, f"{engine} K={k} {contention} "
                                     f"S={shards}")


@pytest.mark.parametrize("engine", ENGINES)
def test_engine_sharded_masked_path(engine):
    # compact=False: the masked (non-ladder) loop must also be sharded-
    # invariant, and rebuild (incremental=False) too
    wl = _wl(32, "med", seed=2)
    dense = make_store(wl.n_objects)
    sh = shard_store(dense, 4)
    for kw in (dict(compact=False), dict(incremental=False)):
        out_d, tr_d = _run(engine, dense, wl, **kw)
        out_s, tr_s = _run(engine, sh, wl, **kw)
        _assert_stores_equal(out_d, out_s, f"{engine} {kw}")
        _assert_traces_equal(tr_d, tr_s, f"{engine} {kw}")


def test_pogl_sharded_equals_dense():
    wl = _wl(16, "med", seed=21)
    seq = _seq_for(wl)
    lanes = jnp.asarray(wl.lanes, jnp.int32)
    dense = make_store(wl.n_objects)
    for shards in (2, 8):
        out_d, tr_d = _pogl_raw(dense, wl.batch, seq, lanes, wl.n_lanes)
        out_s, tr_s = _pogl_raw(shard_store(dense, shards), wl.batch, seq,
                                lanes, wl.n_lanes)
        _assert_stores_equal(out_d, out_s, f"pogl S={shards}")
        _assert_traces_equal(tr_d, tr_s, f"pogl S={shards}")


@settings(max_examples=15, deadline=None)
@given(k=st.integers(1, 24), shards=st.sampled_from([2, 3, 5, 8]),
       skew=st.sampled_from([0.0, 1.0]), seed=st.integers(0, 99))
def test_pcc_sharded_equals_dense_property(k, shards, skew, seed):
    wl = W.counters(n_txns=k, n_objects=max(8, 2 * k), n_reads=2,
                    n_writes=2, n_lanes=min(4, k), skew=skew, seed=seed)
    dense = make_store(wl.n_objects)
    out_d, tr_d = _run("pcc", dense, wl)
    out_s, tr_s = _run("pcc", shard_store(dense, shards), wl)
    _assert_stores_equal(out_d, out_s)
    _assert_traces_equal(tr_d, tr_s)


# --------------------------------------------------------------- session
@pytest.mark.parametrize("engine", ENGINES)
def test_session_sharded_stream_bitwise(engine):
    rng = np.random.default_rng(17)
    batches, lanes = [], []
    for i in range(8):
        kk = int(rng.integers(1, 33))
        wl = W.counters(n_txns=kk, n_objects=101, n_reads=2, n_writes=2,
                        n_lanes=min(4, kk), skew=0.8, seed=200 + i)
        batches.append(wl.batch)
        lanes.append(wl.lanes.tolist())
    ref = PotSession(101, engine=engine, n_lanes=4)
    ref.run_stream(batches, lanes)
    for shards in (2, 8):
        s = PotSession(101, engine=engine, n_lanes=4, shards=shards)
        s.run_stream(batches, lanes)
        assert s.fingerprint() == ref.fingerprint(), (engine, shards)
        assert s.replay_log() == ref.replay_log(), (engine, shards)
        assert s.gv == ref.gv


def test_session_sharded_replay_round_trip():
    wl = W.counters(n_txns=24, n_objects=64, n_lanes=4, skew=0.9, seed=31)
    rec = PotSession(64, engine="occ", n_lanes=4, shards=4)
    rec.submit(wl.batch, wl.lanes.tolist())
    replay = PotSession(64, engine="occ", n_lanes=4, shards=4,
                        sequencer=rec.replay_sequencer())
    replay.submit(wl.batch, wl.lanes.tolist())
    assert replay.fingerprint() == rec.fingerprint()
    assert replay.replay_log() == rec.replay_log()


def test_session_dense_bucket_ladder():
    """The bucket_ladder='dense' satellite: {1,2,4,8} + multiples of 8
    below/instead of pow2 rungs — same outcome, tighter padding, compile
    count still bounded by the ladder."""
    from repro.core.session import dense_bucket
    assert [dense_bucket(k) for k in (1, 2, 3, 5, 8, 9, 16, 17, 24, 30)] \
        == [1, 2, 4, 8, 8, 16, 16, 24, 24, 32]
    rng = np.random.default_rng(23)
    batches, lanes = [], []
    for i in range(16):
        kk = int(rng.integers(1, 33))
        wl = W.counters(n_txns=kk, n_objects=64, n_reads=2, n_writes=2,
                        n_lanes=min(4, kk), skew=0.5, seed=300 + i)
        batches.append(wl.batch)
        lanes.append(wl.lanes.tolist())
    pow2 = PotSession(64, engine="pcc", n_lanes=4)
    pow2.run_stream(batches, lanes)
    dense = PotSession(64, engine="pcc", n_lanes=4, bucket_ladder="dense")
    dense.run_stream(batches, lanes)
    assert dense.fingerprint() == pow2.fingerprint()
    assert dense.replay_log() == pow2.replay_log()
    # every dense bucket K is on the ladder; padding never exceeds 7 rows
    # above 8 (vs up to K-1 for pow2), and the compile count stays within
    # the K<=32 dense ladder {1,2,4,8,16,24,32} x L rungs
    for (bk, _bl), _ in dense.bucket_counts().items():
        assert bk in (1, 2, 4, 8) or bk % 8 == 0, bk
    assert dense.compile_count() <= 7
    # the dense ladder walks no more padded rows than the pow2 one
    pad_dense = sum((bk - b.n_txns) for b, (bk, _) in
                    zip(batches, map(dense._bucket_shape, batches)))
    pad_pow2 = sum((bk - b.n_txns) for b, (bk, _) in
                   zip(batches, map(pow2._bucket_shape, batches)))
    assert pad_dense <= pad_pow2


# ------------------------------------------------------- shard_map mesh
MESH_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.core import (PotSession, RoundRobinSequencer, fingerprint,
                        make_store, pcc_execute, shard_store)
from repro.core import workloads as W

wl = W.counters(n_txns=24, n_objects=80, n_reads=2, n_writes=2,
                n_lanes=4, skew=0.9, seed=6)
seq = jnp.asarray(RoundRobinSequencer(n_root_lanes=4)
                  .order_for(wl.lanes.tolist()), jnp.int32)
dense = make_store(wl.n_objects)
out_d, tr_d = pcc_execute(dense, wl.batch, seq)
for s in (1, 2, 8):
    # s=1 with a mesh: a single-shard ShardedStore must still route
    # through the shard_map path (regression: generic shards=len(devices))
    mesh = jax.make_mesh((s,), ("shard",), devices=jax.devices()[:s])
    out_s, tr_s = pcc_execute(shard_store(dense, s, mesh=mesh),
                              wl.batch, seq)
    assert int(fingerprint(out_s)) == int(fingerprint(out_d)), s
    assert np.array_equal(np.asarray(tr_s.commit_pos),
                          np.asarray(tr_d.commit_pos)), s
# session-level: mesh store threads through the cached jitted step
sess = PotSession(80, engine="pcc", n_lanes=4, shards=8,
                  mesh=jax.make_mesh((8,), ("shard",)))
sess.submit(wl.batch, wl.lanes.tolist())
ref = PotSession(80, engine="pcc", n_lanes=4)
ref.submit(wl.batch, wl.lanes.tolist())
assert sess.fingerprint() == ref.fingerprint()
assert sess.replay_log() == ref.replay_log()
print("MESH_OK")
"""


def test_shard_map_mesh_equals_dense():
    """The per-shard write-back under jax.shard_map on a REAL 8-device
    host-platform mesh reproduces the dense store bitwise (subprocess,
    as in test_moe_shardmap; the CI twin is scripts/ci.sh
    --shard-smoke)."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "-c", MESH_CODE],
                       capture_output=True, text=True, cwd=repo,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "MESH_OK" in r.stdout, (r.stdout[-1000:], r.stderr[-3000:])
