"""Tests for gather-compacted sparse execution + shape-bucketed
streaming (PR 4).

Four layers of guarantees:

* ``txn.run_live_compact`` — the gather-execute-scatter primitive equals
  the masked ``run_live`` for every live set that fits the compact
  width, including live sets of size 0 and 1 (fixed K in {1, 2, 64}
  plus a hypothesis property);
* ``protocol.refresh_round_state_compact`` — the compact read phase
  refreshes the cached results AND the carried conflict table exactly
  like the masked ``refresh_round_state`` over simulated multi-round
  shrinking live sets;
* the engines — ``compact=True`` (ladder cascade) is bit-identical to
  ``compact=False`` (masked loop) and ``incremental=False`` (rebuild)
  on stores and traces, at K in {1, 2, 64}, high/low contention, while
  walking no more device slots than the masked loop;
* NOP shape bucketing — padded (vacant) rows provably never commit:
  engine-level padded runs match unpadded runs on fingerprints,
  versions, gv and real-row commit positions, and the bucketed
  ``PotSession`` reproduces the exact-shape session bitwise with at
  most ladder-size compiled steps.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (READ, RMW, WRITE, PotSession, RoundRobinSequencer,
                        destm_execute, fingerprint, get_engine, make_batch,
                        make_store, occ_execute, pcc_execute, run_all)
from repro.core import protocol
from repro.core import workloads as W
from repro.core.txn import (gather_live_indices, next_pow2, pad_batch,
                            run_live, run_live_compact)

RESULT_FIELDS = ("raddrs", "rn", "waddrs", "wvals", "wn")


def _wl(k: int, contention: str, seed: int = 0) -> W.Workload:
    if contention == "low":
        return W.counters(n_txns=k, n_objects=max(64, 8 * k), n_reads=2,
                          n_writes=2, n_lanes=min(8, k), skew=0.0, seed=seed)
    return W.counters(n_txns=k, n_objects=max(4, k // 4), n_reads=2,
                      n_writes=2, n_lanes=min(8, k), skew=1.0, seed=seed)


def _seq_for(wl):
    seqr = RoundRobinSequencer(n_root_lanes=wl.n_lanes)
    return jnp.asarray(seqr.order_for(wl.lanes.tolist()), jnp.int32)


def _assert_results_equal(a, b, msg=""):
    for f in RESULT_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{msg}: field {f} diverged")


# ------------------------------------------------- run_live_compact
@pytest.mark.parametrize("k", [1, 2, 64])
@pytest.mark.parametrize("n_live", [0, 1, "half", "all"])
def test_run_live_compact_equals_run_live(k, n_live):
    wl = _wl(k, "low", seed=k)
    store = make_store(wl.n_objects, init=np.arange(wl.n_objects) % 7)
    cache = run_all(wl.batch, store.values)
    n = {0: 0, 1: min(1, k), "half": k // 2, "all": k}[n_live]
    rng = np.random.default_rng(k + n)
    live = np.zeros(k, bool)
    live[rng.choice(k, n, replace=False)] = True
    live = jnp.asarray(live)
    values = store.values + 3   # fresh image: live rows must re-read it
    ref = run_live(wl.batch, values, live, cache)
    for width in {max(1, next_pow2(n)), k}:
        got = run_live_compact(wl.batch, values, live, cache, width)[0]
        _assert_results_equal(ref, got, f"k={k} n_live={n} width={width}")


def test_gather_live_indices_covers_live_rows():
    live = jnp.asarray([False, True, False, True, True, False])
    idx, valid = gather_live_indices(live, 4)
    np.testing.assert_array_equal(np.asarray(idx)[:3], [1, 3, 4])
    np.testing.assert_array_equal(np.asarray(valid), [True] * 3 + [False])


@st.composite
def compact_cases(draw):
    n_objects = draw(st.sampled_from([4, 8, 16]))
    k = draw(st.integers(1, 10))
    progs = []
    for _ in range(k):
        n_ins = draw(st.integers(1, 5))
        progs.append([
            (draw(st.sampled_from([READ, WRITE, RMW])),
             draw(st.integers(0, n_objects - 1)),
             draw(st.booleans()), draw(st.integers(-3, 3)))
            for _ in range(n_ins)])
    live = [draw(st.booleans()) for _ in range(k)]
    return n_objects, progs, live


@settings(max_examples=25, deadline=None)
@given(compact_cases())
def test_property_run_live_compact_masks_exactly(case):
    n_objects, progs, live = case
    batch = make_batch(progs)
    store = make_store(n_objects, init=np.arange(n_objects) % 5)
    live = jnp.asarray(live)
    width = max(1, next_pow2(int(live.sum())))
    cache = run_all(batch, store.values)
    ref = run_live(batch, store.values + 1, live, cache)
    got = run_live_compact(batch, store.values + 1, live, cache, width)[0]
    _assert_results_equal(ref, got)


# ------------------------------------- compact round-state refresh
@pytest.mark.parametrize("contention", ["low", "high"])
def test_refresh_compact_equals_masked_over_rounds(contention):
    """Simulated engine rounds with a shrinking live set: the compact
    read phase must refresh the result cache AND the carried conflict
    table exactly like the masked one (matrix path, dense fallback)."""
    k = 32
    wl = _wl(k, contention, seed=41)
    store = make_store(wl.n_objects)
    st_m = protocol.init_round_state(wl.batch, store.values, store.versions,
                                     use_matrix=True)
    st_c = protocol.init_round_state(wl.batch, store.values, store.versions,
                                     use_matrix=True)
    rng = np.random.default_rng(5)
    live = np.ones(k, bool)
    for rnd in range(4):
        jl = jnp.asarray(live)
        width = max(1, next_pow2(int(live.sum())))
        st_m = protocol.refresh_round_state(st_m, wl.batch, jl)
        st_c, _, _, _ = protocol.refresh_round_state_compact(
            st_c, wl.batch, jl, width)
        _assert_results_equal(st_m.res, st_c.res, f"round {rnd}")
        np.testing.assert_array_equal(
            np.asarray(st_m.conflict), np.asarray(st_c.conflict),
            err_msg=f"round {rnd}: carried conflict table diverged")
        assert int(st_m.live_txns) == int(st_c.live_txns)
        assert int(st_m.live_slots) == int(st_c.live_slots)
        assert int(st_c.walked_slots) <= int(st_m.walked_slots)
        bump = st_m.values.at[int(rng.integers(wl.n_objects))].add(1)
        st_m = protocol.commit_round_state(st_m, bump, st_m.versions)
        st_c = protocol.commit_round_state(st_c, bump, st_c.versions)
        live = live & (rng.random(k) < 0.4)


def test_compact_ladder_shape():
    assert protocol.compact_ladder(1) == [1]
    assert protocol.compact_ladder(8) == [8]
    assert protocol.compact_ladder(64) == [64, 16]
    assert protocol.compact_ladder(1024) == [1024, 256, 64, 16]
    for k in (1, 7, 64, 100, 1000):
        ladder = protocol.compact_ladder(k)
        assert ladder[0] == k
        assert all(a > b for a, b in zip(ladder, ladder[1:]))


# ---------------------------------- engines: compact == masked == rebuild
@pytest.mark.parametrize("k", [1, 2, 64])
@pytest.mark.parametrize("contention", ["low", "high"])
def test_engines_compact_equals_masked_equals_rebuild(k, contention):
    wl = _wl(k, contention, seed=57 + k)
    store = make_store(wl.n_objects)
    seq = _seq_for(wl)
    lanes = jnp.asarray(wl.lanes, jnp.int32)
    arrival = jnp.argsort(seq)
    runs = {
        "pcc": lambda **kw: pcc_execute(store, wl.batch, seq, **kw),
        "occ": lambda **kw: occ_execute(store, wl.batch, arrival, **kw),
        "destm": lambda **kw: destm_execute(store, wl.batch, seq, lanes,
                                            wl.n_lanes, **kw),
    }
    for name, run in runs.items():
        out_cpt, t_cpt = run()
        out_msk, t_msk = run(compact=False)
        out_reb, t_reb = run(incremental=False)
        for label, out, t in (("masked", out_msk, t_msk),
                              ("rebuild", out_reb, t_reb)):
            assert int(fingerprint(out_cpt)) == int(fingerprint(out)), (
                name, label)
            np.testing.assert_array_equal(np.asarray(out_cpt.versions),
                                          np.asarray(out.versions))
            for f in ("commit_pos", "retries", "commit_round", "rounds",
                      "exec_ops", "wave_trips", "mode"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(t_cpt, f)),
                    np.asarray(getattr(t, f)),
                    err_msg=f"{name} vs {label}: trace field {f!r} diverged")
        # identical useful work, never more device work than masked
        assert int(t_cpt.live_txns) == int(t_msk.live_txns), name
        assert int(t_cpt.live_slots) == int(t_msk.live_slots), name
        assert int(t_cpt.walked_slots) <= int(t_msk.walked_slots), name


def test_compact_walks_fewer_slots_on_sparse_tail():
    """The sparse-tail regime the cascade targets: most of the batch
    settles in round 0, a tiny straggler chain keeps the loop alive for
    several more rounds.  Those tail rounds must run at the ladder's
    narrow rung (16 for K=64), not the full K — walked slots stay within
    one full-width round plus narrow tail rounds."""
    k, chain = 64, 6
    # k - chain disjoint txns + a serial RMW chain on one hot address,
    # sequenced last: the chain commits one per round after round 0
    progs = [[(RMW, 1 + i, False, 1)] for i in range(k - chain)]
    progs += [[(RMW, 0, False, 1)] for _ in range(chain)]
    batch = make_batch(progs)
    store = make_store(k + 1)
    seq = jnp.arange(1, k + 1, dtype=jnp.int32)
    for fn, order in ((pcc_execute, seq),
                      (occ_execute, jnp.arange(k, dtype=jnp.int32))):
        _, t_cpt = fn(store, batch, order)
        _, t_msk = fn(store, batch, order, compact=False)
        rounds = int(t_cpt.rounds)
        assert rounds == int(t_msk.rounds) > 2
        narrow = protocol.compact_ladder(k)[-1]
        length = batch.max_ins
        assert int(t_msk.walked_slots) == rounds * k * length
        assert int(t_cpt.walked_slots) <= \
            (k + (rounds - 1) * narrow) * length
        assert int(t_cpt.walked_slots) <= int(t_msk.walked_slots) // 2


def test_destm_compact_walks_n_lanes_only():
    wl = _wl(32, "low", seed=3)
    store = make_store(wl.n_objects)
    lanes = jnp.asarray(wl.lanes, jnp.int32)
    _, t = destm_execute(store, wl.batch, _seq_for(wl), lanes, wl.n_lanes)
    assert int(t.walked_slots) == \
        int(t.rounds) * wl.n_lanes * wl.batch.max_ins


# ------------------------------------------------ NOP shape bucketing
@pytest.mark.parametrize("engine", ["pcc", "occ", "destm", "pogl"])
def test_padded_rows_never_commit(engine):
    """Engine-level: a batch padded with vacant NOP rows (sequence
    numbers past every real row) produces the same store image, version
    stamps, gv and real-row commit positions as the unpadded batch, and
    the padded rows never commit (commit_pos == -1)."""
    k, bk, bl = 11, 16, 8
    wl = W.counters(n_txns=k, n_objects=32, n_reads=2, n_writes=2,
                    n_lanes=4, skew=0.9, seed=13)
    store = make_store(wl.n_objects)
    seq = np.asarray(RoundRobinSequencer(n_root_lanes=4).order_for(
        wl.lanes.tolist()))
    lanes = np.asarray(wl.lanes)
    padded = pad_batch(wl.batch, bk, bl)
    assert padded.opcodes.shape == (bk, bl)
    pseq = np.concatenate([seq, seq.max() + 1 + np.arange(bk - k)])
    planes = np.concatenate([lanes, np.zeros(bk - k, lanes.dtype)])
    eng = get_engine(engine)
    out, trace = eng.execute(store, wl.batch, seq, lanes=lanes, n_lanes=4)
    pout, ptrace = eng.execute(store, padded, pseq, lanes=planes, n_lanes=4)
    assert int(fingerprint(out)) == int(fingerprint(pout))
    np.testing.assert_array_equal(np.asarray(out.versions),
                                  np.asarray(pout.versions))
    assert int(out.gv) == int(pout.gv) == k
    cp, pcp = np.asarray(trace.commit_pos), np.asarray(ptrace.commit_pos)
    np.testing.assert_array_equal(cp, pcp[:k])
    assert (pcp[k:] == -1).all()                 # vacant rows never commit
    assert sorted(pcp[:k].tolist()) == list(range(k))


def test_pad_batch_validates_and_noops():
    batch = make_batch([[(RMW, 0, False, 1)]])
    assert pad_batch(batch, 1, 1) is batch
    with pytest.raises(ValueError, match="smaller"):
        pad_batch(batch, 0, 1)


@pytest.mark.parametrize("engine", ["pcc", "occ", "destm", "pogl"])
def test_session_bucketed_stream_matches_exact(engine):
    """A ragged stream through the bucketed session is bitwise identical
    to the exact-shape session: fingerprints, replay logs, gv — and the
    returned traces are sliced back to each batch's real K."""
    rng = np.random.default_rng(19)
    batches, lanes = [], []
    for i in range(8):
        kk = int(rng.integers(1, 30))
        wl = W.counters(n_txns=kk, n_objects=64, n_reads=2, n_writes=2,
                        n_lanes=4, skew=0.7, seed=300 + i)
        batches.append(wl.batch)
        lanes.append(wl.lanes.tolist())
    a = PotSession(64, engine=engine, n_lanes=4)
    b = PotSession(64, engine=engine, n_lanes=4, bucket=False)
    traces = a.run_stream(batches, lanes)
    b.run_stream(batches, lanes)
    assert a.fingerprint() == b.fingerprint()
    assert a.replay_log() == b.replay_log()
    assert a.gv == b.gv == sum(x.n_txns for x in batches)
    for trace, batch in zip(traces, batches):
        assert trace.n_txns == batch.n_txns
        cp = np.asarray(trace.commit_pos)
        assert sorted(cp.tolist()) == list(range(batch.n_txns))
    # pow2 buckets: strictly fewer compiled steps than distinct shapes
    distinct = len({(x.n_txns, x.max_ins) for x in batches})
    assert a.compile_count() <= distinct
    assert a.compile_count() == len(a.bucket_counts())
    assert sum(a.bucket_counts().values()) == len(batches)
    for (bk, bl), _ in a.bucket_counts().items():
        assert bk == next_pow2(bk) and bl == next_pow2(bl)


def test_bucketed_replay_roundtrip():
    """Record under bucketing, replay under bucketing: the replayed
    session must reproduce the store exactly even though vacant padding
    rows sit in every padded trace."""
    rng = np.random.default_rng(29)
    batches = []
    for i in range(5):
        kk = int(rng.integers(2, 20))
        batches.append(W.counters(n_txns=kk, n_objects=32, n_lanes=2,
                                  skew=0.8, seed=i).batch)
    occ = PotSession(32, engine="occ", n_lanes=2)
    occ.run_stream(batches)
    replay = PotSession(32, engine="pcc",
                        sequencer=occ.replay_sequencer())
    replay.run_stream(batches)
    np.testing.assert_array_equal(np.asarray(replay.store.values),
                                  np.asarray(occ.store.values))
    assert replay.fingerprint() == occ.fingerprint()


def test_truncated_run_commit_pos_contract():
    """Rows a max_rounds cap left uncommitted are not part of the
    history: commit_pos == -1 (the same contract vacant rows follow), so
    replay_log's `cp >= 0` filter is exact even for truncated runs."""
    k = 8
    batch = make_batch([[(RMW, 0, False, 1)] for _ in range(k)])
    store = make_store(4)
    seq = jnp.arange(1, k + 1, dtype=jnp.int32)
    _, t = pcc_execute(store, batch, seq, max_rounds=2)
    _, td = destm_execute(store, batch, seq, jnp.zeros((k,), jnp.int32), 2,
                          max_rounds=2)
    for trace in (t, td):
        cp = np.asarray(trace.commit_pos)
        uncommitted = np.asarray(trace.commit_round) < 0
        assert uncommitted.any()            # the cap actually truncated
        assert (cp[uncommitted] == -1).all()
        done = cp[~uncommitted]
        assert sorted(done.tolist()) == list(range(len(done)))


def test_session_live_counts_unaffected_by_bucketing():
    wl = W.counters(n_txns=12, n_objects=16, n_lanes=4, skew=1.0, seed=8)
    s = PotSession(16, engine="pcc", n_lanes=4)
    s.submit(wl.batch, wl.lanes.tolist())
    lc = s.live_counts()[0]
    assert lc[0] == 12          # round 0: the real rows, not the bucket
