"""Numeric equivalence of the shard_map MoE (explicit EP all-to-all +
ZeRO-gathered experts) against the single-device dense oracle, executed
on a REAL multi-device mesh (subprocess with 8 host devices).

Run with a capacity factor high enough that no tokens drop: the two
paths then compute identical expert math and must agree to bf16
tolerance.  This is the test class that catches dispatch-layout bugs the
dry-run cannot (e.g. psum-ing partials across different token sets).

One or two tokens may flip their top-k expert choice between the two
paths: the router logits are computed under different reduction orders,
and a bf16 tie resolves differently.  Such a token gets a *different
but valid* expert mix (observed: 1 token of 128 on jax 0.4.37), so the
elementwise check allows outliers confined to at most 2 whole tokens —
every other token must pass the bf16 tolerance exactly.  A
dispatch-layout bug corrupts whole token SETS (a capacity slice, a
shard's worth), blowing both the token budget and the correlation gate
(> 0.999)."""

import os
import subprocess
import sys

CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models import moe
from repro.runtime.shardings import Profile, SMOKE

cfg = get_smoke_config("deepseek_moe_16b")
cfg = dataclasses.replace(cfg, capacity_factor=16.0)  # no drops
# jax 0.4.x: make_mesh has no axis_types (added in 0.5); default Auto
# axis semantics are what this test wants anyway
mesh = jax.make_mesh((2, 4), ("data", "model"))
prof = Profile(data_axes=("data",), model_axis="model", mesh=mesh)

key = jax.random.PRNGKey(0)
p = moe.init_moe(key, cfg)
b, s = 4, 32
x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model),
                      jnp.float32).astype(jnp.bfloat16)

dense = moe.moe_apply(p, x, cfg, SMOKE)

# jax 0.4.x: no jax.set_mesh; entering the mesh context is equivalent here
with mesh:
    sharded = jax.jit(lambda p, x: moe.moe_apply(p, x, cfg, prof))(p, x)

a = np.asarray(dense, np.float32)
bv = np.asarray(sharded, np.float32)
# elementwise bf16 tolerance; outliers must be confined to <= 2 whole
# tokens (router tie-flips, see module docstring) — a real dispatch bug
# corrupts whole token sets and blows past this
bad = np.abs(a - bv) > (0.08 + 0.08 * np.abs(bv))
tokens_bad = bad.reshape(-1, a.shape[-1]).any(axis=1)
assert tokens_bad.sum() <= 2, (int(tokens_bad.sum()), int(bad.sum()))
# and the values must be meaningfully close overall (correlation)
corr = np.corrcoef(a.ravel(), bv.ravel())[0, 1]
assert corr > 0.999, corr
print("MOE_OK", corr)
"""


def test_shardmap_moe_matches_dense_oracle():
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, cwd=repo,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "MOE_OK" in r.stdout, (r.stdout[-1000:], r.stderr[-3000:])
