"""Per-architecture smoke tests (reduced same-family configs, CPU).

For each of the 10 assigned architectures: one forward + one train step
(finite loss, correct shapes, no NaNs), one decode step against a fresh
cache, and — for representative families — a prefill->decode consistency
check (decoding after prefill matches decoding after token-by-token
feeding)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import lm
from repro.runtime.shardings import SMOKE
from repro.train import make_train_step
from repro.train.train_step import init_state


def _batch(cfg, b=2, s=32, key=None):
    key = key or jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    labels = jnp.concatenate(
        [tokens[:, 1:], -jnp.ones((b, 1), jnp.int32)], axis=1)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            key, (b, cfg.n_frames, cfg.d_model), jnp.float32)
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(
            key, (b, cfg.n_patches, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    step = make_train_step(cfg, SMOKE, mode="pot", n_microbatches=2,
                           remat=False)
    state = init_state(params)
    state2, loss = jax.jit(step)(state, batch)
    assert np.isfinite(float(loss))
    assert int(state2.gv) == 1 and int(state2.step) == 1
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + float(jnp.abs(b).sum()),
        jax.tree.map(lambda a, b: a - b, state2.params, state.params), 0.0)
    assert delta > 0
    for leaf in jax.tree.leaves(state2.params):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg, b=2, s=16)
    enc = None
    if cfg.encoder_layers:
        enc = lm.encode(params, batch["frames"], cfg, SMOKE)
    logits = lm.forward(params, batch["tokens"], cfg, SMOKE,
                        prefix_embeds=batch.get("patches"), enc=enc)
    total = 16 + (cfg.n_patches or 0)
    assert logits.shape == (2, total, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(jax.random.PRNGKey(2), cfg)
    b, smax = 2, 64
    cache = lm.init_cache(cfg, b, smax, SMOKE)
    if cfg.encoder_layers:  # fill cross cache from a prefill
        batch = _batch(cfg, b=b, s=8)
        enc = lm.encode(params, batch["frames"], cfg, SMOKE)
        _, cache2 = lm.prefill(params, batch["tokens"], cfg, SMOKE,
                               max_seq=smax, enc=enc)
        cache = cache2
        pos = jnp.full((b,), 8, jnp.int32)
    else:
        pos = jnp.zeros((b,), jnp.int32)
    tokens = jnp.zeros((b, 1), jnp.int32)
    logits, new_cache = jax.jit(
        lambda p, c, t, po: lm.decode_step(p, c, t, po, cfg, SMOKE))(
            params, cache, tokens, pos)
    assert logits.shape == (b, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ["stablelm_12b", "gemma3_27b",
                                  "mamba2_370m", "recurrentgemma_9b",
                                  "deepseek_moe_16b"])
def test_prefill_decode_consistency(arch):
    """Decoding token s after prefill(tokens[:s]) must match the forward
    logits at position s (same math, cache path vs parallel path)."""
    cfg = get_smoke_config(arch)
    params = lm.init_params(jax.random.PRNGKey(3), cfg)
    b, s = 2, 16
    key = jax.random.PRNGKey(4)
    tokens = jax.random.randint(key, (b, s + 1), 0, cfg.vocab)
    # parallel forward over s+1 tokens: logits at position s
    full_logits = lm.forward(params, tokens, cfg, SMOKE)
    want = np.asarray(full_logits[:, s - 0 - 1 + 1], np.float32)  # pos s
    # prefill first s tokens, decode token s
    _, cache = lm.prefill(params, tokens[:, :s], cfg, SMOKE,
                          max_seq=s + 8)
    pos = jnp.full((b,), s, jnp.int32)
    got_logits, _ = lm.decode_step(params, cache, tokens[:, s:s + 1], pos,
                                   cfg, SMOKE)
    got = np.asarray(got_logits[:, 0], np.float32)
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    import dataclasses
    rows = {
        "mamba2_370m": dict(n_layers=48, d_model=1024, vocab=50280,
                            ssm_state=128),
        "stablelm_12b": dict(n_layers=40, d_model=5120, n_heads=32,
                             n_kv_heads=8, d_ff=13824, vocab=100352),
        "gemma3_27b": dict(n_layers=62, d_model=5376, n_heads=32,
                           n_kv_heads=16, d_ff=21504, vocab=262144),
        "qwen15_32b": dict(n_layers=64, d_model=5120, n_heads=40,
                           n_kv_heads=40, d_ff=27392, vocab=152064,
                           qkv_bias=True),
        "starcoder2_15b": dict(n_layers=40, d_model=6144, n_heads=48,
                               n_kv_heads=4, d_ff=24576, vocab=49152),
        "arctic_480b": dict(n_layers=35, d_model=7168, n_heads=56,
                            n_kv_heads=8, d_ff=4864, vocab=32000,
                            n_experts=128, top_k=2, dense_residual=True),
        "deepseek_moe_16b": dict(n_layers=28, d_model=2048, n_heads=16,
                                 n_kv_heads=16, d_ff=1408, vocab=102400,
                                 n_experts=64, top_k=6,
                                 n_shared_experts=2),
        "whisper_medium": dict(n_layers=24, d_model=1024, n_heads=16,
                               n_kv_heads=16, d_ff=4096, vocab=51865,
                               encoder_layers=24),
        "recurrentgemma_9b": dict(n_layers=38, d_model=4096, n_heads=16,
                                  n_kv_heads=1, d_ff=12288, vocab=256000),
        "internvl2_26b": dict(n_layers=48, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab=92553),
    }
    for arch, want in rows.items():
        cfg = get_config(arch)
        for k, v in want.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_param_counts_in_expected_range():
    """Sanity: analytical parameter counts are in the ballpark the model
    names claim (within ~40% — configs from the brief, not HF exact)."""
    expect = {
        "mamba2_370m": 370e6, "stablelm_12b": 12e9, "gemma3_27b": 27e9,
        "qwen15_32b": 32e9, "starcoder2_15b": 15e9, "arctic_480b": 480e9,
        "deepseek_moe_16b": 16e9, "whisper_medium": 769e6,
        "recurrentgemma_9b": 9e9, "internvl2_26b": 20e9,
    }
    for arch, want in expect.items():
        n = get_config(arch).param_count()
        assert 0.45 * want < n < 1.8 * want, (arch, n, want)
