"""Substrate tests: checkpoint/restart, data pipeline, ordered reduction,
elastic scaling, straggler invariance, serving determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ck
from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, batch_at
from repro.models import lm
from repro.optim import ordered_ring_reduce, ordered_tree_sum
from repro.runtime.elastic import ElasticLaneManager, ScalingEvent
from repro.runtime.straggler import commit_deadline_policy, simulate_arrivals
from repro.runtime.shardings import SMOKE
from repro.serve.session import Session
from repro.train import make_train_step
from repro.train.train_step import init_state


# ------------------------------------------------------------ checkpoint
class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        cfg = get_smoke_config("stablelm_12b")
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        state = init_state(params)
        ck.save(str(tmp_path), 7, state, extra={"data_step": 7})
        assert ck.latest_step(str(tmp_path)) == 7
        restored, extra = ck.restore(str(tmp_path), 7, state)
        assert extra == {"data_step": 7}
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_no_partial(self, tmp_path):
        state = {"w": jnp.ones((4, 4))}
        ck.save(str(tmp_path), 1, state)
        # a .tmp dir from a crashed save must not count as a checkpoint
        os.makedirs(os.path.join(str(tmp_path), "step_2.tmp_0"))
        assert ck.latest_step(str(tmp_path)) == 1

    def test_prune(self, tmp_path):
        state = {"w": jnp.ones((2,))}
        for s in (1, 2, 3, 4, 5):
            ck.save(str(tmp_path), s, state)
        ck.prune(str(tmp_path), keep=2)
        assert ck.latest_step(str(tmp_path)) == 5
        assert sorted(os.listdir(str(tmp_path))) == ["step_4", "step_5"]

    def test_restart_reproduces_run_bitwise(self, tmp_path):
        """Train 4 steps straight vs train 2 + checkpoint + restore +
        train 2: identical parameters (deterministic restart)."""
        cfg = get_smoke_config("stablelm_12b")
        params = lm.init_params(jax.random.PRNGKey(1), cfg)
        dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
        step = jax.jit(make_train_step(cfg, SMOKE, mode="pot",
                                       remat=False))

        s_a = init_state(params)
        for i in range(4):
            s_a, _ = step(s_a, batch_at(dcfg, i))

        s_b = init_state(params)
        for i in range(2):
            s_b, _ = step(s_b, batch_at(dcfg, i))
        ck.save(str(tmp_path), 2, s_b, extra={"data_step": 2})
        s_c, extra = ck.restore(str(tmp_path), 2, s_b)
        for i in range(extra["data_step"], 4):
            s_c, _ = step(s_c, batch_at(dcfg, i))

        for a, b in zip(jax.tree.leaves(s_a.params),
                        jax.tree.leaves(s_c.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------------ data
class TestData:
    def test_deterministic_per_step(self):
        cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8)
        a = batch_at(cfg, 5)
        b = batch_at(cfg, 5)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))

    def test_steps_differ(self):
        cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8)
        a = batch_at(cfg, 1)["tokens"]
        b = batch_at(cfg, 2)["tokens"]
        assert not np.array_equal(np.asarray(a), np.asarray(b))

    def test_host_sharding_disjoint_and_deterministic(self):
        base = DataConfig(vocab=500, seq_len=16, global_batch=8, n_hosts=2)
        h0 = batch_at(base, 3)
        h1 = batch_at(DataConfig(vocab=500, seq_len=16, global_batch=8,
                                 n_hosts=2, host_id=1), 3)
        assert h0["tokens"].shape == (4, 16)
        assert not np.array_equal(np.asarray(h0["tokens"]),
                                  np.asarray(h1["tokens"]))

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
        b = batch_at(cfg, 0)
        np.testing.assert_array_equal(np.asarray(b["labels"][:, :-1]),
                                      np.asarray(b["tokens"][:, 1:]))
        assert (np.asarray(b["labels"][:, -1]) == -1).all()


# -------------------------------------------------------- ordered reduce
class TestOrderedReduce:
    def test_tree_sum_matches_sum(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(7, 13)),
                        jnp.float32)
        got = ordered_tree_sum(x)
        # fixed tree order != jnp's reduction order: agreement is only up
        # to f32 associativity (~2.5e-6 rel on this draw), same bound the
        # ring-reduce test uses.  Bitwise determinism is asserted by
        # test_tree_sum_fixed_order, not here.
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(x.sum(0)), rtol=1e-5)

    def test_tree_sum_fixed_order(self):
        """Same values, same order -> bitwise equal across calls."""
        x = jnp.asarray(np.random.default_rng(1).normal(size=(8, 64)),
                        jnp.float32)
        a = np.asarray(ordered_tree_sum(x))
        b = np.asarray(ordered_tree_sum(x))
        assert a.tobytes() == b.tobytes()

    def test_ring_reduce_multidevice(self):
        """Needs >1 device: spawn a subprocess with 8 host devices to keep
        this process at 1 device (see conftest note in the brief)."""
        import subprocess
        import sys
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.optim import ordered_ring_reduce
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(8)
x = jnp.arange(8 * 24, dtype=jnp.float32).reshape(8, 24) / 7.0
f = shard_map(lambda y: ordered_ring_reduce(y[0], "data")[None],
              mesh=mesh, in_specs=P("data", None),
              out_specs=P("data", None), check_rep=False)
got = np.asarray(f(x))
want = np.asarray(x.sum(0))
for i in range(8):
    np.testing.assert_allclose(got[i], want, rtol=1e-5)
print("OK")
"""
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True,
                           env={**os.environ, "PYTHONPATH": "src"},
                           cwd=os.path.dirname(os.path.dirname(
                               os.path.abspath(__file__))))
        assert "OK" in r.stdout, r.stderr[-2000:]


# ----------------------------------------------------- elastic/straggler
class TestRuntime:
    def test_elastic_join_leave_deterministic(self):
        ev = [ScalingEvent(at_round=1, action="join", lane_id=7),
              ScalingEvent(at_round=3, action="leave", lane_id=0)]
        a = ElasticLaneManager(2, [ScalingEvent(**vars(e)) for e in ev])
        b = ElasticLaneManager(2, [ScalingEvent(**vars(e)) for e in ev])
        for mgr in (a, b):
            mgr.advance_to(1)
        assert a.live_lanes() == b.live_lanes()
        a.advance_to(3)
        b.advance_to(3)
        assert a.live_lanes() == b.live_lanes()
        assert 0 not in a.live_lanes() and 7 in a.live_lanes()

    def test_straggler_arrivals_seeded(self):
        a = simulate_arrivals(32, n_stragglers=4, seed=9)
        b = simulate_arrivals(32, n_stragglers=4, seed=9)
        np.testing.assert_array_equal(a, b)

    def test_deadline_policy(self):
        assert commit_deadline_policy(5, 4) == "fast"
        assert commit_deadline_policy(8, 4, max_stale=8) == "validate"
        assert commit_deadline_policy(20, 4, max_stale=8) == "rebase"

    def test_pot_invariant_to_straggler_arrivals(self):
        """The core claim: PCC output does not depend on arrival order."""
        import jax
        from repro.core import (RoundRobinSequencer, fingerprint,
                                make_store, pcc_execute)
        from repro.core import workloads as W
        wl = W.vacation_like(n_txns=24, n_objects=128, n_lanes=4, seed=3)
        store = make_store(wl.n_objects)
        seq = np.asarray(RoundRobinSequencer(
            n_root_lanes=4).order_for(wl.lanes.tolist()))
        fps = set()
        for s in range(4):
            arr = simulate_arrivals(24, n_stragglers=6, seed=s)
            batch_p = jax.tree.map(lambda a: a[arr], wl.batch)
            out, _ = pcc_execute(store, batch_p,
                                 jnp.asarray(seq[arr], jnp.int32))
            fps.add(int(fingerprint(out)))
        assert len(fps) == 1


# ----------------------------------------------------------------- serve
class TestServe:
    def test_session_replicas_identical(self):
        cfg = get_smoke_config("stablelm_12b")
        params = lm.init_params(jax.random.PRNGKey(5), cfg)

        def run_replica():
            s = Session(cfg, params, n_slots=4, max_seq=32)
            for i in range(4):
                s.add_request(i, first_token=i + 1)
            toks = s.generate(6)
            return toks, s.fingerprint()

        t1, f1 = run_replica()
        t2, f2 = run_replica()
        np.testing.assert_array_equal(t1, t2)
        assert f1 == f2

    def test_page_versions_record_commit_order(self):
        cfg = get_smoke_config("stablelm_12b")
        params = lm.init_params(jax.random.PRNGKey(6), cfg)
        s = Session(cfg, params, n_slots=2, max_seq=32)
        s.add_request(0, 3)
        s.add_request(1, 4)
        s.step()
        vers = np.asarray(s.page_versions)
        assert set(vers[vers > 0]) == {1, 2}  # sequence numbers, §3.1
