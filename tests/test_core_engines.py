"""Unit + property tests for the Pot core engines.

The central properties (DESIGN.md §8):
  P1  PCC == PoGL (serial oracle) bitwise, for any transactions + order.
  P2  PCC output is invariant to arrival order / lane count / timing.
  P3  DeSTM-analog == PoGL under the shared round-robin order.
  P4  OCC output DOES depend on the arrival permutation (witness).
  P5  PCC makes progress: rounds <= K; head of prefix always commits.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (MODE_FAST, MODE_PREFIX, NOP, READ, RMW, WRITE,
                        ExplicitSequencer, ReplaySequencer,
                        RoundRobinSequencer, destm_execute, fingerprint,
                        make_batch, make_store, occ_execute, pcc_execute,
                        pogl_execute, run_all)
from repro.core import workloads as W


def _fp(store) -> int:
    return int(fingerprint(store))


def _seq_for(wl, n_lanes=None):
    seqr = RoundRobinSequencer(n_root_lanes=n_lanes or wl.n_lanes)
    return jnp.asarray(seqr.order_for(wl.lanes.tolist()), jnp.int32)


# ---------------------------------------------------------------- txn VM
class TestTxnVM:
    def test_read_your_writes(self):
        # WRITE 5 <- 7 then READ 5 must observe 7, not memory
        batch = make_batch([[(WRITE, 5, False, 7), (READ, 5, False, 0),
                             (WRITE, 6, False, 0)]])
        store = make_store(16)
        res = run_all(batch, store.values)
        # acc after read = 7 -> write to 6 stores acc+0 = 7
        assert int(res.wvals[0, 1, 0]) == 7
        assert int(res.rn[0]) == 1 and int(res.wn[0]) == 2

    def test_indirect_addressing_is_data_dependent(self):
        # M[3] = 9 -> READ 3 (last=9) -> READ indirect 2 => addr (2+9)%16=11
        store = make_store(16, init=np.arange(16))
        batch = make_batch([[(READ, 3, False, 0), (READ, 2, True, 0)]])
        res = run_all(batch, store.values)
        assert int(res.raddrs[0, 1]) == (2 + 3) % 16

    def test_deferred_updates_do_not_mutate(self):
        store = make_store(8)
        batch = make_batch([[(WRITE, 0, False, 42)]])
        run_all(batch, store.values)
        assert int(store.values[0, 0]) == 0

    def test_last_write_wins_within_txn(self):
        batch = make_batch([[(WRITE, 2, False, 1), (WRITE, 2, False, 9)]])
        store = make_store(8)
        seq = jnp.asarray([1], jnp.int32)
        out = pogl_execute(store, batch, seq)
        assert int(out.values[2, 0]) == 9


# ------------------------------------------------------------- sequencer
class TestSequencer:
    def test_round_robin_deterministic(self):
        a = RoundRobinSequencer(n_root_lanes=3).order_for([0, 1, 2, 0, 1, 2])
        b = RoundRobinSequencer(n_root_lanes=3).order_for([0, 1, 2, 0, 1, 2])
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, [1, 2, 3, 4, 5, 6])

    def test_lane_tree_postorder_spawn(self):
        # paper §2.1: t=(a;b;c), u=(d;e;f), b spawns v=(g;h)
        # expected order: a d b e g c f h
        s = RoundRobinSequencer(n_root_lanes=1)
        u = s.spawn_lane(0)
        assert s.lane_order() == [u, 0]  # post-order: children first

    def test_lane_stop_is_deterministic(self):
        s = RoundRobinSequencer(n_root_lanes=2)
        s1 = s.get_seq_no(0)
        s2 = s.get_seq_no(1)
        s.stop_lane(1)
        s3 = s.get_seq_no(0)
        s4 = s.get_seq_no(0)
        assert (s1, s2) == (1, 2)
        # pending round-robin numbers drain, then only lane 0 gets numbers
        assert s3 < s4

    def test_replay_sequencer(self):
        rs = ReplaySequencer([2, 0, 1])
        np.testing.assert_array_equal(rs.order_for([0, 0, 0]), [2, 3, 1])

    def test_explicit_sequencer_detects_hang(self):
        es = ExplicitSequencer(["a", "b", "c"])
        with pytest.raises(RuntimeError, match="waits forever"):
            es.order_for(["a", "b"])  # 'c' never executes -> would hang


# ------------------------------------------------ serializability (P1,P3)
WORKLOADS = [
    W.counters(n_txns=16, n_objects=32, n_reads=2, n_writes=2, n_lanes=4,
               skew=1.0, seed=2),
    W.vacation_like(n_txns=20, n_objects=128, n_lanes=4, seed=3),
    W.kmeans_like(n_txns=16, n_lanes=4, seed=4),
    W.ssca2_like(n_txns=24, n_objects=512, n_lanes=8, seed=5),
    W.labyrinth_like(n_txns=8, n_objects=64, path_len=8, n_lanes=4, seed=6),
    W.genome_like(n_txns=16, n_objects=128, n_lanes=4, seed=7),
    W.yada_like(n_txns=12, n_objects=128, n_lanes=4, seed=8),
    W.intruder_like(n_txns=16, n_objects=128, n_lanes=4, seed=9),
    W.bayes_like(n_txns=8, n_objects=64, n_lanes=4, seed=10),
    W.stmbench7_like("rw", n_txns=16, n_objects=256, n_lanes=4, seed=11),
]


@pytest.mark.parametrize("wl", WORKLOADS, ids=lambda w: w.name)
def test_pcc_equals_serial_oracle(wl):
    store = make_store(wl.n_objects)
    seq = _seq_for(wl)
    oracle = pogl_execute(store, wl.batch, seq)
    out, trace = pcc_execute(store, wl.batch, seq)
    np.testing.assert_array_equal(np.asarray(out.values),
                                  np.asarray(oracle.values))
    assert int(out.gv) == wl.batch.n_txns
    assert int(trace.rounds) <= wl.batch.n_txns  # P5 progress


@pytest.mark.parametrize("wl", WORKLOADS[:6], ids=lambda w: w.name)
def test_destm_equals_serial_oracle(wl):
    store = make_store(wl.n_objects)
    seq = _seq_for(wl)
    oracle = pogl_execute(store, wl.batch, seq)
    out, trace = destm_execute(store, wl.batch, seq,
                               jnp.asarray(wl.lanes, jnp.int32), wl.n_lanes)
    np.testing.assert_array_equal(np.asarray(out.values),
                                  np.asarray(oracle.values))


def test_pcc_arrival_invariance():
    """P2: permuting the *storage order* of transactions (arrival) while
    keeping their sequence numbers fixed must not change the outcome."""
    wl = W.vacation_like(n_txns=24, n_objects=128, n_lanes=4, seed=1)
    store = make_store(wl.n_objects)
    seq = np.asarray(_seq_for(wl))
    base_fp = None
    rng = np.random.default_rng(0)
    for _ in range(3):
        perm = rng.permutation(wl.batch.n_txns)
        import jax
        batch_p = jax.tree.map(lambda a: a[perm], wl.batch)
        seq_p = jnp.asarray(seq[perm], jnp.int32)
        out, _ = pcc_execute(store, batch_p, seq_p)
        fp = _fp(out)
        if base_fp is None:
            base_fp = fp
        assert fp == base_fp


def test_occ_is_nondeterministic_witness():
    """P4: the baseline's outcome depends on the interleaving (this is the
    problem Pot exists to remove)."""
    wl = W.counters(n_txns=16, n_objects=8, n_reads=2, n_writes=2,
                    n_lanes=4, skew=0.0, seed=12)
    store = make_store(wl.n_objects)
    k = wl.batch.n_txns
    fps = set()
    rng = np.random.default_rng(3)
    for _ in range(8):
        arrival = jnp.asarray(rng.permutation(k), jnp.int32)
        out, _ = occ_execute(store, wl.batch, arrival)
        fps.add(_fp(out))
    assert len(fps) > 1, "expected arrival-order-dependent outcomes"


def test_occ_record_replay_through_pot():
    """§2.1 record/replay: record an OCC commit order, replay it as the
    sequencer order -> Pot reproduces that exact outcome deterministically."""
    wl = W.vacation_like(n_txns=16, n_objects=64, n_lanes=4, seed=5)
    store = make_store(wl.n_objects)
    arrival = jnp.asarray(np.random.default_rng(9).permutation(16), jnp.int32)
    occ_out, occ_trace = occ_execute(store, wl.batch, arrival)
    commit_pos = np.asarray(occ_trace.commit_pos)
    order = np.argsort(commit_pos)  # txn indices in commit order
    seq = jnp.asarray(ReplaySequencer(order.tolist()).order_for(
        wl.lanes.tolist()), jnp.int32)
    replay_out, _ = pcc_execute(store, wl.batch, seq)
    np.testing.assert_array_equal(np.asarray(replay_out.values),
                                  np.asarray(occ_out.values))


# --------------------------------------------------------- modes (paper §2.2.3)
def test_disjoint_txns_commit_in_one_round_all_fast():
    """Non-conflicting successive transactions all commit simultaneously
    (multiple simultaneous fast transactions)."""
    progs = [[(RMW, i, False, 1)] for i in range(8)]
    batch = make_batch(progs)
    store = make_store(8)
    seq = jnp.arange(1, 9, dtype=jnp.int32)
    out, trace = pcc_execute(store, batch, seq)
    assert int(trace.rounds) == 1
    mode = np.asarray(trace.mode)
    assert (mode[0] == MODE_FAST) and (mode[1:] == MODE_PREFIX).all()
    assert int(trace.retries.sum()) == 0


def test_fully_conflicting_txns_serialize():
    """All txns RMW the same object -> serialized commits, all in fast
    mode; live promotion (§2.2.3) commits TWO per round (the prefix head
    + the promoted successor), halving the round count vs the Pot*
    ablation — the paper's 'Pot close to PoGL when speculation does not
    help, live promotion pays off' observation."""
    progs = [[(RMW, 0, False, 1)] for _ in range(6)]
    batch = make_batch(progs)
    store = make_store(4)
    seq = jnp.arange(1, 7, dtype=jnp.int32)
    out, trace = pcc_execute(store, batch, seq)
    assert int(out.values[0, 0]) == 6
    assert int(trace.rounds) == 3           # head + promotion per round
    assert int(trace.promotions) == 3
    assert (np.asarray(trace.mode) == MODE_FAST).all()
    # Pot* ablation: no promotion -> one commit per round
    out2, trace2 = pcc_execute(store, batch, seq, live_promotion=False)
    np.testing.assert_array_equal(np.asarray(out2.values),
                                  np.asarray(out.values))
    assert int(trace2.rounds) == 6 and int(trace2.promotions) == 0


def test_live_promotion_matches_oracle_on_workloads():
    """Promotion must never change outcomes, only round counts."""
    from repro.core import workloads as W
    for wl in [W.vacation_like(n_txns=20, n_objects=64, n_lanes=4, seed=8),
               W.kmeans_like(n_txns=16, n_lanes=4, seed=9)]:
        store = make_store(wl.n_objects)
        seq = _seq_for(wl)
        oracle = pogl_execute(store, wl.batch, seq)
        for lp in (False, True):
            out, tr = pcc_execute(store, wl.batch, seq, live_promotion=lp)
            np.testing.assert_array_equal(np.asarray(out.values),
                                          np.asarray(oracle.values))
        out_lp, tr_lp = pcc_execute(store, wl.batch, seq)
        out_np, tr_np = pcc_execute(store, wl.batch, seq,
                                    live_promotion=False)
        assert int(tr_lp.rounds) <= int(tr_np.rounds)


def test_versions_are_sequence_numbers():
    """§3.1: sequence numbers retrofitted as versions — after commit, each
    object's version equals the seq number of its last writer."""
    progs = [[(WRITE, 0, False, 5)], [(WRITE, 1, False, 6)],
             [(WRITE, 0, False, 7)]]
    batch = make_batch(progs)
    store = make_store(4)
    seq = jnp.asarray([1, 2, 3], jnp.int32)
    out, _ = pcc_execute(store, batch, seq)
    assert int(out.versions[0]) == 3   # last writer of obj 0 was txn seq 3
    assert int(out.versions[1]) == 2
    assert int(out.gv) == 3


def test_gv_accumulates_across_batches():
    progs = [[(RMW, 0, False, 1)]]
    batch = make_batch(progs)
    store = make_store(2)
    store, _ = pcc_execute(store, batch, jnp.asarray([1], jnp.int32))
    store, _ = pcc_execute(store, batch, jnp.asarray([1], jnp.int32))
    assert int(store.gv) == 2
    assert int(store.values[0, 0]) == 2


# --------------------------------------------------------------- hypothesis
@st.composite
def txn_programs(draw):
    n_objects = draw(st.sampled_from([4, 8, 16]))
    k = draw(st.integers(1, 10))
    progs = []
    for _ in range(k):
        n_ins = draw(st.integers(1, 5))
        ins = []
        for _ in range(n_ins):
            op = draw(st.sampled_from([READ, WRITE, RMW]))
            addr = draw(st.integers(0, n_objects - 1))
            ind = draw(st.booleans())
            val = draw(st.integers(-3, 3))
            ins.append((op, addr, ind, val))
        progs.append(ins)
    return n_objects, progs


@settings(max_examples=25, deadline=None)
@given(txn_programs(), st.randoms(use_true_random=False))
def test_property_pcc_serializable_and_arrival_invariant(programs, rnd):
    """P1+P2 under random programs, including indirect addressing."""
    import jax
    n_objects, progs = programs
    batch = make_batch(progs)
    k = batch.n_txns
    store = make_store(n_objects, init=np.arange(n_objects) % 5)
    seq = jnp.arange(1, k + 1, dtype=jnp.int32)
    oracle = pogl_execute(store, batch, seq)
    out, _ = pcc_execute(store, batch, seq)
    np.testing.assert_array_equal(np.asarray(out.values),
                                  np.asarray(oracle.values))
    # arrival invariance: permute storage order
    perm = list(range(k))
    rnd.shuffle(perm)
    perm = np.asarray(perm)
    batch_p = jax.tree.map(lambda a: a[perm], batch)
    out_p, _ = pcc_execute(store, batch_p,
                           jnp.asarray(np.asarray(seq)[perm], jnp.int32))
    np.testing.assert_array_equal(np.asarray(out_p.values),
                                  np.asarray(oracle.values))


@settings(max_examples=15, deadline=None)
@given(txn_programs())
def test_property_destm_matches_oracle(programs):
    n_objects, progs = programs
    batch = make_batch(progs)
    k = batch.n_txns
    n_lanes = min(4, k)
    lanes = jnp.asarray(np.arange(k) % n_lanes, jnp.int32)
    store = make_store(n_objects)
    seq = jnp.arange(1, k + 1, dtype=jnp.int32)
    oracle = pogl_execute(store, batch, seq)
    out, _ = destm_execute(store, batch, seq, lanes, n_lanes)
    np.testing.assert_array_equal(np.asarray(out.values),
                                  np.asarray(oracle.values))
