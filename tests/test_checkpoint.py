"""Crash-consistent session snapshots (PR 9): format + recovery invariant.

Properties:
  C1  Atomic commit: atomic_dir materializes a directory all-or-nothing
      — a failure mid-write leaves the previous contents (and a *.tmp*
      turd readers skip), never a half-written final dir.
  C2  Self-verification: a snapshot proves itself complete before
      serving — per-file sha256 digests, the store fingerprint, and the
      chained snapshot digest all re-verify; any corruption raises
      SnapshotError, and latest_snapshot falls back to the newest
      snapshot that verifies.
  C3  Recovery invariant: restore(snapshot) + drain(arrival-journal
      suffix) is bit-identical (fingerprints, traces, replay_log()) to
      the uninterrupted run — at any snapshot point (including batch 0
      and after the final batch), under different drain-budget
      schedules, across store reshards S -> S' and bucket-ladder
      changes, and idempotently (restoring twice changes nothing).
  C4  Sequencer cursors round-trip: a RoundRobinSequencer snapshotted
      mid-refill (pending numbers outstanding) resumes the SAME global
      numbering; replay/explicit sequencers round-trip too.
"""

import os

import numpy as np
import pytest

from repro.core import (IngressPool, PotSession, SnapshotError,
                        latest_snapshot, load_snapshot, restore_session,
                        sequencer_from_state, sequencer_state,
                        trace_digest)
from repro.core import workloads as W
from repro.core.checkpoint import atomic_dir, snapshot_ids
from repro.core.ingress import programs_from_batch
from repro.core.sequencer import (ExplicitSequencer, ReplaySequencer,
                                  RoundRobinSequencer)

from _hypothesis_compat import given, settings, st

N_OBJECTS = 64
N_LANES = 6
BUDGETS = (7, 11)


def _journal(n_txns=60, seed=3):
    wl = W.counters(n_txns=n_txns, n_objects=N_OBJECTS, n_reads=2,
                    n_writes=2, n_lanes=N_LANES, skew=0.7, seed=seed)
    pool = IngressPool(capacity=512)
    for i, p in enumerate(programs_from_batch(wl.batch)):
        pool.admit(p, lane=i % N_LANES, fee=i % 5)
    return pool.arrival_journal()


JOURNAL = _journal()


def _session(**kw):
    kw.setdefault("engine", "pcc")
    kw.setdefault("n_lanes", N_LANES)
    return PotSession(N_OBJECTS, **kw)


def _drain_through(session, pool, budgets=BUDGETS):
    """The deterministic replica loop body: budgets indexed by the
    formed-batch cursor, so a restored session re-enters the schedule
    where the snapshot left it."""
    while True:
        fb = pool.drain(budgets[session.batches_formed % len(budgets)])
        if fb is None:
            break
        session._serve_formed(fb)
    session._spec_flush()
    return session


def _uninterrupted(**kw):
    pool, _ = IngressPool.replay(JOURNAL)
    return _drain_through(_session(**kw), pool)


def _interrupted(tmp_path, snapshot_after, budgets=BUDGETS, restore_kw=None,
                 **kw):
    """Serve ``snapshot_after`` batches, snapshot, restore into a fresh
    session, finish the stream there.  Returns the restored session."""
    pool, _ = IngressPool.replay(JOURNAL)
    s = _session(**kw)
    for _ in range(snapshot_after):
        fb = pool.drain(budgets[s.batches_formed % len(budgets)])
        if fb is None:
            break
        s._serve_formed(fb)
    s.snapshot(str(tmp_path), pool=pool)
    s2, p2 = PotSession.restore(str(tmp_path), arrival_journal=JOURNAL,
                                **(restore_kw or {}))
    return _drain_through(s2, p2, budgets)


def _assert_bitwise_equal(restored, baseline):
    assert restored.fingerprint() == baseline.fingerprint()
    assert restored.replay_log() == baseline.replay_log()
    assert restored.gv == baseline.gv
    assert restored.n_txns == baseline.n_txns
    bd = [trace_digest(t) for t in baseline.traces]
    rd = [trace_digest(t) for t in restored.traces]
    assert rd == bd[len(bd) - len(rd):]


# ------------------------------------------------------------- C1 atomic
def test_atomic_dir_commits_all_or_nothing(tmp_path):
    final = str(tmp_path / "out")
    with atomic_dir(final) as tmp:
        with open(os.path.join(tmp, "a.txt"), "w") as f:
            f.write("v1")
    assert open(os.path.join(final, "a.txt")).read() == "v1"

    # a failure mid-write must leave v1 intact and the turd visible
    with pytest.raises(RuntimeError, match="boom"):
        with atomic_dir(final) as tmp:
            with open(os.path.join(tmp, "a.txt"), "w") as f:
                f.write("v2")
            raise RuntimeError("boom")
    assert open(os.path.join(final, "a.txt")).read() == "v1"
    assert os.path.isdir(final + ".tmp")

    # the next attempt replaces the turd and commits
    with atomic_dir(final) as tmp:
        with open(os.path.join(tmp, "a.txt"), "w") as f:
            f.write("v3")
    assert open(os.path.join(final, "a.txt")).read() == "v3"
    assert not os.path.exists(final + ".tmp")


# ---------------------------------------------------- C2 self-verification
def test_snapshot_self_verifies_and_detects_corruption(tmp_path):
    pool, _ = IngressPool.replay(JOURNAL)
    s = _session()
    for _ in range(2):
        s._serve_formed(pool.drain(8))
    path = s.snapshot(str(tmp_path), pool=pool)
    load_snapshot(path)     # verifies digests + fingerprint + chain

    # corrupt the store payload: the file digest catches it
    store_file = os.path.join(path, "store.npz")
    data = open(store_file, "rb").read()
    with open(store_file, "wb") as f:
        f.write(data[: len(data) // 2])
    with pytest.raises(SnapshotError, match="corrupted"):
        load_snapshot(path)


def test_latest_snapshot_falls_back_past_corruption(tmp_path):
    pool, _ = IngressPool.replay(JOURNAL)
    s = _session()
    s._serve_formed(pool.drain(8))
    p0 = s.snapshot(str(tmp_path), pool=pool)
    s._serve_formed(pool.drain(8))
    p1 = s.snapshot(str(tmp_path), pool=pool)
    assert snapshot_ids(str(tmp_path)) == [0, 1]
    assert latest_snapshot(str(tmp_path)) == p1
    # corrupt the newest: the latest COMPLETE snapshot is the older one
    os.remove(os.path.join(p1, "store.npz"))
    assert latest_snapshot(str(tmp_path)) == p0


def test_chain_digest_detects_tampered_manifest(tmp_path):
    import json
    pool, _ = IngressPool.replay(JOURNAL)
    s = _session()
    s._serve_formed(pool.drain(8))
    path = s.snapshot(str(tmp_path), pool=pool)
    mpath = os.path.join(path, "manifest.json")
    manifest = json.load(open(mpath))
    manifest["replay_log"] = list(reversed(manifest["replay_log"]))
    json.dump(manifest, open(mpath, "w"))
    with pytest.raises(SnapshotError, match="chain digest"):
        load_snapshot(path)


def test_restore_refuses_empty_directory(tmp_path):
    with pytest.raises(SnapshotError, match="no complete snapshot"):
        restore_session(str(tmp_path))


# ------------------------------------------------- C3 recovery invariant
def test_restore_midstream_is_bitwise_identical(tmp_path):
    base = _uninterrupted()
    restored = _interrupted(tmp_path, snapshot_after=3)
    assert restored.restored_from == 0
    assert restored.recovery_batches == len(restored.traces) > 0
    _assert_bitwise_equal(restored, base)


def test_snapshot_at_batch_zero(tmp_path):
    base = _uninterrupted()
    restored = _interrupted(tmp_path, snapshot_after=0)
    # the whole stream replays from the empty snapshot
    assert restored.n_txns == base.n_txns
    _assert_bitwise_equal(restored, base)


def test_snapshot_after_final_batch(tmp_path):
    base = _uninterrupted()
    restored = _interrupted(tmp_path, snapshot_after=99)
    # nothing left to drain: the restored session IS the final state
    assert restored.recovery_batches == 0
    _assert_bitwise_equal(restored, base)


def test_restore_under_a_different_budget_schedule(tmp_path):
    """The snapshot pins the formed-batch cursor, not the budgets: a
    replica restoring into a different schedule still converges to that
    schedule's uninterrupted stream (PCC: budget-partition invariant)."""
    pool, _ = IngressPool.replay(JOURNAL)
    base = _drain_through(_session(), pool, budgets=(5, 9, 3))
    restored = _interrupted(tmp_path, snapshot_after=2, budgets=(5, 9, 3))
    _assert_bitwise_equal(restored, base)


def test_restore_into_different_shards(tmp_path):
    base = _uninterrupted()
    restored = _interrupted(tmp_path, snapshot_after=3,
                            restore_kw={"shards": 4}, shards=8)
    assert restored.store.layout.shards == 4
    _assert_bitwise_equal(restored, base)
    # and back down to the dense store
    dense = _interrupted(tmp_path, snapshot_after=2,
                         restore_kw={"shards": 1}, shards=8)
    assert dense.store.layout.shards == 1
    _assert_bitwise_equal(dense, base)


def test_restore_into_different_bucket_ladder(tmp_path):
    """Bucketing never changes commits (vacant rows), so restoring into
    the other ladder family is still bit-identical."""
    base = _uninterrupted(bucket_ladder="pow2")
    restored = _interrupted(tmp_path, snapshot_after=3,
                            restore_kw={"bucket_ladder": "dense",
                                        "pipeline_depth": 2},
                            bucket_ladder="pow2")
    assert restored.bucket_ladder == "dense"
    _assert_bitwise_equal(restored, base)


def test_double_restore_is_idempotent(tmp_path):
    base = _uninterrupted()
    pool, _ = IngressPool.replay(JOURNAL)
    s = _session()
    for _ in range(3):
        fb = pool.drain(BUDGETS[s.batches_formed % 2])
        s._serve_formed(fb)
    s.snapshot(str(tmp_path), pool=pool)

    outcomes = []
    for _ in range(2):      # restore TWICE from the same snapshot
        s2, p2 = PotSession.restore(str(tmp_path), arrival_journal=JOURNAL)
        _drain_through(s2, p2)
        outcomes.append((s2.fingerprint(), tuple(s2.replay_log()),
                         [trace_digest(t) for t in s2.traces]))
        _assert_bitwise_equal(s2, base)
    assert outcomes[0] == outcomes[1]

    # restore -> snapshot (no new work) -> restore is also stable
    s3, p3 = PotSession.restore(str(tmp_path), arrival_journal=JOURNAL)
    s3.snapshot(str(tmp_path), pool=p3)
    s4, p4 = PotSession.restore(str(tmp_path), arrival_journal=JOURNAL)
    _drain_through(s4, p4)
    _assert_bitwise_equal(s4, base)


def test_pipelined_window_is_flushed_into_snapshot(tmp_path):
    """pipeline_depth > 0: the speculative window is flushed (executed
    and committed) by snapshot(), never persisted speculatively — the
    manifest's txn count equals the committed count at that point."""
    import json
    base = _uninterrupted()
    pool, _ = IngressPool.replay(JOURNAL)
    s = _session(pipeline_depth=2)
    for _ in range(3):
        fb = pool.drain(BUDGETS[s.batches_formed % 2])
        s._serve_formed(fb)
    assert len(s._window) > 0          # speculation genuinely pending
    path = s.snapshot(str(tmp_path), pool=pool)
    assert len(s._window) == 0         # flushed, not persisted
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    assert manifest["n_txns"] == s.n_txns
    s2, p2 = PotSession.restore(str(tmp_path), arrival_journal=JOURNAL)
    _drain_through(s2, p2)
    _assert_bitwise_equal(s2, base)


# ------------------------------------------------- C4 sequencer cursors
def test_run_stream_snapshot_restores_sequencer_cursor(tmp_path):
    """The run_stream path (no pool): a RoundRobinSequencer snapshotted
    mid-stream — with pending pre-assigned numbers outstanding — resumes
    the same global numbering bit-exactly."""
    wls = [W.counters(n_txns=k, n_objects=N_OBJECTS, n_reads=2,
                      n_writes=2, n_lanes=3, skew=0.6, seed=10 + k)
           for k in (5, 9, 7, 11)]
    batches = [w.batch for w in wls]
    lanes = [w.lanes.tolist() for w in wls]

    base = PotSession(N_OBJECTS, engine="pcc", n_lanes=3)
    base.run_stream(batches, lanes)

    s = PotSession(N_OBJECTS, engine="pcc", n_lanes=3)
    s.run_stream(batches[:2], lanes[:2])
    assert any(s.sequencer._pending.values())   # cursor mid-refill
    s.snapshot(str(tmp_path))
    s2, pool2 = PotSession.restore(str(tmp_path))
    assert pool2 is None                        # no pool was snapshotted
    s2.run_stream(batches[2:], lanes[2:])
    _assert_bitwise_equal(s2, base)


def test_sequencer_state_roundtrip_unit():
    r = RoundRobinSequencer(n_root_lanes=2)
    r.spawn_lane(0)
    r.order_for([0, 1, 2, 0])       # leaves pending numbers outstanding
    r.stop_lane(1)
    r2 = sequencer_from_state(sequencer_state(r))
    assert r2.lanes.keys() == r.lanes.keys()
    assert r2._pending == r._pending and r2._next_sn == r._next_sn
    assert np.array_equal(r2.order_for([0, 2, 0]), r.order_for([0, 2, 0]))

    rep = ReplaySequencer([1, 0, 2, 3])
    rep.order_for([0, 0, 0])
    rep2 = sequencer_from_state(sequencer_state(rep))
    assert np.array_equal(rep2.order_for([0]), rep.order_for([0]))
    assert rep2.remaining == rep.remaining == 0

    ex = sequencer_from_state(sequencer_state(ExplicitSequencer(["a", "b"])))
    assert np.array_equal(ex.order_for(["b", "a"]), [2, 1])

    class Weird:
        pass
    assert sequencer_state(Weird())["type"] == "opaque"
    with pytest.raises(ValueError, match="opaque"):
        sequencer_from_state({"type": "opaque", "class": "Weird"})


# ------------------------------------------- property: any snapshot point
@settings(max_examples=5, deadline=None)
@given(point=st.integers(min_value=0, max_value=6),
       schedule=st.sampled_from([(7, 11), (5, 9, 3)]))
def test_property_restored_equals_uninterrupted(tmp_path_factory, point,
                                                schedule):
    """C3 as a property: for ANY snapshot point and either budget
    schedule, restored == uninterrupted fingerprints + replay logs."""
    tmp_path = tmp_path_factory.mktemp("snap")
    pool, _ = IngressPool.replay(JOURNAL)
    base = _drain_through(_session(), pool, budgets=schedule)
    restored = _interrupted(tmp_path, snapshot_after=point,
                            budgets=schedule)
    _assert_bitwise_equal(restored, base)
