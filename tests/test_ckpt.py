"""Trainer-facing pytree checkpoints (repro.ckpt.checkpoint).

The module rides on the shared :func:`repro.core.checkpoint.atomic_dir`
commit helper (PR 9 factored it out of the old inline tmp/rename code),
so the crash-safety tests here double as coverage for that helper under
the trainer layout: a crash at ANY point mid-save leaves either the
previous complete checkpoint or a ``*.tmp*`` turd that ``latest_step``
and ``prune`` never list.
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ck


def _state(seed=0):
    k = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(k.normal(size=(4, 3)).astype("float32")),
                   "b": jnp.asarray(k.normal(size=(3,)).astype("float32"))},
        "opt": {"mu": jnp.zeros((4, 3)), "step": jnp.asarray(7, jnp.int32)},
    }


def _assert_tree_equal(a, b):
    import jax
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_save_restore_round_trip_with_extra(tmp_path):
    state = _state()
    extra = {"gv": 123, "pipeline_step": 9}
    path = ck.save(str(tmp_path), 5, state, extra=extra)
    assert os.path.basename(path) == "step_5"
    restored, got_extra = ck.restore(str(tmp_path), 5, _state(seed=1))
    _assert_tree_equal(restored, state)
    assert got_extra == extra


def test_latest_step_ignores_tmp_turds(tmp_path):
    assert ck.latest_step(str(tmp_path)) is None
    ck.save(str(tmp_path), 1, _state())
    ck.save(str(tmp_path), 3, _state())
    os.makedirs(tmp_path / "step_9.tmp_0")       # simulated torn save
    assert ck.latest_step(str(tmp_path)) == 3


def test_overwrite_existing_step_wins(tmp_path):
    ck.save(str(tmp_path), 2, _state(seed=0))
    newer = _state(seed=42)
    ck.save(str(tmp_path), 2, newer)
    restored, _ = ck.restore(str(tmp_path), 2, _state(seed=1))
    _assert_tree_equal(restored, newer)


def test_crash_mid_save_keeps_previous_checkpoint(tmp_path, monkeypatch):
    ck.save(str(tmp_path), 1, _state(seed=0))
    boom = RuntimeError("torn write")
    real_savez = np.savez      # ck.np IS this numpy module: avoid recursion

    def dying_savez(path, **kw):
        real_savez(path, **kw)
        with open(path, "r+b") as f:     # corrupt, then die pre-commit
            f.truncate(8)
        raise boom

    monkeypatch.setattr(ck.np, "savez", dying_savez)
    with pytest.raises(RuntimeError, match="torn write"):
        ck.save(str(tmp_path), 2, _state(seed=1))
    monkeypatch.undo()
    # step_2 was never committed; step_1 still restores intact
    assert ck.latest_step(str(tmp_path)) == 1
    restored, _ = ck.restore(str(tmp_path), 1, _state(seed=3))
    _assert_tree_equal(restored, _state(seed=0))


def test_prune_keeps_newest_and_skips_turds(tmp_path):
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, _state(seed=s))
    os.makedirs(tmp_path / "step_0.tmp_0")
    ck.prune(str(tmp_path), keep=2)
    kept = sorted(d for d in os.listdir(tmp_path) if "tmp" not in d)
    assert kept == ["step_4", "step_5"]
    assert (tmp_path / "step_0.tmp_0").is_dir()  # prune never touches turds
    restored, _ = ck.restore(str(tmp_path), 5, _state(seed=9))
    _assert_tree_equal(restored, _state(seed=5))
