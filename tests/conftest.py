"""Shared test harness hygiene.

The suite jit-compiles thousands of (engine, shape, path) variants.  On
CPU every compiled XLA executable keeps its own code pages mapped, and
the kernel's default ``vm.max_map_count`` (65530) is low enough that a
full serial run can exhaust the process VMA table and segfault inside a
late LLVM compile — deterministically at the suite's biggest graph,
while any module in isolation passes.  Dropping the compile caches at
module boundaries bounds the map count at the cost of re-compiling the
shapes shared across modules.
"""

import gc

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _bound_compile_cache_maps():
    yield
    jax.clear_caches()
    gc.collect()
