"""Import hypothesis, or stub it so test modules still collect.

The tier-1 image does not ship ``hypothesis`` (it is a test extra in
pyproject.toml).  Modules using property tests import ``given`` /
``settings`` / ``st`` from here: with hypothesis installed they are the
real thing; without it the property tests are collected as skips and
the deterministic tests keep running.
"""

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis is not installed")

    def settings(*_a, **_k):
        return lambda fn: fn

    class _StrategiesStub:
        @staticmethod
        def composite(_fn):
            return lambda *a, **k: None

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategiesStub()
