"""Equivalence tests for the vectorized commit pipeline (PR 2).

The pipeline (protocol.conflict_table -> prefix_commit / wave_commit ->
fused_write_back) must reproduce the pre-refactor scan machinery
bit-exactly:

  * matrix-fixpoint prefix decisions == a pure-NumPy reference scan
    (hypothesis property + fixed regression vectors at K in {1, 2, 64});
  * every engine's final TStore image and ExecTrace
    commit_pos/mode/retries == the preserved scan engines
    (repro.core.legacy_scan);
  * the sort-based dedup_last_writer == the old all-pairs mask.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (READ, RMW, WRITE, ReplaySequencer,
                        RoundRobinSequencer, destm_execute, fingerprint,
                        make_batch, make_store, occ_execute, pcc_execute,
                        run_all)
from repro.core import legacy_scan, protocol
from repro.core import workloads as W
from repro.core.engine import rank_from_order, seq_rank


# ----------------------------------------------------------- NumPy oracles
def _footprints(batch, values):
    """Host-side copies of one speculative round's footprints."""
    res = run_all(batch, values)
    return (np.asarray(res.raddrs), np.asarray(res.rn),
            np.asarray(res.waddrs), np.asarray(res.wn), res)


def numpy_prefix_reference(raddrs, rn, waddrs, wn, order, n_comm):
    """Pure-NumPy transliteration of the legacy PCC commit scan: walk the
    sequence order, commit the maximal prefix of pending txns whose
    footprints miss the writes of earlier committers."""
    k = len(order)
    written = set()
    alive = True
    committing = np.zeros(k, bool)
    for p in range(k):
        t = order[p]
        pending = p >= n_comm
        foot = set(raddrs[t][:rn[t]].tolist()) | set(waddrs[t][:wn[t]].tolist())
        conflict = bool(foot & written)
        c = alive and pending and not conflict
        if c:
            written |= set(waddrs[t][:wn[t]].tolist())
        committing[p] = c
        alive = alive and (c or not pending)
    return committing


def numpy_wave_reference(raddrs, rn, waddrs, wn, arrival, pending):
    """Pure-NumPy transliteration of the legacy OCC wave scan (greedy,
    no prefix cutoff)."""
    k = len(arrival)
    written = set()
    committing = np.zeros(k, bool)
    for p in range(k):
        t = arrival[p]
        foot = set(raddrs[t][:rn[t]].tolist()) | set(waddrs[t][:wn[t]].tolist())
        c = bool(pending[p]) and not (foot & written)
        if c:
            written |= set(waddrs[t][:wn[t]].tolist())
        committing[p] = c
    return committing


def _prefix_pos(res, order, n_comm, n_objects, use_matrix=False):
    """Run the pipeline's prefix decision; return it in POSITION space
    (to compare against the position-space NumPy reference)."""
    order = jnp.asarray(np.asarray(order), jnp.int32)
    rank = rank_from_order(order)
    conflict = protocol.conflict_table(res, n_objects, use_matrix=use_matrix)
    committing_t = protocol.prefix_commit(
        res, conflict, order, rank, jnp.asarray(n_comm, jnp.int32), n_objects)
    return np.asarray(committing_t)[np.asarray(order)]


def _wave_pos(res, arrival, pending_pos, n_objects, use_matrix=False):
    """Run the pipeline's wave decision; return it in POSITION space."""
    arrival_np = np.asarray(arrival)
    arrival = jnp.asarray(arrival_np, jnp.int32)
    rank = rank_from_order(arrival)
    pending_t = np.zeros(len(arrival_np), bool)
    pending_t[arrival_np] = pending_pos
    conflict = protocol.conflict_table(res, n_objects, use_matrix=use_matrix)
    committing_t, _trips = protocol.wave_commit(
        res, conflict, jnp.asarray(pending_t), rank, n_objects)
    return np.asarray(committing_t)[arrival_np]


# ----------------------------------------------- fixed vectors, K in {1,2,64}
def test_prefix_regression_k1():
    batch = make_batch([[(RMW, 0, False, 1)]])
    store = make_store(4)
    res = run_all(batch, store.values)
    np.testing.assert_array_equal(
        _prefix_pos(res, [0], 0, 4), [True])  # head always commits


def test_prefix_regression_k2_conflict_pair():
    batch = make_batch([[(RMW, 3, False, 1)], [(RMW, 3, False, 1)]])
    store = make_store(4)
    res = run_all(batch, store.values)
    for order in ([0, 1], [1, 0]):
        np.testing.assert_array_equal(
            _prefix_pos(res, order, 0, 4), [True, False])
    # disjoint pair: both commit
    batch2 = make_batch([[(RMW, 0, False, 1)], [(RMW, 1, False, 1)]])
    res2 = run_all(batch2, store.values)
    np.testing.assert_array_equal(
        _prefix_pos(res2, [0, 1], 0, 4), [True, True])


@pytest.mark.parametrize("n_comm", [0, 7, 63])
def test_prefix_regression_k64(n_comm):
    wl = W.counters(n_txns=64, n_objects=48, n_reads=2, n_writes=2,
                    n_lanes=8, skew=0.8, seed=13)
    store = make_store(wl.n_objects, init=np.arange(wl.n_objects) % 7)
    rng = np.random.default_rng(n_comm)
    order = rng.permutation(64)
    raddrs, rn, waddrs, wn, res = _footprints(wl.batch, store.values)
    exp = numpy_prefix_reference(raddrs, rn, waddrs, wn, order, n_comm)
    # both conflict formulations must match the reference scan exactly
    for use_matrix in (False, True):
        np.testing.assert_array_equal(
            _prefix_pos(res, order, n_comm, wl.n_objects,
                        use_matrix=use_matrix), exp,
            err_msg=f"use_matrix={use_matrix}")


@pytest.mark.parametrize("k", [1, 2, 64])
def test_wave_regression(k):
    wl = W.counters(n_txns=k, n_objects=max(4, k // 4), n_reads=1,
                    n_writes=2, n_lanes=4, skew=0.5, seed=21)
    store = make_store(wl.n_objects)
    rng = np.random.default_rng(k)
    arrival = rng.permutation(k)
    pending = rng.random(k) < 0.8
    raddrs, rn, waddrs, wn, res = _footprints(wl.batch, store.values)
    exp = numpy_wave_reference(raddrs, rn, waddrs, wn, arrival, pending)
    for use_matrix in (False, True):
        got = _wave_pos(res, arrival, pending, wl.n_objects,
                        use_matrix=use_matrix)
        np.testing.assert_array_equal(got, exp,
                                      err_msg=f"use_matrix={use_matrix}")


# ------------------------------------------------------ hypothesis property
@st.composite
def decision_cases(draw):
    n_objects = draw(st.sampled_from([4, 8, 16]))
    k = draw(st.integers(1, 12))
    progs = []
    for _ in range(k):
        n_ins = draw(st.integers(1, 5))
        ins = []
        for _ in range(n_ins):
            op = draw(st.sampled_from([READ, WRITE, RMW]))
            addr = draw(st.integers(0, n_objects - 1))
            ind = draw(st.booleans())
            val = draw(st.integers(-3, 3))
            ins.append((op, addr, ind, val))
        progs.append(ins)
    order = draw(st.permutations(list(range(k))))
    n_comm = draw(st.integers(0, k))
    return n_objects, progs, order, n_comm


@settings(max_examples=40, deadline=None)
@given(decision_cases())
def test_property_matrix_prefix_equals_numpy_scan(case):
    """The matrix-fixpoint prefix equals the pure-NumPy reference scan on
    random batches/orders/pending windows (incl. indirect addressing)."""
    n_objects, progs, order, n_comm = case
    batch = make_batch(progs)
    store = make_store(n_objects, init=np.arange(n_objects) % 5)
    raddrs, rn, waddrs, wn, res = _footprints(batch, store.values)
    order = np.asarray(order)
    exp = numpy_prefix_reference(raddrs, rn, waddrs, wn, order, n_comm)
    pending = np.arange(len(order)) >= n_comm
    exp_w = numpy_wave_reference(raddrs, rn, waddrs, wn, order, pending)
    for use_matrix in (False, True):
        np.testing.assert_array_equal(
            _prefix_pos(res, order, n_comm, n_objects,
                        use_matrix=use_matrix), exp)
        got_w = _wave_pos(res, order, pending, n_objects,
                          use_matrix=use_matrix)
        np.testing.assert_array_equal(got_w, exp_w)


# ------------------------------------------- engine vs legacy-scan equality
ENGINE_WORKLOADS = [
    W.counters(n_txns=1, n_objects=8, n_reads=1, n_writes=1, n_lanes=1,
               skew=0.0, seed=0),
    W.counters(n_txns=2, n_objects=2, n_reads=1, n_writes=2, n_lanes=2,
               skew=0.0, seed=1),
    W.counters(n_txns=64, n_objects=32, n_reads=2, n_writes=2, n_lanes=8,
               skew=1.0, seed=2),
    W.vacation_like(n_txns=24, n_objects=128, n_lanes=4, seed=3),
    W.labyrinth_like(n_txns=8, n_objects=64, path_len=8, n_lanes=4, seed=6),
    W.ssca2_like(n_txns=24, n_objects=512, n_lanes=8, seed=5),
]


def _seq_for(wl):
    seqr = RoundRobinSequencer(n_root_lanes=wl.n_lanes)
    return jnp.asarray(seqr.order_for(wl.lanes.tolist()), jnp.int32)


def _assert_trace_equal(t_old, t_new, fields, ctx):
    for f in fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(t_old, f)), np.asarray(getattr(t_new, f)),
            err_msg=f"{ctx}: trace field {f!r} diverged from legacy scan")


@pytest.mark.parametrize("wl", ENGINE_WORKLOADS, ids=lambda w: f"{w.name}-k{w.batch.n_txns}")
def test_pcc_pipeline_equals_legacy_scan(wl):
    store = make_store(wl.n_objects)
    seq = _seq_for(wl)
    for lp in (True, False):
        out_old, t_old = legacy_scan.pcc_execute_scan(
            store, wl.batch, seq, live_promotion=lp)
        out_new, t_new = pcc_execute(store, wl.batch, seq, live_promotion=lp)
        assert int(fingerprint(out_old)) == int(fingerprint(out_new))
        np.testing.assert_array_equal(np.asarray(out_old.versions),
                                      np.asarray(out_new.versions))
        _assert_trace_equal(
            t_old, t_new,
            ["commit_pos", "mode", "retries", "commit_round", "first_round",
             "wait_rounds", "rounds", "exec_ops", "validation_words",
             "promotions"], f"pcc lp={lp}")


@pytest.mark.parametrize("wl", ENGINE_WORKLOADS, ids=lambda w: f"{w.name}-k{w.batch.n_txns}")
def test_occ_pipeline_equals_legacy_scan(wl):
    store = make_store(wl.n_objects)
    k = wl.batch.n_txns
    for s in range(2):
        arrival = jnp.asarray(np.random.default_rng(s).permutation(k),
                              jnp.int32)
        out_old, t_old = legacy_scan.occ_execute_scan(store, wl.batch, arrival)
        out_new, t_new = occ_execute(store, wl.batch, arrival)
        assert int(fingerprint(out_old)) == int(fingerprint(out_new))
        np.testing.assert_array_equal(np.asarray(out_old.versions),
                                      np.asarray(out_new.versions))
        _assert_trace_equal(
            t_old, t_new,
            ["commit_pos", "retries", "commit_round", "rounds", "exec_ops"],
            f"occ arrival#{s}")


@pytest.mark.parametrize("wl", ENGINE_WORKLOADS, ids=lambda w: f"{w.name}-k{w.batch.n_txns}")
def test_destm_pipeline_equals_legacy_scan(wl):
    store = make_store(wl.n_objects)
    seq = _seq_for(wl)
    lanes = jnp.asarray(wl.lanes, jnp.int32)
    out_old, t_old = legacy_scan.destm_execute_scan(
        store, wl.batch, seq, lanes, wl.n_lanes)
    out_new, t_new = destm_execute(store, wl.batch, seq, lanes, wl.n_lanes)
    assert int(fingerprint(out_old)) == int(fingerprint(out_new))
    np.testing.assert_array_equal(np.asarray(out_old.versions),
                                  np.asarray(out_new.versions))
    _assert_trace_equal(
        t_old, t_new,
        ["commit_pos", "retries", "commit_round", "first_round", "rounds",
         "exec_ops", "barrier_ops"], "destm")


# ------------------------------------------------- fused write-back oracle
def test_fused_write_back_matches_sequential_apply():
    """The one-scatter write-back equals a txn-by-txn apply chain,
    including cross-txn overwrites and within-txn duplicate writes."""
    rng = np.random.default_rng(7)
    k, length, slot, n_obj = 9, 5, 2, 12
    waddrs = jnp.asarray(rng.integers(0, n_obj, (k, length)), jnp.int32)
    wvals = jnp.asarray(rng.integers(-9, 9, (k, length, slot)), jnp.int32)
    wn = jnp.asarray(rng.integers(0, length + 1, (k,)), jnp.int32)
    committing = jnp.asarray(rng.random(k) < 0.7)
    seq_nos = jnp.arange(100, 100 + k, dtype=jnp.int32)
    values0 = jnp.asarray(rng.integers(0, 5, (n_obj, slot)), jnp.int32)
    versions0 = jnp.zeros((n_obj,), jnp.int32)
    got_v, got_ver = protocol.fused_write_back(
        values0, versions0, waddrs, wvals, wn, committing,
        jnp.arange(k, dtype=jnp.int32), seq_nos)
    exp_v, exp_ver = values0, versions0
    for p in range(k):
        if bool(committing[p]):
            exp_v, exp_ver = protocol.apply_writes(
                exp_v, exp_ver, waddrs[p], wvals[p], wn[p], seq_nos[p])
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(exp_v))
    np.testing.assert_array_equal(np.asarray(got_ver), np.asarray(exp_ver))


def test_fused_write_back_permuted_rank():
    """With a non-identity rank the winner per address follows the
    serialization order, not the storage order."""
    rng = np.random.default_rng(3)
    k, length, slot, n_obj = 7, 4, 1, 6
    waddrs = jnp.asarray(rng.integers(0, n_obj, (k, length)), jnp.int32)
    wvals = jnp.asarray(rng.integers(-9, 9, (k, length, slot)), jnp.int32)
    wn = jnp.asarray(rng.integers(0, length + 1, (k,)), jnp.int32)
    committing = jnp.asarray(rng.random(k) < 0.8)
    rank_np = rng.permutation(k)
    rank = jnp.asarray(rank_np, jnp.int32)
    seq_nos = jnp.asarray(50 + rank_np, jnp.int32)
    values0 = jnp.zeros((n_obj, slot), jnp.int32)
    versions0 = jnp.zeros((n_obj,), jnp.int32)
    got_v, got_ver = protocol.fused_write_back(
        values0, versions0, waddrs, wvals, wn, committing, rank, seq_nos)
    exp_v, exp_ver = values0, versions0
    for t in np.argsort(rank_np):      # apply serially in rank order
        if bool(committing[t]):
            exp_v, exp_ver = protocol.apply_writes(
                exp_v, exp_ver, waddrs[t], wvals[t], wn[t], seq_nos[t])
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(exp_v))
    np.testing.assert_array_equal(np.asarray(got_ver), np.asarray(exp_ver))


# ----------------------------------------------------- dedup + rank helpers
@pytest.mark.parametrize("seed", range(6))
def test_dedup_last_writer_matches_all_pairs_reference(seed):
    rng = np.random.default_rng(seed)
    length = int(rng.integers(1, 12))
    waddrs = jnp.asarray(rng.integers(0, 4, (length,)), jnp.int32)
    for wn in [0, length // 2, length]:
        got = np.asarray(protocol.dedup_last_writer(
            waddrs, jnp.asarray(wn, jnp.int32)))
        exp = np.asarray(protocol._dedup_last_writer_reference(
            waddrs, jnp.asarray(wn, jnp.int32)))
        np.testing.assert_array_equal(got, exp)


def test_seq_rank_is_inverse_permutation():
    rng = np.random.default_rng(0)
    for k in [1, 2, 17, 64]:
        seq = jnp.asarray(rng.permutation(k) + 1, jnp.int32)
        order = jnp.argsort(seq)
        rank = np.asarray(rank_from_order(order))
        np.testing.assert_array_equal(rank,
                                      np.argsort(np.argsort(np.asarray(seq))))
        np.testing.assert_array_equal(np.asarray(seq_rank(seq)), rank)


# ------------------------------------------------------ lazy replay log
def test_replay_log_is_lazy_and_incremental():
    from repro.core import PotSession
    wl = W.counters(n_txns=8, n_objects=32, n_reads=1, n_writes=1,
                    n_lanes=2, seed=4)
    session = PotSession(wl.n_objects, engine="pcc", n_lanes=2)
    session.submit(wl.batch, wl.lanes.tolist())
    first = session.replay_log()
    assert len(first) == 8
    session.submit(wl.batch, wl.lanes.tolist())
    second = session.replay_log()
    assert second[:8] == first and len(second) == 16
    assert session.replay_log() == second  # idempotent
    # and the replayed session reproduces the stream bitwise
    replay = PotSession(wl.n_objects, engine="pcc",
                        sequencer=session.replay_sequencer())
    replay.run_stream([wl.batch, wl.batch])
    assert replay.fingerprint() == session.fingerprint()
