"""Deterministic replica failover under injected faults (PR 9).

Properties:
  F1  FaultPlan is deterministic: fault points are (formed-batch index,
      phase) positions in the order — validated, replayable, and (in
      "raise" mode) observable in-process.
  F2  Kill-and-restore: a replica killed at ANY fault point — including
      mid-snapshot with a torn tmp dir — restores from its latest
      COMPLETE snapshot plus the shared arrival-journal suffix and
      produces bitwise-identical store fingerprints, ExecTraces
      (speculation observables aside, per the PR 7 invariant) and
      replay_log() to an uninterrupted replica.  Driven both in-process
      ("raise" mode) and as a real subprocess SIGKILL (-9).
  F3  Elastic failover: worker join/leave events are sequenced,
      snapshot-visible state — a replica restored across a scaling
      event numbers lanes identically (destm: lane placement is
      load-bearing).
  F4  The metrics CSV carries the failover observables
      (snapshots_taken / restored_from / recovery_batches).

The acceptance matrix — engines {pcc, occ} x shards {1, 8} x
pipeline_depth {0, 2} x two drain-budget schedules, phases cycling
admit/drain/execute/snapshot(+torn) — is expensive (every config
compiles its own engine steps), so tier-1 runs a fixed subset and
``scripts/ci.sh --failover-smoke`` runs the full matrix via
``REPRO_FAILOVER_FULL=1``.
"""

import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from repro.core import (FaultInjected, FaultPlan, IngressPool, PotSession,
                        run_replica, trace_digest)
from repro.core import workloads as W
from repro.core.checkpoint import snapshot_ids
from repro.core.ingress import programs_from_batch

FULL = os.environ.get("REPRO_FAILOVER_FULL") == "1"
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N_OBJECTS = 64
N_LANES = 6


def _journal(n_txns=60, seed=3):
    wl = W.counters(n_txns=n_txns, n_objects=N_OBJECTS, n_reads=2,
                    n_writes=2, n_lanes=N_LANES, skew=0.7, seed=seed)
    pool = IngressPool(capacity=512)
    for i, p in enumerate(programs_from_batch(wl.batch)):
        pool.admit(p, lane=i % N_LANES, fee=i % 5)
    return pool.arrival_journal()


JOURNAL = _journal()


def _assert_recovered(rec_fp, rec_log, rec_digests, base):
    assert rec_fp == base.session.fingerprint()
    assert rec_log == base.session.replay_log()
    bd = [trace_digest(t) for t in base.session.traces]
    assert rec_digests == bd[len(bd) - len(rec_digests):]


# ------------------------------------------------------------- F1 plans
def test_fault_plan_validates_its_schedule():
    with pytest.raises(ValueError, match="phase"):
        FaultPlan(kill_batch=1, kill_phase="commit")
    with pytest.raises(ValueError, match="action"):
        FaultPlan(kill_batch=1, action="explode")
    with pytest.raises(ValueError, match="torn"):
        FaultPlan(kill_batch=1, kill_phase="execute", torn=True)


def test_fault_plan_fires_only_at_its_point():
    plan = FaultPlan(kill_batch=2, kill_phase="drain", action="raise")
    plan.fire(0, "drain")
    plan.fire(2, "execute")
    assert not plan.matches(1, "drain") and plan.matches(2, "drain")
    with pytest.raises(FaultInjected, match="batch 2, phase 'drain'"):
        plan.fire(2, "drain")
    # the empty plan never fires
    FaultPlan().fire(0, "drain")


# -------------------------------------------------- F2 kill-and-restore
# (engine, shards, pipeline_depth, budgets, kill_batch, phase, torn)
_SCHED_A, _SCHED_B = (7, 11), (16,)
MATRIX = []
_PHASES = [("drain", False), ("execute", False), ("snapshot", False),
           ("snapshot", True)]
for _i, (_e, _s, _d, _b) in enumerate(
        (e, s, d, b) for e in ("pcc", "occ") for s in (1, 8)
        for d in (0, 2) for b in (_SCHED_A, _SCHED_B)):
    _ph, _torn = _PHASES[_i % len(_PHASES)]
    # snapshot-phase faults must land ON a snapshot point: with
    # snapshot_every=2 those are even formed-batch counts (2, 4, ...)
    # regardless of schedule; drain/execute faults land mid-stream
    # (schedule A forms 7 batches of 60 txns, schedule B forms 4)
    _kill = 4 if (_ph == "snapshot" or _b == _SCHED_A) else 3
    MATRIX.append((_e, _s, _d, _b, _kill, _ph, _torn))

# tier-1 subset: both engines, both layouts, both depths, both
# schedules, a torn and a non-torn phase all appear at least once
TIER1 = {("pcc", 1, 0, _SCHED_A), ("occ", 8, 2, _SCHED_B),
         ("pcc", 8, 2, _SCHED_B), ("occ", 1, 0, _SCHED_A)}


def _full_only(engine, shards, depth, budgets):
    if not FULL and (engine, shards, depth, budgets) not in TIER1:
        pytest.skip("full failover matrix runs under REPRO_FAILOVER_FULL=1 "
                    "(scripts/ci.sh --failover-smoke)")


@pytest.mark.parametrize("engine,shards,depth,budgets,kill,phase,torn",
                         MATRIX)
def test_kill_and_restore_in_process(tmp_path, engine, shards, depth,
                                     budgets, kill, phase, torn):
    """F2 in 'raise' mode: the whole acceptance matrix, in-process."""
    _full_only(engine, shards, depth, budgets)
    kw = dict(n_objects=N_OBJECTS, engine=engine, n_lanes=N_LANES,
              shards=shards, pipeline_depth=depth, budgets=budgets)
    base = run_replica(JOURNAL, directory=str(tmp_path / "base"),
                       snapshot_every=0, **kw)
    vdir = str(tmp_path / "victim")
    plan = FaultPlan(kill_batch=kill, kill_phase=phase, torn=torn,
                     action="raise")
    with pytest.raises(FaultInjected):
        run_replica(JOURNAL, directory=vdir, snapshot_every=2,
                    fault_plan=plan, **kw)
    rec = run_replica(JOURNAL, directory=vdir, snapshot_every=2,
                      resume=True, **kw)
    assert rec.session.restored_from >= 0
    _assert_recovered(rec.session.fingerprint(), rec.session.replay_log(),
                      [trace_digest(t) for t in rec.session.traces], base)


def test_torn_snapshot_leaves_latest_complete_invariant(tmp_path):
    """The torn tmp dir is invisible (never renamed): the victim's
    snapshot directory still serves its latest COMPLETE snapshot, and
    recovery restores from it — not from the torn turd."""
    kw = dict(n_objects=N_OBJECTS, engine="pcc", n_lanes=N_LANES,
              budgets=(7, 11))
    vdir = str(tmp_path / "victim")
    plan = FaultPlan(kill_batch=4, kill_phase="snapshot", torn=True,
                     action="raise")
    with pytest.raises(FaultInjected):
        run_replica(JOURNAL, directory=vdir, snapshot_every=2,
                    fault_plan=plan, **kw)
    # snapshot 0 (after batch 2) committed; snapshot 1 (after batch 4)
    # died mid-commit: only a .tmp turd remains
    assert snapshot_ids(vdir) == [0]
    assert any("tmp" in name for name in os.listdir(vdir))
    base = run_replica(JOURNAL, directory=str(tmp_path / "base"),
                       snapshot_every=0, **kw)
    rec = run_replica(JOURNAL, directory=vdir, snapshot_every=2,
                      resume=True, **kw)
    assert rec.session.restored_from == 0
    _assert_recovered(rec.session.fingerprint(), rec.session.replay_log(),
                      [trace_digest(t) for t in rec.session.traces], base)


def test_kill_before_any_snapshot_cold_starts(tmp_path):
    """A victim killed before its first snapshot leaves nothing: resume
    falls back to a cold start from the arrival journal alone."""
    kw = dict(n_objects=N_OBJECTS, engine="pcc", n_lanes=N_LANES,
              budgets=(7, 11))
    vdir = str(tmp_path / "victim")
    plan = FaultPlan(kill_batch=0, kill_phase="admit", action="raise")
    with pytest.raises(FaultInjected):
        run_replica(JOURNAL, directory=vdir, snapshot_every=2,
                    fault_plan=plan, **kw)
    assert snapshot_ids(vdir) == []
    base = run_replica(JOURNAL, directory=str(tmp_path / "base"),
                       snapshot_every=0, **kw)
    rec = run_replica(JOURNAL, directory=vdir, snapshot_every=2,
                      resume=True, **kw)
    assert rec.session.restored_from == -1      # never restored: cold
    _assert_recovered(rec.session.fingerprint(), rec.session.replay_log(),
                      [trace_digest(t) for t in rec.session.traces], base)


# ------------------------------------------------- F2 subprocess SIGKILL
def _subprocess_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    # share one persistent XLA compile cache across the victim /
    # recovery processes — the matrix is compile-bound otherwise
    env.setdefault("JAX_COMPILATION_CACHE_DIR",
                   os.path.join(tempfile.gettempdir(), "repro_jax_pcache"))
    env.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")
    return env


def _run_driver(cfg, cfg_path, out_path, env):
    with open(cfg_path, "w") as f:
        json.dump(cfg, f)
    return subprocess.run(
        [sys.executable, "-m", "repro.core.checkpoint",
         str(cfg_path), str(out_path)],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=420)


SUBPROC_CASES = [
    ("pcc", 1, 0, (7, 11), 4, "execute", False),
    ("occ", 8, 2, (16,), 4, "snapshot", True),
]
if FULL:
    SUBPROC_CASES += [
        ("pcc", 8, 2, (7, 11), 4, "snapshot", True),
        ("occ", 1, 0, (16,), 3, "drain", False),
        ("pcc", 1, 2, (16,), 2, "drain", False),
        ("occ", 8, 0, (7, 11), 4, "execute", False),
        ("pcc", 8, 0, (16,), 0, "admit", False),
        ("occ", 1, 2, (7, 11), 2, "snapshot", False),
    ]


@pytest.mark.parametrize("engine,shards,depth,budgets,kill,phase,torn",
                         SUBPROC_CASES)
def test_sigkill_and_restore_subprocess(tmp_path, engine, shards, depth,
                                        budgets, kill, phase, torn):
    """F2 for real: the victim process takes an actual SIGKILL at its
    deterministic fault point (torn case: after corrupting the staged
    snapshot mid-commit); a fresh process restores and reconverges."""
    env = _subprocess_env()
    kw = dict(n_objects=N_OBJECTS, engine=engine, n_lanes=N_LANES,
              shards=shards, pipeline_depth=depth, budgets=list(budgets))
    base = run_replica(JOURNAL, directory=str(tmp_path / "base"),
                       snapshot_every=0, **kw)

    vdir = str(tmp_path / "victim")
    cfg_path, out_path = tmp_path / "cfg.json", tmp_path / "out.json"
    victim = dict(kw, journal=JOURNAL, directory=vdir, snapshot_every=2,
                  fault={"kill_batch": kill, "kill_phase": phase,
                         "torn": torn})
    r = _run_driver(victim, cfg_path, out_path, env)
    assert r.returncode == -9, (r.returncode, r.stderr[-2000:])
    assert not out_path.exists()

    recovery = dict(kw, journal=JOURNAL, directory=vdir, snapshot_every=2,
                    resume=True)
    r = _run_driver(recovery, cfg_path, out_path, env)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(out_path.read_text())
    assert out["pool_depth"] == 0
    _assert_recovered(out["fingerprint"], out["replay_log"],
                      out["trace_digests"], base)


# ------------------------------------------------- F3 elastic failover
ELASTIC_EVENTS = [[2, "join", None, 0], [5, "leave", 2, 0]]


def test_elastic_failover_numbers_lanes_identically(tmp_path):
    """destm's lane placement decides round membership, so this fails
    loudly if a restored replica renumbers lanes across the join/leave
    events the victim already applied."""
    kw = dict(n_objects=N_OBJECTS, engine="destm", n_lanes=4,
              budgets=(7, 11), elastic_events=ELASTIC_EVENTS)
    base = run_replica(JOURNAL, directory=str(tmp_path / "base"),
                       snapshot_every=0, **kw)
    assert base.session.elastic is not None
    vdir = str(tmp_path / "victim")
    plan = FaultPlan(kill_batch=4, kill_phase="execute", action="raise")
    with pytest.raises(FaultInjected):
        run_replica(JOURNAL, directory=vdir, snapshot_every=2,
                    fault_plan=plan, **kw)
    rec = run_replica(JOURNAL, directory=vdir, snapshot_every=2,
                      resume=True, **kw)
    # the restored manager is byte-for-byte the uninterrupted one:
    # same events (with their assigned lane ids), same round cursor
    assert rec.session.elastic.state_dict() == \
        base.session.elastic.state_dict()
    assert rec.session.elastic.live_lanes() == \
        base.session.elastic.live_lanes()
    _assert_recovered(rec.session.fingerprint(), rec.session.replay_log(),
                      [trace_digest(t) for t in rec.session.traces], base)


def test_serve_accepts_elastic_manager():
    """PotSession.serve(elastic=...) wires scaling events through the
    ordinary serve loop — same stream as a plain serve when no event
    fires inside it, different (but deterministic) lane placement when
    one does."""
    from repro.runtime.elastic import ElasticLaneManager, ScalingEvent
    pool, _ = IngressPool.replay(JOURNAL)
    mgr = ElasticLaneManager(4, [ScalingEvent(2, "join", None, 0)])
    s = PotSession(N_OBJECTS, engine="pcc", n_lanes=4)
    s.serve(pool, budget=9, elastic=mgr)
    assert s.elastic is mgr and s.batches_formed > 2
    assert mgr._round == s.batches_formed
    assert 4 in mgr.live_lanes()        # the joined worker lane

    # two replicas serving the same journal + schedule agree bitwise
    pool2, _ = IngressPool.replay(JOURNAL)
    mgr2 = ElasticLaneManager(4, [ScalingEvent(2, "join", None, 0)])
    s2 = PotSession(N_OBJECTS, engine="pcc", n_lanes=4)
    s2.serve(pool2, budget=9, elastic=mgr2)
    assert s2.fingerprint() == s.fingerprint()
    assert s2.replay_log() == s.replay_log()


# ------------------------------------------------- F4 metrics columns
def test_metrics_csv_carries_failover_observables(tmp_path):
    from repro.core import make_store, run_all
    from repro.core import metrics as M

    kw = dict(n_objects=N_OBJECTS, engine="pcc", n_lanes=N_LANES,
              budgets=(7, 11))
    run_replica(JOURNAL, directory=str(tmp_path), snapshot_every=2, **kw)
    rec = run_replica(JOURNAL, directory=str(tmp_path), snapshot_every=2,
                      resume=True, **kw)
    session, pool = rec.session, rec.pool
    wl = W.counters(n_txns=12, n_objects=N_OBJECTS, n_lanes=4, seed=4)
    trace = session.submit(wl.batch, wl.lanes.tolist())
    res = run_all(wl.batch, make_store(N_OBJECTS).values)
    rep = M.report_from_trace("pcc", trace, wl.batch,
                              np.asarray(res.rn), np.asarray(res.wn),
                              session=session, pool=pool)
    assert rep.snapshots_taken == session.snapshots_taken >= 1
    assert rep.restored_from == session.restored_from >= 0
    assert rep.recovery_batches == session.recovery_batches >= 1
    row, header = rep.row(), M.HEADER
    assert len(row.split(",")) == len(header.split(","))
    for col in ("snapshots_taken", "restored_from", "recovery_batches"):
        assert col in header.split(",")
    # a never-restored session reports the defaults
    fresh = M.report_from_trace("pcc", trace, wl.batch,
                                np.asarray(res.rn), np.asarray(res.wn),
                                session=PotSession(N_OBJECTS))
    assert (fresh.snapshots_taken, fresh.restored_from,
            fresh.recovery_batches) == (0, -1, 0)
