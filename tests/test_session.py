"""Tests for the unified engine API: PotSession + engine registry +
canonical ExecTrace (the streaming layer over the Pot pipeline).

Properties:
  S1  Every engine runs through get_engine(name) / PotSession with the
      same submit() signature and returns the shared ExecTrace schema.
  S2  A multi-batch run_stream is bitwise-equal to the PoGL serial
      oracle and invariant to per-batch arrival (storage) permutations.
  S3  A recorded OCC commit order round-trips through ReplaySequencer +
      PotSession, reproducing the OCC store exactly.
  S4  ExplicitSequencer error paths (hang detection) surface through the
      session; ReplaySequencer validates its stream log.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ENGINES, ExecTrace, ExplicitSequencer, PotSession,
                        READ, ReplaySequencer, RMW, RoundRobinSequencer,
                        WRITE, get_engine, make_batch, make_store,
                        pogl_execute)
from repro.core import workloads as W

ALL_ENGINES = ("pcc", "pogl", "destm", "occ")
N_OBJECTS, N_LANES = 64, 4


def _stream(seeds=(1, 2, 3)):
    """A stream of same-shaped workload batches sharing one lane layout."""
    wls = [W.counters(n_txns=12, n_objects=N_OBJECTS, n_reads=2, n_writes=2,
                      n_lanes=N_LANES, skew=0.8, seed=s) for s in seeds]
    return [w.batch for w in wls], wls[0].lanes.tolist()


# ------------------------------------------------------- registry (S1)
def test_registry_knows_all_engines():
    for name in ALL_ENGINES:
        assert get_engine(name).name == name
        assert name in ENGINES
    assert get_engine("pot") is get_engine("pcc")  # paper-name alias
    with pytest.raises(KeyError, match="unknown engine"):
        get_engine("2pl")


def test_every_engine_same_call_same_schema():
    batches, lanes = _stream(seeds=(7,))
    fps = {}
    for name in ALL_ENGINES:
        s = PotSession(N_OBJECTS, engine=name, n_lanes=N_LANES)
        trace = s.submit(batches[0], lanes)
        assert isinstance(trace, ExecTrace)
        assert trace.n_txns == batches[0].n_txns
        # commit_pos is a permutation for every engine (all txns commit)
        assert sorted(np.asarray(trace.commit_pos).tolist()) == \
            list(range(batches[0].n_txns))
        assert s.gv == batches[0].n_txns
        fps[name] = s.fingerprint()
    # the three deterministic order-preserving engines agree bitwise
    assert fps["pcc"] == fps["pogl"] == fps["destm"]


def test_engine_execute_entry_point():
    """get_engine(name).execute — the non-session unified entry point."""
    batches, _ = _stream(seeds=(11,))
    batch = batches[0]
    k = batch.n_txns
    store = make_store(N_OBJECTS)
    seq = jnp.arange(1, k + 1, dtype=jnp.int32)
    oracle = pogl_execute(store, batch, seq)
    for name in ("pcc", "destm"):
        out, trace = get_engine(name).execute(
            store, batch, seq, lanes=np.arange(k) % N_LANES,
            n_lanes=N_LANES)
        np.testing.assert_array_equal(np.asarray(out.values),
                                      np.asarray(oracle.values))
        assert int(trace.rounds) <= k


# ------------------------------------------- stream determinism (S2)
def test_run_stream_matches_pogl_oracle():
    batches, lanes = _stream()
    pot = PotSession(N_OBJECTS, engine="pcc", n_lanes=N_LANES)
    traces = pot.run_stream(batches, [lanes] * len(batches))
    assert len(traces) == len(batches)
    oracle = PotSession(N_OBJECTS, engine="pogl", n_lanes=N_LANES)
    oracle.run_stream(batches, [lanes] * len(batches))
    np.testing.assert_array_equal(np.asarray(pot.store.values),
                                  np.asarray(oracle.store.values))
    assert pot.fingerprint() == oracle.fingerprint()
    # gv accumulates across the stream
    assert pot.gv == sum(b.n_txns for b in batches)
    assert pot.replay_log() == oracle.replay_log()


def test_run_stream_invariant_to_per_batch_arrival_permutation():
    """Permuting each batch's storage order (the arrival interleaving)
    while replaying the same logical commit order is bitwise-invariant
    and equals the PoGL oracle."""
    batches, lanes = _stream()
    base = PotSession(N_OBJECTS, engine="pcc", n_lanes=N_LANES)
    base.run_stream(batches, [lanes] * len(batches))
    log = base.replay_log()

    rng = np.random.default_rng(0)
    for trial in range(3):
        permuted, mapped_log, offset = [], [], 0
        for i, batch in enumerate(batches):
            k = batch.n_txns
            perm = rng.permutation(k)
            inv = np.argsort(perm)
            permuted.append(jax.tree.map(lambda a: a[perm], batch))
            # same logical order, expressed in permuted storage indices
            chunk = log[offset:offset + k]
            mapped_log.extend(offset + int(inv[t - offset]) for t in chunk)
            offset += k
        s = PotSession(N_OBJECTS, engine="pcc",
                       sequencer=ReplaySequencer(mapped_log))
        s.run_stream(permuted)
        np.testing.assert_array_equal(np.asarray(s.store.values),
                                      np.asarray(base.store.values))
        assert s.fingerprint() == base.fingerprint()


# --------------------------------------------- record/replay (S3)
def test_replay_sequencer_roundtrips_occ_commit_order():
    batches, lanes = _stream()
    # nondeterministic arrival interleavings per batch, as a flat log
    rng = np.random.default_rng(42)
    arrivals, offset = [], 0
    for b in batches:
        arrivals.extend(offset + int(t) for t in rng.permutation(b.n_txns))
        offset += b.n_txns
    occ = PotSession(N_OBJECTS, engine="occ",
                     sequencer=ReplaySequencer(arrivals))
    occ.run_stream(batches)
    # replay the *recorded commit order* (not the arrival!) through Pot
    replay = PotSession(N_OBJECTS, engine="pcc",
                        sequencer=occ.replay_sequencer())
    replay.run_stream(batches)
    np.testing.assert_array_equal(np.asarray(replay.store.values),
                                  np.asarray(occ.store.values))
    assert replay.fingerprint() == occ.fingerprint()


def test_destm_replay_log_is_round_major():
    """DeSTM's serialization is round-major (one txn per lane per round),
    not plain sequence order when lanes are unevenly loaded; the session
    log must record the order DeSTM actually committed in, so replaying
    it through Pot reproduces the DeSTM store."""
    progs = [
        [(RMW, 0, False, 1)],                        # T0  lane 0, seq 1
        [(READ, 5, False, 0), (WRITE, 1, False, 0)],  # T1  lane 0, seq 2
        [(WRITE, 5, False, 99)],                     # T2  lane 1, seq 3
    ]
    batch = make_batch(progs)
    destm = PotSession(8, engine="destm", n_lanes=2,
                       sequencer=ReplaySequencer([0, 1, 2]))
    destm.submit(batch, lanes=[0, 0, 1])
    # round 1 commits T0 (lane 0) and T2 (lane 1); T1 waits for round 2
    # and therefore observes T2's write — commit order is [0, 2, 1]
    assert destm.replay_log() == [0, 2, 1]
    assert int(destm.store.values[1, 0]) == 99
    replay = PotSession(8, engine="pcc",
                        sequencer=destm.replay_sequencer())
    replay.submit(batch)
    np.testing.assert_array_equal(np.asarray(replay.store.values),
                                  np.asarray(destm.store.values))


def test_occ_stream_depends_on_arrival_witness():
    """The baseline stays nondeterministic through the session API."""
    wl = W.counters(n_txns=16, n_objects=8, n_reads=2, n_writes=2,
                    n_lanes=4, skew=0.0, seed=12)
    fps = set()
    rng = np.random.default_rng(3)
    for _ in range(8):
        s = PotSession(wl.n_objects, engine="occ",
                       sequencer=ReplaySequencer(
                           rng.permutation(wl.batch.n_txns).tolist()))
        s.submit(wl.batch)
        fps.add(s.fingerprint())
    assert len(fps) > 1


# ----------------------------------------------- error paths (S4)
def test_explicit_sequencer_hang_detection_through_session():
    batch = make_batch([[(RMW, 0, False, 1)], [(RMW, 1, False, 1)]])
    s = PotSession(4, sequencer=ExplicitSequencer(["init", "use", "close"]))
    with pytest.raises(RuntimeError, match="waits forever"):
        s.submit(batch, lanes=["init", "use"])  # "close" never arrives
    s2 = PotSession(4, sequencer=ExplicitSequencer(["init"]))
    with pytest.raises(RuntimeError, match="not in explicit order"):
        s2.submit(batch, lanes=["init", "rogue"])
    # named keys work when the order is complete (names -> lane 0)
    s3 = PotSession(4, sequencer=ExplicitSequencer(["use", "init"]))
    trace = s3.submit(batch, lanes=["init", "use"])
    np.testing.assert_array_equal(np.asarray(trace.commit_pos), [1, 0])


def test_replay_sequencer_stream_validation():
    rs = ReplaySequencer([0, 1, 2])
    with pytest.raises(ValueError, match="replay log has"):
        rs.order_for([0, 0, 0, 0])  # log too short for the batch
    rs2 = ReplaySequencer([0, 2])   # not a permutation of batch 0..1
    with pytest.raises(ValueError, match="not a permutation"):
        rs2.order_for([0, 0])


def test_session_lane_count_mismatch():
    batch = make_batch([[(RMW, 0, False, 1)]])
    s = PotSession(4)
    with pytest.raises(ValueError, match="lanes"):
        s.submit(batch, lanes=[0, 1])


def test_round_robin_spawn_and_stop_mid_stream():
    """Elastic scaling (paper §2.1): lanes joining and leaving between
    batches change the round-robin schedule deterministically — the
    spawned lane slots in post-order *before* its parent, the stopped
    lane drops out of the refill — and the resulting commit order
    round-trips through record/replay."""
    seqr = RoundRobinSequencer(n_root_lanes=2)
    s = PotSession(4, engine="pcc", sequencer=seqr)
    b1 = make_batch([[(WRITE, i % 2, False, 10 + i)] for i in range(6)])
    s.submit(b1, lanes=[0, 1, 0, 1, 0, 1])
    assert s.replay_log() == [0, 1, 2, 3, 4, 5]

    seqr.spawn_lane(0, 2)              # child of 0: post-order [2, 0, 1]
    assert seqr.lane_order() == [2, 0, 1]
    b2 = make_batch([[(WRITE, 0, False, 20 + i)] for i in range(3)])
    s.submit(b2, lanes=[0, 1, 2])      # seqs (8, 9, 7): lane 2 first
    assert s.replay_log()[6:] == [8, 6, 7]

    seqr.stop_lane(1)                  # refill stops feeding lane 1
    assert seqr.lane_order() == [2, 0]
    b3 = make_batch([[(WRITE, 1, False, 30 + i)] for i in range(2)])
    s.submit(b3, lanes=[0, 2])         # seqs (11, 10): lane 2 still first
    assert s.replay_log()[9:] == [10, 9]
    assert int(s.store.values[0, 0]) == 21   # last lane-0 write of b2
    assert int(s.store.values[1, 0]) == 30   # lane-0 write of b3

    replay = PotSession(4, engine="pcc",
                        sequencer=ReplaySequencer(s.replay_log()))
    replay.run_stream([b1, b2, b3])
    assert replay.fingerprint() == s.fingerprint()
    assert replay.replay_log() == s.replay_log()


def test_round_robin_unknown_or_stopped_lane_raises():
    """The sequencer must raise, not spin forever, for a lane its refill
    loop will never feed (paper §2.1's hang, surfaced as an error)."""
    batch = make_batch([[(RMW, 0, False, 1)], [(RMW, 1, False, 1)]])
    s = PotSession(8, engine="pcc", n_lanes=2)
    with pytest.raises(KeyError, match="unknown lane"):
        s.submit(batch, lanes=[0, 2])  # lane 2 was never spawned
    seqr = RoundRobinSequencer(n_root_lanes=2)
    assert seqr.get_seq_no(0) == 1
    seqr.stop_lane(1)
    assert seqr.get_seq_no(1) == 2  # pre-assigned number still drains
    with pytest.raises(RuntimeError, match="stopped"):
        seqr.get_seq_no(1)
