"""Cross-batch speculative pipelining == the serial stream, bit for bit
(PR 7), plus the OCC blocked wave solve.

The pipelining invariant: with ranks globally consecutive across batches
and version stamps globally monotone (gv0 + commit position + 1),
``versions > snap_gv`` is the exact post-snapshot dirty predicate, and a
speculated row whose logged read set misses every dirty address replays
bit-identically (row purity + induction along its read chain).  So a
``PotSession(pipeline_depth=D)`` stream must equal the serial ``D=0``
run on store fingerprints, full ExecTraces (every pre-existing field)
and ``replay_log()`` — for any engine, bucket ladder, shard count and
ingress budget schedule; the speculation cost may only surface in the
new ``spec_*`` observables.  Layers under test:

* the validation strip kernels (``kernels.ops.spec_dirty_words`` /
  ``spec_read_invalid`` and their sharded OR-over-shards twins) against
  a dense NumPy oracle;
* ``protocol.seed_round_state``: a seeded engine call equals the
  unseeded call on stores the speculation snapshot is stale against;
* pipelined sessions over ragged bucketed streams, all four engines
  seeded (pcc / occ since PR 7, destm / pogl since PR 10), D in {1, 2},
  shards in {1, 8}, both bucket ladders, ingress ``serve``;
* ``protocol.wave_commit(block=B)``: decision-identical to B=1 with
  fewer `while_loop` trips on a deep neighbor conflict chain.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (READ, WRITE, IngressPool, PotSession,
                        RoundRobinSequencer, fingerprint, make_batch,
                        make_store)
from repro.core import protocol
from repro.core import workloads as W
from repro.core.engine import ExecTrace
from repro.core.occ import _occ_execute
from repro.core.pcc import _pcc_execute
from repro.core.txn import run_all
from repro.kernels import ops as kernel_ops

ENGINES = ("pcc", "occ", "pogl", "destm")
N_OBJ = 96


def _wl(k, skew, seed):
    return W.counters(n_txns=k, n_objects=N_OBJ, n_reads=3, n_writes=3,
                      n_lanes=8, skew=skew, seed=seed)


def _stream(n_batches=5, skew=0.8, seed=0):
    """A ragged stream: several distinct (K, L) shapes, shared hot set."""
    ks = (13, 16, 7, 32, 9, 24)
    wls = [_wl(ks[i % len(ks)], skew, seed + 100 + i)
           for i in range(n_batches)]
    return [w.batch for w in wls], [w.lanes for w in wls]


def _assert_traces_match(serial, pipelined, msg=""):
    """Every pre-existing trace field bitwise equal; serial spec_* zero."""
    assert len(serial) == len(pipelined), msg
    for i, (a, b) in enumerate(zip(serial, pipelined)):
        for f in dataclasses.fields(ExecTrace):
            x, y = np.asarray(getattr(a, f.name)), \
                np.asarray(getattr(b, f.name))
            if f.name.startswith("spec_"):
                assert x.sum() == 0, f"serial {f.name} nonzero {msg}"
                continue
            np.testing.assert_array_equal(
                x, y, err_msg=f"batch {i} field {f.name} diverged {msg}")


def _run_sessions(engine, depth, shards, ladder="pow2", n_batches=5,
                  skew=0.8, seed=0):
    batches, lanes = _stream(n_batches, skew, seed)
    kw = dict(engine=engine, n_lanes=8, shards=shards,
              bucket_ladder=ladder)
    s0 = PotSession(N_OBJ, **kw)
    t0 = s0.run_stream(batches, lanes)
    s1 = PotSession(N_OBJ, pipeline_depth=depth, **kw)
    t1 = s1.run_stream(batches, lanes)
    return s0, t0, s1, t1


# ------------------------------------------------- validation strip kernels
class TestValidationStrip:
    def _case(self, seed, k=24, skew=1.0):
        rng = np.random.default_rng(seed)
        wl = _wl(k, skew, seed)
        values = jnp.asarray(
            rng.integers(0, 50, size=(N_OBJ, 1)), jnp.int32)
        res = run_all(wl.batch, values)
        # a random post-snapshot version image: snap_gv 5, some stamps
        # above it (dirty), some at/below (clean)
        versions = jnp.asarray(rng.integers(0, 12, size=(N_OBJ,)),
                               jnp.int32)
        return res, versions, jnp.asarray(5, jnp.int32)

    def _oracle(self, res, versions, snap_gv):
        raddrs, rn = np.asarray(res.raddrs), np.asarray(res.rn)
        dirty = np.asarray(versions) > int(snap_gv)
        k, length = raddrs.shape
        out = np.zeros((k,), bool)
        for t in range(k):
            out[t] = bool(dirty[raddrs[t, :rn[t]]].any())
        return out

    def test_dirty_words_pack_convention(self):
        versions = jnp.zeros((70,), jnp.int32).at[jnp.asarray([0, 33, 69])
                                                  ].set(9)
        words = np.asarray(kernel_ops.spec_dirty_words(
            versions, jnp.asarray(0, jnp.int32), 70))
        assert words.shape == (3,)   # ceil(70/32)
        assert words[0] == 1                     # bit 0 of word 0
        assert words[1] == (1 << 1)              # addr 33 -> word 1 bit 1
        assert np.uint32(words[2]) == np.uint32(1) << 5   # addr 69

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_dense_matches_numpy_oracle(self, seed):
        res, versions, snap_gv = self._case(seed)
        got = np.asarray(kernel_ops.spec_read_invalid(
            res.raddrs, res.rn, versions, snap_gv, N_OBJ))
        np.testing.assert_array_equal(got,
                                      self._oracle(res, versions, snap_gv))

    @pytest.mark.parametrize("shards", [2, 8])
    def test_sharded_matches_dense(self, shards):
        from repro.core import StoreLayout
        res, versions, snap_gv = self._case(3)
        layout = StoreLayout(N_OBJ, shards)
        # stack the dense versions into the sharded (S, C) image
        pad = layout.padded_objects - N_OBJ
        vs = jnp.pad(versions, (0, pad)).reshape(layout.shards,
                                                 layout.shard_size)
        got = np.asarray(kernel_ops.spec_read_invalid_sharded(
            res.raddrs, res.rn, vs, snap_gv, layout))
        np.testing.assert_array_equal(got,
                                      self._oracle(res, versions, snap_gv))

    def test_everything_clean_when_no_dirty_writes(self):
        res, versions, _ = self._case(4)
        snap = jnp.asarray(int(np.asarray(versions).max()), jnp.int32)
        got = np.asarray(kernel_ops.spec_read_invalid(
            res.raddrs, res.rn, versions, snap, N_OBJ))
        assert not got.any()


# ------------------------------------------------------ seeded engine calls
class TestSeededEngines:
    @pytest.mark.parametrize("engine", ["pcc", "occ"])
    @pytest.mark.parametrize("shards", [1, 4])
    def test_seeded_equals_unseeded(self, engine, shards):
        fn = _pcc_execute if engine == "pcc" else _occ_execute
        wl1, wl2 = _wl(16, 1.0, 1), _wl(16, 1.0, 2)
        seq = jnp.arange(1, 17, dtype=jnp.int32)
        arg = seq if engine == "pcc" else jnp.argsort(seq)
        store0 = make_store(N_OBJ, shards=shards)
        s1, _ = fn(store0, wl1.batch, arg)
        s2, t2 = fn(s1, wl2.batch, arg)
        # speculate batch 2 against the PRE-batch-1 snapshot (stale)
        seed = protocol.spec_execute(store0, wl2.batch)
        s2b, t2b = fn(s1, wl2.batch, arg, seed=seed)
        np.testing.assert_array_equal(
            np.asarray(s2.values).reshape(-1),
            np.asarray(s2b.values).reshape(-1))
        np.testing.assert_array_equal(
            np.asarray(s2.versions).reshape(-1),
            np.asarray(s2b.versions).reshape(-1))
        assert int(s2.gv) == int(s2b.gv)
        _assert_traces_match([t2], [t2b], f"{engine} S={shards}")
        assert int(t2b.spec_executed) == 16
        assert int(t2b.spec_rounds) == (int(t2b.spec_invalidated) > 0)

    @pytest.mark.parametrize("engine", ["destm", "pogl"])
    def test_seeded_equals_unseeded_lane_engines(self, engine):
        # destm / pogl go through the registry's uniform raw signature
        # (they need lanes); same stale-seed setup as above
        from repro.core.engine import get_engine
        eng = get_engine(engine)
        wl1, wl2 = _wl(16, 1.0, 1), _wl(16, 1.0, 2)
        seq = jnp.arange(1, 17, dtype=jnp.int32)
        lanes = jnp.asarray(wl2.lanes, jnp.int32)
        store0 = make_store(N_OBJ)
        s1, _ = eng.raw(store0, wl1.batch,
                        seq, jnp.asarray(wl1.lanes, jnp.int32), 8)
        s2, t2 = eng.raw(s1, wl2.batch, seq, lanes, 8)
        seed = protocol.spec_execute(store0, wl2.batch)  # stale snapshot
        s2b, t2b = eng.raw_spec(s1, wl2.batch, seq, lanes, 8, seed)
        np.testing.assert_array_equal(np.asarray(s2.values),
                                      np.asarray(s2b.values))
        np.testing.assert_array_equal(np.asarray(s2.versions),
                                      np.asarray(s2b.versions))
        assert int(s2.gv) == int(s2b.gv)
        _assert_traces_match([t2], [t2b], engine)
        assert int(t2b.spec_executed) == 16

    def test_fresh_seed_invalidates_nothing(self):
        wl = _wl(16, 0.5, 7)
        seq = jnp.arange(1, 17, dtype=jnp.int32)
        store = make_store(N_OBJ)
        seed = protocol.spec_execute(store, wl.batch)  # current snapshot
        _, trace = _pcc_execute(store, wl.batch, seq, seed=seed)
        assert int(trace.spec_invalidated) == 0
        assert int(trace.spec_rounds) == 0
        assert int(trace.spec_executed) == 16


# ------------------------------------------------------- pipelined sessions
class TestPipelinedSession:
    @pytest.mark.parametrize("engine", ENGINES)
    @pytest.mark.parametrize("depth", [1, 2])
    def test_stream_equals_serial(self, engine, depth):
        s0, t0, s1, t1 = _run_sessions(engine, depth, shards=1)
        assert s0.fingerprint() == s1.fingerprint()
        assert s0.replay_log() == s1.replay_log()
        assert int(s0.store.gv) == int(s1.store.gv)
        _assert_traces_match(t0, t1, f"{engine} D={depth}")
        # all four engines are seeded and must record the overlap
        assert sum(int(t.spec_executed) for t in t1) > 0

    @pytest.mark.parametrize("shards", [8])
    @pytest.mark.parametrize("engine", ["pcc", "occ"])
    def test_sharded_stream_equals_serial(self, engine, shards):
        s0, t0, s1, t1 = _run_sessions(engine, 2, shards=shards)
        assert s0.fingerprint() == s1.fingerprint()
        assert s0.replay_log() == s1.replay_log()
        _assert_traces_match(t0, t1, f"{engine} S={shards}")

    def test_dense_ladder_stream_equals_serial(self):
        s0, t0, s1, t1 = _run_sessions("pcc", 2, shards=1, ladder="dense")
        assert s0.fingerprint() == s1.fingerprint()
        assert s0.replay_log() == s1.replay_log()
        _assert_traces_match(t0, t1, "dense ladder")

    def test_low_contention_speculation_survives(self):
        # disjoint-ish batches: most speculated rows must stay valid
        batches, lanes = _stream(4, skew=0.0, seed=50)
        s = PotSession(4096, engine="pcc", n_lanes=8, pipeline_depth=1)
        s0 = PotSession(4096, engine="pcc", n_lanes=8)
        wls = [W.counters(n_txns=16, n_objects=4096, n_reads=2,
                          n_writes=2, n_lanes=8, skew=0.0, seed=i)
               for i in range(4)]
        t1 = s.run_stream([w.batch for w in wls], [w.lanes for w in wls])
        t0 = s0.run_stream([w.batch for w in wls], [w.lanes for w in wls])
        assert s.fingerprint() == s0.fingerprint()
        executed = sum(int(t.spec_executed) for t in t1)
        invalidated = sum(int(t.spec_invalidated) for t in t1)
        assert executed > 0 and invalidated < executed

    def test_depth_zero_is_serial_path(self):
        s = PotSession(N_OBJ, engine="pcc", pipeline_depth=0)
        assert not s._pipelined and s._spec_step is None

    def test_negative_depth_rejected(self):
        with pytest.raises(ValueError):
            PotSession(N_OBJ, pipeline_depth=-1)

    def test_submit_flushes_pending_window(self):
        # interleave run_stream and submit: submit must see the window
        # fully drained (run_stream flushes; the submit-side flush is a
        # guard) and the combined history must equal the serial one
        batches, lanes = _stream(3, seed=9)
        extra = _wl(11, 0.8, 999)
        s1 = PotSession(N_OBJ, engine="pcc", n_lanes=8, pipeline_depth=2)
        s1.run_stream(batches, lanes)
        s1.submit(extra.batch, extra.lanes)
        s0 = PotSession(N_OBJ, engine="pcc", n_lanes=8)
        s0.run_stream(batches, lanes)
        s0.submit(extra.batch, extra.lanes)
        assert s1.fingerprint() == s0.fingerprint()
        assert s1.replay_log() == s0.replay_log()

    def test_replay_round_trip(self):
        batches, lanes = _stream(4, seed=3)
        s1 = PotSession(N_OBJ, engine="pcc", n_lanes=8, pipeline_depth=2)
        s1.run_stream(batches, lanes)
        replay = PotSession(N_OBJ, engine="pcc", n_lanes=8,
                            sequencer=s1.replay_sequencer())
        replay.run_stream(batches)
        assert replay.fingerprint() == s1.fingerprint()


# ----------------------------------------------------------- ingress serve
class TestPipelinedServe:
    def _fill(self, pool, n=60, seed=11):
        rng = np.random.default_rng(seed)
        for i in range(n):
            prog = ((READ, int(rng.integers(0, N_OBJ)), False, 0),
                    (WRITE, int(rng.integers(0, N_OBJ)), False, i + 1))
            pool.admit(prog, lane=int(rng.integers(0, 6)),
                       fee=int(rng.integers(0, 5)))

    @pytest.mark.parametrize("budgets", [(16,), (5, 9, 3, 31)])
    def test_serve_equals_serial_across_budgets(self, budgets):
        pool0, pool1 = IngressPool(), IngressPool()
        self._fill(pool0)
        self._fill(pool1)
        s0 = PotSession(N_OBJ, engine="pcc", n_lanes=8)
        s1 = PotSession(N_OBJ, engine="pcc", n_lanes=8, pipeline_depth=2)
        for b in budgets:
            s0.serve(pool0, budget=b)
            s1.serve(pool1, budget=b)
        assert s0.fingerprint() == s1.fingerprint()
        assert s0.replay_log() == s1.replay_log()


# ------------------------------------------------------- blocked wave solve
class TestBlockedWaveCommit:
    def _chain(self, k=48):
        """Neighbor conflict chain: txn i reads i-1's write target — the
        wave fixpoint resolves one conflict layer per query, so its
        depth is O(chain length) at block=1."""
        progs = [[(READ, (i - 1) % N_OBJ, False, 0), (WRITE, i, False, 1)]
                 for i in range(k)]
        return make_batch(progs)

    @pytest.mark.parametrize("block", [2, 8])
    def test_decisions_identical_any_block(self, block):
        batch = self._chain()
        store = make_store(N_OBJ)
        res = run_all(batch, store.values)
        rank = jnp.arange(batch.n_txns, dtype=jnp.int32)
        pending = jnp.ones((batch.n_txns,), bool)
        conflict = protocol.conflict_table(res, N_OBJ, use_matrix=True)
        c1, t1 = protocol.wave_commit(res, conflict, pending, rank, N_OBJ)
        cb, tb = protocol.wave_commit(res, conflict, pending, rank, N_OBJ,
                                      block=block)
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(cb))
        assert int(tb) < int(t1)   # deep chain: trips cut by ~block

    def test_occ_engine_blocked_equals_unblocked(self):
        batch = self._chain()
        store = make_store(N_OBJ)
        arrival = jnp.arange(batch.n_txns, dtype=jnp.int32)
        s1, t1 = _occ_execute(store, batch, arrival, wave_block=1)
        s8, t8 = _occ_execute(store, batch, arrival, wave_block=8)
        np.testing.assert_array_equal(np.asarray(s1.values),
                                      np.asarray(s8.values))
        np.testing.assert_array_equal(np.asarray(s1.versions),
                                      np.asarray(s8.versions))
        for f in ("commit_pos", "retries", "rounds", "commit_round"):
            np.testing.assert_array_equal(np.asarray(getattr(t1, f)),
                                          np.asarray(getattr(t8, f)),
                                          err_msg=f)
        assert int(t8.wave_trips) < int(t1.wave_trips)

    def test_disjoint_wave_single_trip_any_block(self):
        # disjoint txns: fixpoint converges on the first check at any B
        progs = [[(WRITE, i, False, 1)] for i in range(8)]
        batch = make_batch(progs)
        store = make_store(N_OBJ)
        res = run_all(batch, store.values)
        rank = jnp.arange(8, dtype=jnp.int32)
        pending = jnp.ones((8,), bool)
        for block in (1, 8):
            c, trips = protocol.wave_commit(res, None, pending, rank,
                                            N_OBJ, block=block)
            assert np.asarray(c).all()
            assert int(trips) == 1


# ------------------------------------------------------- hypothesis property
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1, 2]),
       st.sampled_from(["pcc", "occ", "destm", "pogl"]),
       st.floats(0.0, 1.5))
def test_pipelined_equals_serial_property(seed, depth, engine, skew):
    s0, t0, s1, t1 = _run_sessions(engine, depth, shards=1,
                                   n_batches=4, skew=skew, seed=seed)
    assert s0.fingerprint() == s1.fingerprint()
    assert s0.replay_log() == s1.replay_log()
    _assert_traces_match(t0, t1, f"{engine} D={depth} seed={seed}")
