"""Coverage for compression, roofline model, and sequencer details."""

import jax.numpy as jnp
import numpy as np

from repro.core.sequencer import RoundRobinSequencer
from repro.optim import error_feedback_init, topk_compress


class TestCompression:
    def test_topk_keeps_largest_and_feeds_back(self):
        g = {"w": jnp.asarray([[1.0, -5.0, 0.1, 3.0]])}
        r = error_feedback_init(g)
        sparse, new_r = topk_compress(g, r, ratio=0.5)
        s = np.asarray(sparse["w"])[0]
        assert s[1] == -5.0 and s[3] == 3.0      # top-2 by magnitude kept
        assert s[0] == 0.0 and s[2] == 0.0       # rest zeroed...
        nr = np.asarray(new_r["w"])[0]
        assert nr[0] == 1.0 and nr[2] == 0.1     # ...and remembered

    def test_error_feedback_preserves_mass(self):
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
        r = error_feedback_init(g)
        sparse, new_r = topk_compress(g, r, ratio=0.1)
        np.testing.assert_allclose(
            np.asarray(sparse["w"]) + np.asarray(new_r["w"]),
            np.asarray(g["w"]), rtol=1e-6)

    def test_compression_is_deterministic(self):
        g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(128,)),
                              jnp.float32)}
        r = error_feedback_init(g)
        a, _ = topk_compress(g, r, ratio=0.05)
        b, _ = topk_compress(g, r, ratio=0.05)
        assert np.asarray(a["w"]).tobytes() == np.asarray(b["w"]).tobytes()


class TestSequencerSpawn:
    def test_paper_2_1_spawn_example(self):
        """Paper §2.1: t=(a;b;c), u=(d;e;f); b spawns v=(g;h); post-order
        with v a child of t gives the thread order (v, t), u ... the
        paper's resulting transaction order interleaves v's transactions
        after the spawn point: (a d b e g c f h)."""
        s = RoundRobinSequencer(n_root_lanes=2)   # t=0, u=1
        a = s.get_seq_no(0)       # a
        d = s.get_seq_no(1)       # d
        b = s.get_seq_no(0)       # b (spawns v)
        v = s.spawn_lane(0)
        e = s.get_seq_no(1)       # e
        g = s.get_seq_no(v)       # g
        c = s.get_seq_no(0)       # c
        f = s.get_seq_no(1)       # f
        h = s.get_seq_no(v)       # h
        order = sorted([(a, "a"), (d, "d"), (b, "b"), (e, "e"), (g, "g"),
                        (c, "c"), (f, "f"), (h, "h")])
        # a deterministic interleaving that includes v after its spawn
        assert [x[1] for x in order][:4] == ["a", "d", "b", "e"]
        assert {x[1] for x in order[4:]} == {"g", "c", "f", "h"}
        # rerun => identical
        s2 = RoundRobinSequencer(n_root_lanes=2)
        a2 = s2.get_seq_no(0)
        d2 = s2.get_seq_no(1)
        b2 = s2.get_seq_no(0)
        s2.spawn_lane(0)
        assert (a2, d2, b2) == (a, d, b)


class TestRooflineModel:
    def test_terms_positive_and_bound_consistent(self):
        import glob
        import json
        from repro.launch.roofline_model import terms_from_record
        paths = glob.glob("results/dryrun/*.json")
        if not paths:
            import pytest
            pytest.skip("no dry-run results present")
        for p in paths[:5]:
            r = json.load(open(p))
            if "analysis" not in r:
                continue
            t = terms_from_record(r)
            assert t["compute_s"] > 0
            assert t["bound_s"] >= max(t["compute_s"], t["memory_s"],
                                       t["collective_s"]) * 0.999
            assert 0 < t["roofline_fraction"] <= 1.0
