"""Tests for the incremental round state (PR 3).

Three layers of guarantees:

* ``txn.run_live`` — masked re-execution equals a full ``run_all`` on the
  live rows and carries the cache bit-exactly on the settled rows
  (fixed K in {1, 2, 64} plus a hypothesis property);
* ``protocol.refresh_round_state`` — the carried/delta conflict table
  equals a per-round from-scratch rebuild on every refreshed entry, for
  random multi-round simulations at high and low contention;
* the engines — ``incremental=True`` (masked loop, carried state) and
  ``incremental=False`` (PR 2 full rebuild) produce bit-identical stores
  and traces, and the incremental path's live counts prove settled
  transactions are skipped.  (Bit-exactness vs the frozen legacy scans
  is asserted in tests/test_commit_pipeline.py — the engines under test
  there now run the incremental loop by default.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (READ, RMW, WRITE, RoundRobinSequencer, destm_execute,
                        fingerprint, make_batch, make_store, occ_execute,
                        pcc_execute, run_all)
from repro.core import protocol
from repro.core import workloads as W
from repro.core.txn import run_live
from repro.kernels.ops import _conflict_matrix_dense


def _wl(k: int, contention: str, seed: int = 0) -> W.Workload:
    if contention == "low":
        return W.counters(n_txns=k, n_objects=max(64, 8 * k), n_reads=2,
                          n_writes=2, n_lanes=min(8, k), skew=0.0, seed=seed)
    return W.counters(n_txns=k, n_objects=max(4, k // 4), n_reads=2,
                      n_writes=2, n_lanes=min(8, k), skew=1.0, seed=seed)


def _seq_for(wl):
    seqr = RoundRobinSequencer(n_root_lanes=wl.n_lanes)
    return jnp.asarray(seqr.order_for(wl.lanes.tolist()), jnp.int32)


# ------------------------------------------------------------- run_live
@pytest.mark.parametrize("k", [1, 2, 64])
@pytest.mark.parametrize("contention", ["low", "high"])
def test_run_live_equals_run_all_on_live_rows(k, contention):
    wl = _wl(k, contention, seed=k)
    store = make_store(wl.n_objects, init=np.arange(wl.n_objects) % 7)
    rng = np.random.default_rng(k)
    live = jnp.asarray(rng.random(k) < 0.5)
    full = run_all(wl.batch, store.values)
    got = run_live(wl.batch, store.values, live)
    lv = np.asarray(live)
    for f in ("raddrs", "rn", "waddrs", "wvals", "wn"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f))[lv], np.asarray(getattr(full, f))[lv],
            err_msg=f"live rows of {f} diverged from run_all")


def test_run_live_carries_cache_on_settled_rows():
    wl = _wl(16, "high", seed=3)
    store = make_store(wl.n_objects)
    cache = run_all(wl.batch, store.values)
    # change the store; settled rows must still show the OLD results
    values2 = store.values + 5
    live = jnp.asarray(np.arange(16) % 3 == 0)
    got = run_live(wl.batch, values2, live, cache)
    lv = np.asarray(live)
    full2 = run_all(wl.batch, values2)
    for f in ("raddrs", "rn", "waddrs", "wvals", "wn"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f))[~lv], np.asarray(getattr(cache, f))[~lv])
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f))[lv], np.asarray(getattr(full2, f))[lv])


@st.composite
def live_cases(draw):
    n_objects = draw(st.sampled_from([4, 8, 16]))
    k = draw(st.integers(1, 10))
    progs = []
    for _ in range(k):
        n_ins = draw(st.integers(1, 5))
        progs.append([
            (draw(st.sampled_from([READ, WRITE, RMW])),
             draw(st.integers(0, n_objects - 1)),
             draw(st.booleans()), draw(st.integers(-3, 3)))
            for _ in range(n_ins)])
    live = [draw(st.booleans()) for _ in range(k)]
    return n_objects, progs, live


@settings(max_examples=30, deadline=None)
@given(live_cases())
def test_property_run_live_masks_exactly(case):
    n_objects, progs, live = case
    batch = make_batch(progs)
    store = make_store(n_objects, init=np.arange(n_objects) % 5)
    live = jnp.asarray(live)
    full = run_all(batch, store.values)
    got = run_live(batch, store.values, live)
    lv = np.asarray(live)
    for f in ("raddrs", "rn", "waddrs", "wvals", "wn"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f))[lv], np.asarray(getattr(full, f))[lv])
    # dead rows with no cache are inert (empty footprints)
    assert not np.asarray(got.rn)[~lv].any()
    assert not np.asarray(got.wn)[~lv].any()


# --------------------------------------------- carried conflict tables
@pytest.mark.parametrize("k", [1, 2, 64])
@pytest.mark.parametrize("contention", ["low", "high"])
def test_delta_conflict_table_equals_rebuild_over_rounds(k, contention):
    """Simulated engine rounds: shrink the live set, mutate the store,
    and check every refreshed entry of the carried table against a
    from-scratch rebuild of the merged results (dense delta fallback)."""
    wl = _wl(k, contention, seed=11 + k)
    store = make_store(wl.n_objects)
    st_ = protocol.init_round_state(wl.batch, store.values, store.versions,
                                    use_matrix=True)
    rng = np.random.default_rng(k)
    live = np.ones(k, bool)
    for rnd in range(4):
        st_ = protocol.refresh_round_state(st_, wl.batch, jnp.asarray(live))
        fresh = np.asarray(_conflict_matrix_dense(
            st_.res.raddrs, st_.res.rn, st_.res.waddrs, st_.res.wn,
            wl.n_objects))
        refreshed = live[:, None] | live[None, :]
        np.testing.assert_array_equal(
            np.asarray(st_.conflict)[refreshed], fresh[refreshed],
            err_msg=f"round {rnd}: refreshed entries diverged from rebuild")
        # live rows of the cached result equal a full run_all
        full = run_all(wl.batch, st_.values)
        np.testing.assert_array_equal(
            np.asarray(st_.res.waddrs)[live], np.asarray(full.waddrs)[live])
        # a "commit": bump a random object, settle ~half the live txns
        st_ = protocol.commit_round_state(
            st_, st_.values.at[int(rng.integers(wl.n_objects))].add(1),
            st_.versions)
        live = live & (rng.random(k) < 0.5)


def test_refresh_accumulates_live_work():
    wl = _wl(8, "low", seed=2)
    store = make_store(wl.n_objects)
    st_ = protocol.init_round_state(wl.batch, store.values, store.versions)
    st_ = protocol.refresh_round_state(st_, wl.batch,
                                       jnp.ones((8,), bool))
    st_ = protocol.refresh_round_state(st_, wl.batch,
                                       jnp.asarray(np.arange(8) < 2))
    assert int(st_.live_txns) == 8 + 2
    n_ins = np.asarray(wl.batch.n_ins)
    assert int(st_.live_slots) == int(n_ins.sum() + n_ins[:2].sum())


# ------------------------------------------- incremental == rebuild
@pytest.mark.parametrize("k", [1, 2, 64])
@pytest.mark.parametrize("contention", ["low", "high"])
def test_engines_incremental_equals_rebuild(k, contention):
    wl = _wl(k, contention, seed=23 + k)
    store = make_store(wl.n_objects)
    seq = _seq_for(wl)
    lanes = jnp.asarray(wl.lanes, jnp.int32)
    arrival = jnp.argsort(seq)
    runs = {
        "pcc": lambda inc: pcc_execute(store, wl.batch, seq,
                                       incremental=inc),
        "occ": lambda inc: occ_execute(store, wl.batch, arrival,
                                       incremental=inc),
        "destm": lambda inc: destm_execute(store, wl.batch, seq, lanes,
                                           wl.n_lanes, incremental=inc),
    }
    for name, run in runs.items():
        out_inc, t_inc = run(True)
        out_reb, t_reb = run(False)
        assert int(fingerprint(out_inc)) == int(fingerprint(out_reb)), name
        np.testing.assert_array_equal(np.asarray(out_inc.versions),
                                      np.asarray(out_reb.versions))
        for f in ("commit_pos", "retries", "commit_round", "rounds",
                  "exec_ops", "wave_trips"):
            np.testing.assert_array_equal(
                np.asarray(getattr(t_inc, f)), np.asarray(getattr(t_reb, f)),
                err_msg=f"{name}: trace field {f!r} diverged")
        # the rebuild loop re-executes everything, every round
        assert int(t_reb.live_txns) == int(t_reb.rounds) * k
        assert int(t_inc.live_txns) <= int(t_reb.live_txns), name


def test_pcc_live_counts_shrink_with_commits():
    """The per-round live counts are the observable proving settled
    transactions are skipped: under PCC they equal the pending count,
    which shrinks by the committed prefix each round."""
    wl = _wl(64, "high", seed=7)
    store = make_store(wl.n_objects)
    out, trace = pcc_execute(store, wl.batch, _seq_for(wl))
    lc = trace.live_counts()
    assert lc[0] == 64
    assert (np.diff(lc) < 0).all()      # strictly shrinking live set
    assert int(trace.live_txns) == lc.sum()
    assert int(trace.live_slots) <= int(trace.rounds) * int(
        np.asarray(wl.batch.n_ins).sum())


def test_destm_live_counts_are_round_members():
    wl = _wl(32, "low", seed=9)
    store = make_store(wl.n_objects)
    out, trace = destm_execute(store, wl.batch, _seq_for(wl),
                               jnp.asarray(wl.lanes, jnp.int32), wl.n_lanes)
    lc = trace.live_counts()
    assert (lc <= wl.n_lanes).all()     # ≤ one txn per lane per round
    assert lc.sum() == 32               # every txn executes exactly once
    assert int(trace.live_txns) == 32


def test_occ_wave_trips_exposed():
    # disjoint: every wave converges in one trip
    progs = [[(RMW, i, False, 1)] for i in range(8)]
    batch = make_batch(progs)
    store = make_store(8)
    out, trace = occ_execute(store, batch, jnp.arange(8, dtype=jnp.int32))
    assert int(trace.rounds) == 1 and int(trace.wave_trips) == 1
    # a write-write chain: the fixpoint must iterate to the chain depth
    progs = [[(RMW, 0, False, 1)] for _ in range(6)]
    batch = make_batch(progs)
    store = make_store(4)
    out, trace = occ_execute(store, batch, jnp.arange(6, dtype=jnp.int32))
    assert int(trace.wave_trips) > int(trace.rounds)


def test_session_surfaces_live_counts():
    from repro.core import PotSession
    wl = _wl(16, "high", seed=4)
    session = PotSession(wl.n_objects, engine="pcc", n_lanes=wl.n_lanes)
    session.submit(wl.batch, wl.lanes.tolist())
    session.submit(wl.batch, wl.lanes.tolist())
    counts = session.live_counts()
    assert len(counts) == 2
    for lc, trace in zip(counts, session.traces):
        assert lc.shape == (int(trace.rounds),)
        assert lc[0] == 16
