"""Wave-speculative DeSTM retries == the serial token walk, bit for bit
(PR 10).

The wave-validity invariant (see repro/core/destm.py): a wave may commit
a token-order prefix of its re-executed members iff each committed row
(i) classifies identically once earlier wave members' speculative writes
are swapped for their actual re-executed writes, and (ii) logged no read
of an address an earlier prefix row commits this trip.  Both checks are
conservative only toward shrinking the prefix, and the first conflicting
row always commits, so:

* store image, fingerprint, and EVERY trace field except the wave
  observables (``retry_waves`` / ``waves_per_round``) are bitwise equal
  between ``wave=True`` and ``wave=False`` — and both match the PoGL
  serial oracle's store;
* ``retry_waves`` (wave) <= retry events (serial walk, = Σ retries for
  DeSTM), with equality exactly on fully serial conflict chains.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (RMW, READ, WRITE, RoundRobinSequencer, fingerprint,
                        make_batch, make_store)
from repro.core import workloads as W
from repro.core.destm import _destm_execute
from repro.core.engine import ExecTrace
from repro.core.pogl import pogl_execute

# the wave observables are the ONLY fields allowed to differ
WAVE_FIELDS = {"retry_waves", "waves_per_round"}

_destm = jax.jit(_destm_execute,
                 static_argnames=("n_lanes", "max_rounds", "incremental",
                                  "compact", "wave"))


def _seq_for(wl, lanes=None, n_lanes=None):
    lanes = wl.lanes.tolist() if lanes is None else lanes
    seqr = RoundRobinSequencer(n_root_lanes=n_lanes or wl.n_lanes)
    return jnp.asarray(seqr.order_for(lanes), jnp.int32)


def _run_both(store, batch, seq, lanes, n_lanes):
    sW, tW = _destm(store, batch, seq, lanes, n_lanes, wave=True)
    sS, tS = _destm(store, batch, seq, lanes, n_lanes, wave=False)
    return sW, tW, sS, tS


def _assert_wave_equals_serial(store, batch, seq, lanes, n_lanes, ctx=""):
    sW, tW, sS, tS = _run_both(store, batch, seq, lanes, n_lanes)
    assert int(fingerprint(sW)) == int(fingerprint(sS)), ctx
    np.testing.assert_array_equal(np.asarray(sW.values),
                                  np.asarray(sS.values), err_msg=ctx)
    np.testing.assert_array_equal(np.asarray(sW.versions),
                                  np.asarray(sS.versions), err_msg=ctx)
    assert int(sW.gv) == int(sS.gv), ctx
    for f in dataclasses.fields(ExecTrace):
        if f.name in WAVE_FIELDS:
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(tW, f.name)), np.asarray(getattr(tS, f.name)),
            err_msg=f"{ctx}: trace field {f.name} diverged")
    events = int(tS.retry_waves)
    waves = int(tW.retry_waves)
    # the serial walk's trips ARE the retry events
    assert events == int(np.asarray(tS.retries).sum()), ctx
    assert waves <= events, f"{ctx}: waves {waves} > events {events}"
    # per-round counts dominate the same way, round by round
    wS, wW = tS.wave_counts(), tW.wave_counts()
    assert wS.shape == wW.shape and (wW <= wS).all(), ctx
    return sW, tW, tS


def _wl(k: int, contention: str, seed: int = 0, n_lanes: int = 4):
    n_lanes = min(n_lanes, k)
    if contention == "low":
        return W.counters(n_txns=k, n_objects=max(64, 8 * k), n_reads=2,
                          n_writes=2, n_lanes=n_lanes, skew=0.0, seed=seed)
    return W.counters(n_txns=k, n_objects=max(4, k // 4), n_reads=2,
                      n_writes=2, n_lanes=n_lanes, skew=1.0, seed=seed)


# ------------------------------------------------- wave == serial == oracle
@pytest.mark.parametrize("k", [1, 2, 64])
@pytest.mark.parametrize("contention", ["low", "high"])
@pytest.mark.parametrize("n_lanes", [1, 8])
def test_wave_equals_serial_walk(k, contention, n_lanes):
    wl = _wl(k, contention, seed=3 * k + n_lanes, n_lanes=n_lanes)
    store = make_store(wl.n_objects)
    seq = _seq_for(wl)
    lanes = jnp.asarray(wl.lanes, jnp.int32)
    sW, _, _ = _assert_wave_equals_serial(
        store, wl.batch, seq, lanes, wl.n_lanes,
        f"k={k} {contention} lanes={wl.n_lanes}")
    # anchor both modes to the serial oracle
    assert int(fingerprint(sW)) == int(fingerprint(
        pogl_execute(store, wl.batch, seq)))


def test_single_lane_degenerate():
    # one lane: one member per round, never a conflict, never a wave
    wl = _wl(12, "high", seed=5, n_lanes=1)
    store = make_store(wl.n_objects)
    seq = _seq_for(wl)
    lanes = jnp.asarray(wl.lanes, jnp.int32)
    _, tW, tS = _assert_wave_equals_serial(store, wl.batch, seq, lanes, 1,
                                           "single lane")
    assert int(tW.retry_waves) == int(tS.retry_waves) == 0
    assert int(np.asarray(tW.retries).sum()) == 0


def test_fully_serial_chain_wave_equals_events():
    # every txn RMWs the same object: within a round, each member
    # conflicts with ALL earlier members, so each wave resolves exactly
    # one row — waves == retry events (the equality edge of the bound)
    n_lanes, per_lane = 6, 2
    progs = [[(RMW, 0, False, 1)]
             for _ in range(n_lanes * per_lane)]
    batch = make_batch(progs)
    lanes = [i % n_lanes for i in range(n_lanes * per_lane)]
    seq = _seq_for(None, lanes=lanes, n_lanes=n_lanes)
    store = make_store(16)
    _, tW, tS = _assert_wave_equals_serial(
        store, batch, seq, jnp.asarray(lanes, jnp.int32), n_lanes,
        "serial chain")
    events = int(tS.retry_waves)
    assert events == (n_lanes - 1) * per_lane  # all but the token head
    assert int(tW.retry_waves) == events       # no wave win on a chain


def test_disjoint_pairs_one_wave_per_round():
    # lanes (2i, 2i+1) blind-WRITE object i: each round has 4
    # independent pairwise write-write conflicts.  The serial walk pays
    # one retry event per pair; one wave re-executes all 4 losers at
    # once, and with empty read sets every re-execution is trivially
    # serial-valid, so the whole prefix commits in a single wave.  (RMW
    # pairs would NOT collapse: the loser must read its partner's value,
    # which commits in the same trip — after the wave's snapshot — so
    # the execution-validity check correctly rejects it to next wave.)
    n_lanes = 8
    progs = [[(WRITE, i // 2, False, i + 1)] for i in range(n_lanes)]
    batch = make_batch(progs)
    lanes = list(range(n_lanes))
    seq = _seq_for(None, lanes=lanes, n_lanes=n_lanes)
    store = make_store(16)
    sW, tW, tS = _assert_wave_equals_serial(
        store, batch, seq, jnp.asarray(lanes, jnp.int32), n_lanes,
        "disjoint pairs")
    assert int(tS.retry_waves) == n_lanes // 2   # one event per pair
    assert int(tW.retry_waves) == 1              # one wave clears them all
    # last-writer-wins per pair: the loser's value lands
    got = np.asarray(sW.values)[:n_lanes // 2, 0]
    np.testing.assert_array_equal(got, [2, 4, 6, 8])


def test_wave_counts_accessor_trims_to_rounds():
    wl = _wl(24, "high", seed=9, n_lanes=8)
    store = make_store(wl.n_objects)
    _, tW = _destm(store, wl.batch, _seq_for(wl),
                   jnp.asarray(wl.lanes, jnp.int32), wl.n_lanes, wave=True)
    counts = tW.wave_counts()
    assert counts.shape == (int(tW.rounds),)
    assert (counts >= 0).all()                   # -1 padding trimmed off
    assert counts.sum() == int(tW.retry_waves)


def test_session_wave_counts():
    from repro.core import PotSession
    wl = _wl(16, "high", seed=13, n_lanes=4)
    s = PotSession(wl.n_objects, engine="destm", n_lanes=4)
    s.submit(wl.batch, wl.lanes)
    (counts,) = s.wave_counts()
    assert counts.shape == (int(s.traces[0].rounds),)
    # pcc has no token walk: empty arrays, same accessor
    s2 = PotSession(wl.n_objects, engine="pcc", n_lanes=4)
    s2.submit(wl.batch, wl.lanes)
    assert s2.wave_counts()[0].size == 0


# ------------------------------------------------------- hypothesis property
@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.0, 1.8),
       st.sampled_from([2, 5, 8]))
def test_wave_equals_serial_property(seed, skew, n_lanes):
    # random retry graphs: skewed hot sets drive random conflict shapes
    wl = W.counters(n_txns=24, n_objects=24, n_reads=2, n_writes=2,
                    n_lanes=n_lanes, skew=skew, seed=seed)
    store = make_store(wl.n_objects)
    _assert_wave_equals_serial(
        store, wl.batch, _seq_for(wl), jnp.asarray(wl.lanes, jnp.int32),
        wl.n_lanes, f"seed={seed} skew={skew:.2f} lanes={n_lanes}")
