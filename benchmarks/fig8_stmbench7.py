"""Fig. 8 analog: STMBench7 throughput (r / rw / w workloads), normalized
to the nondeterministic OCC baseline (higher is better)."""

from __future__ import annotations

from benchmarks.common import emit, run_engines
from repro.core import workloads as W


def run() -> None:
    for mode in ("r", "rw", "w"):
        for n_lanes in (2, 4, 8, 16):
            wl = W.stmbench7_like(mode, n_txns=96, n_lanes=n_lanes, seed=7)
            reports = run_engines(wl)
            base = reports["occ"].throughput or 1.0
            emit(f"fig8_stmbench7[{mode},lanes={n_lanes}]",
                 reports["pot"].critical_path,
                 "throughput_vs_occ:"
                 f"destm={reports['destm'].throughput/base:.2f}x,"
                 f"pogl={reports['pogl'].throughput/base:.2f}x,"
                 f"pot={reports['pot'].throughput/base:.2f}x")


if __name__ == "__main__":
    run()
