"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see each fig module for
the mapping to the paper's tables/figures).  ``python -m benchmarks.run``
runs everything; ``--only fig7`` filters."""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark module names")
    args = ap.parse_args()

    from benchmarks import (engine_bench, fig6_fast_tx, fig7_stamp,
                            fig8_stmbench7, fig9_wait, fig11_scalability,
                            fig13_capacity, fig14_det_training, roofline)
    mods = [fig6_fast_tx, fig7_stamp, fig8_stmbench7, fig9_wait,
            fig11_scalability, fig13_capacity, fig14_det_training,
            roofline, engine_bench]
    print("name,us_per_call,derived")
    failed = []
    for mod in mods:
        name = mod.__name__.split(".")[-1]
        if args.only and args.only not in name:
            continue
        try:
            mod.run()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
