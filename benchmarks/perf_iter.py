"""§Perf hillclimb runner: re-lower a cell under a named variant and
report the roofline-term deltas vs the recorded baseline.

Variants (sharding/schedule changes, not model changes):
  base        — the swept configuration (FSDP+TP+SP)
  pure_dp     — use the model axis as extra data: 256-way FSDP, no TP/SP
  mb2 / mb1   — fewer microbatches (fewer per-step parameter regathers)
  pure_dp_mb1 — combined
"""

from __future__ import annotations

import json
import os
import sys

VARIANTS = {
    "base": {},
    "pure_dp": {"profile_patch": {"pure_dp": True}},
    "mb2": {"n_mb_override": 2},
    "mb1": {"n_mb_override": 1},
    "pure_dp_mb1": {"profile_patch": {"pure_dp": True}, "n_mb_override": 1},
    "pure_dp_mb2": {"profile_patch": {"pure_dp": True}, "n_mb_override": 2},
    "bf16_params": {"force_huge": True},
    "pure_dp_bf16": {"profile_patch": {"pure_dp": True},
                     "n_mb_override": 1, "force_huge": True},
    "cf1": {"cfg_patch": {"capacity_factor": 1.0}},
    "cf1_bf16": {"cfg_patch": {"capacity_factor": 1.0},
                 "force_huge": True},
}


def run_variant(arch: str, shape: str, variant: str, out_dir: str):
    from repro.launch import dryrun as D
    from repro.configs import get_config
    cfg = get_config(arch)
    kw = VARIANTS[variant]
    c1, m1 = D.lower_cell(arch, shape, multi_pod=False, n_groups=1,
                          unroll=True, train_mode="baseline",
                          verbose=False, **kw)
    s1 = D.summarize(c1, 256)
    del c1
    c2, _ = D.lower_cell(arch, shape, multi_pod=False, n_groups=2,
                         unroll=True, train_mode="baseline",
                         verbose=False, **kw)
    s2 = D.summarize(c2, 256)
    del c2
    # full-depth fit check for the variant
    cf, mf = D.lower_cell(arch, shape, multi_pod=False, train_mode="pot",
                          verbose=False, **kw)
    mem = cf.memory_analysis()
    del cf
    units = D.depth_units(cfg)
    ex = D.extrapolate(s1, s2, units)
    rec = {"arch": arch, "shape": shape, "variant": variant,
           "analysis": {"g1": s1, "g2": s2, "depth_units": units,
                        "extrapolated": ex},
           "single_pod": {"meta": mf, "memory": {
               "argument_bytes": int(mem.argument_size_in_bytes),
               "temp_bytes": int(mem.temp_size_in_bytes),
               "peak_bytes": int(mem.argument_size_in_bytes
                                 + mem.output_size_in_bytes
                                 + mem.temp_size_in_bytes)}}}
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape}__{variant}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    arch, shape, variant = sys.argv[1], sys.argv[2], sys.argv[3]
    rec = run_variant(arch, shape, variant, "results/perf")
    ex = rec["analysis"]["extrapolated"]
    coll = ex["collectives"]
    print(f"{arch}/{shape} [{variant}]  flops={ex['flops']:.3e}  "
          f"coll_total={coll['total']/1e9:.1f}GB  "
          f"bf16wire={coll.get('total_bf16_wire', 0)/1e9:.1f}GB  "
          f"ag={coll['all-gather']/1e9:.1f} ar={coll['all-reduce']/1e9:.1f} "
          f"rs={coll['reduce-scatter']/1e9:.1f} a2a={coll['all-to-all']/1e9:.1f}  "
          f"temp={rec['single_pod']['memory']['temp_bytes']/1e9:.1f}GB")


if __name__ == "__main__":
    main()
