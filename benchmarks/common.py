"""Shared benchmark utilities."""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import PotSession, make_store, run_all
from repro.core import metrics as M


def timeit(fn, *args, warmup=1, iters=3):
    """Median wall-clock seconds of fn(*args) (jit-compiled callables)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run_engines(wl, *, engines=("pot", "pogl", "destm", "occ")):
    """Run a workload through the engines; return {name: EngineReport}.

    Every engine goes through the same PotSession API — the report's
    cost model is the only per-engine piece left.
    """
    res = run_all(wl.batch, make_store(wl.n_objects).values)
    rn, wn = np.asarray(res.rn), np.asarray(res.wn)
    out = {}
    for name in engines:
        session = PotSession(wl.n_objects, engine=name, n_lanes=wl.n_lanes)
        trace = session.submit(wl.batch, wl.lanes.tolist())
        out[name] = M.report_from_trace(name, trace, wl.batch, rn, wn,
                                        n_lanes=wl.n_lanes, session=session)
    return out


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.2f},{derived}")
