"""Shared benchmark utilities."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (RoundRobinSequencer, destm_execute, make_store,
                        occ_execute, pcc_execute, pogl_execute, run_all)
from repro.core import metrics as M


def timeit(fn, *args, warmup=1, iters=3):
    """Median wall-clock seconds of fn(*args) (jit-compiled callables)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run_engines(wl, *, engines=("pot", "pogl", "destm", "occ")):
    """Run a workload through the engines; return {name: EngineReport}."""
    store = make_store(wl.n_objects)
    seq = jnp.asarray(
        RoundRobinSequencer(n_root_lanes=wl.n_lanes).order_for(
            wl.lanes.tolist()), jnp.int32)
    res = run_all(wl.batch, store.values)
    rn, wn = np.asarray(res.rn), np.asarray(res.wn)
    out = {}
    if "pot" in engines:
        _, tr = pcc_execute(store, wl.batch, seq)
        out["pot"] = M.report_pcc(tr, wl.batch, rn, wn)
    if "pogl" in engines:
        pogl_execute(store, wl.batch, seq)
        out["pogl"] = M.report_pogl(wl.batch, rn, wn)
    if "destm" in engines:
        _, tr = destm_execute(store, wl.batch, seq,
                              jnp.asarray(wl.lanes, jnp.int32), wl.n_lanes)
        out["destm"] = M.report_destm(tr, wl.batch, rn, wn, wl.n_lanes)
    if "occ" in engines:
        arrival = jnp.arange(wl.batch.n_txns, dtype=jnp.int32)
        _, tr = occ_execute(store, wl.batch, arrival)
        out["occ"] = M.report_occ(tr, wl.batch, rn, wn)
    return out


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.2f},{derived}")
