"""Regenerate the §Tables section of EXPERIMENTS.md from
results/dryrun/*.json (run after a dry-run sweep)."""

from __future__ import annotations

import glob
import json
import os
import re

ROOT = os.path.join(os.path.dirname(__file__), "..")
RESULTS = os.path.join(ROOT, "results", "dryrun")


def dryrun_table() -> str:
    rows = ["| arch | shape | mode | 16x16 GB/chip | 2x16x16 GB/chip | "
            "opt | mb | compile s (sp/mp) |",
            "|---|---|---|---|---|---|---|---|"]
    for p in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        r = json.load(open(p))
        sp = r["single_pod"]

        def corrected(mem, mode):
            if "alias_bytes" in mem:
                base = (mem["argument_bytes"] + mem["output_bytes"]
                        - mem["alias_bytes"] + mem["temp_bytes"])
            elif mode in ("train", "decode"):
                # donated state/cache: outputs alias the arguments
                base = mem["argument_bytes"] + mem["temp_bytes"]
            else:
                base = (mem["argument_bytes"] + mem["output_bytes"]
                        + mem["temp_bytes"])
            if mode == "decode":
                # CPU assigner cannot alias the donated cache through the
                # layer scan: temp carries ~2 unaliased cache copies
                base -= 2 * mem["argument_bytes"]
            return max(base, 0)

        mem = sp["memory"]
        peak = corrected(mem, r["mode"]) / 1e9
        mp = r.get("multi_pod", {})
        mpeak = (corrected(mp["memory"], r["mode"]) / 1e9
                 if mp.get("memory") else 0.0)
        meta = sp["meta"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mode']} | {peak:.1f} | "
            f"{mpeak:.1f} | {meta['optimizer']} | {meta['n_microbatches']}"
            f" | {meta['compile_s']}/"
            f"{mp.get('meta', {}).get('compile_s', '-')} |")
    return "\n".join(rows)


def roofline_table() -> str:
    import sys
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro.launch.roofline_model import terms_from_record
    rows = ["| arch | shape | compute_s | memory_s | collective_s | "
            "bound | roof_frac | MFU-proxy | useful |",
            "|---|---|---|---|---|---|---|---|---|"]
    for p in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        r = json.load(open(p))
        if "analysis" not in r:
            continue
        t = terms_from_record(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"{t['bottleneck']} | {t['roofline_fraction']:.3f} | "
            f"{t['mfu_proxy']:.3f} | {t['useful_ratio']:.2f} |")
    return "\n".join(rows)


def main() -> None:
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    text = re.sub(
        r"<!-- TABLE:DRYRUN -->(?:.*?(?=\n### |\n8 documented|\Z))?",
        "<!-- TABLE:DRYRUN -->\n" + dryrun_table() + "\n",
        text, flags=re.S)
    text = re.sub(
        r"<!-- TABLE:ROOFLINE -->(?:.*?(?=\n8 documented|\Z))?",
        "<!-- TABLE:ROOFLINE -->\n" + roofline_table() + "\n",
        text, flags=re.S)
    open(path, "w").write(text)
    print("EXPERIMENTS.md tables updated")


if __name__ == "__main__":
    main()
