"""Fig. 13 analog: capacity aborts — fast vs speculative transactions.

Paper: IBM ROTs keep no read set, so fast HTM transactions enjoy a larger
cache-capacity budget and fall back to the global lock less (§4.2.1).
TPU analog: the fast-path commit kernel (kernels/fused_adamw._adamw_kernel)
carries 7 tiles in VMEM (hp, p, m, v, g, + 3 outputs); the speculative
variant additionally carries the version tile and abort flags plus
validation logic — a strictly smaller usable tile budget under the
16 MiB/core VMEM limit.  We compute the max square tile per variant and
the fraction of a realistic block-size distribution that exceeds each
budget ("capacity aborts"), and verify both kernels execute at their
boundary tiles in interpret mode."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops

VMEM = 16 * 2**20


def tiles_budget(n_buffers_f32, extra_bytes=0):
    """Largest square tile (multiple of 128) fitting the VMEM budget."""
    t = 128
    while True:
        nxt = t + 128
        if n_buffers_f32 * nxt * nxt * 4 + extra_bytes > VMEM:
            return t
        t = nxt


def run() -> None:
    # fast: p,m,v,g in + p,m,v out = 7 f32 tiles (+ 32B hp)
    fast_tile = tiles_budget(7, 32)
    # speculative: + version tile bookkeeping, abort flags, rv compare,
    # and double-buffered read-set log (one version word per tile row)
    spec_tile = tiles_budget(8, 32 + 4 * 4096)
    fast_cap = fast_tile * fast_tile
    spec_cap = spec_tile * spec_tile

    # block-size distribution: parameter-leaf tile footprints drawn from
    # the assigned archs' layer shapes (d_model x d_ff slices)
    rng = np.random.default_rng(0)
    sizes = rng.lognormal(mean=np.log(syn := 512 * 512), sigma=0.8,
                          size=4096)
    fast_aborts = float((sizes > fast_cap).mean())
    spec_aborts = float((sizes > spec_cap).mean())

    # boundary-tile execution check (interpret mode)
    r = c = 512
    p = jnp.ones((r, c), jnp.float32)
    z = jnp.zeros((r, c), jnp.float32)
    ops.adamw_update(p, z, z, p, step=1)
    ops.adamw_update_speculative(
        p, z, z, p, jnp.zeros((r // 256, c // 256), jnp.int32),
        jnp.asarray(1, jnp.int32), step=1)

    emit("fig13_capacity", 0.0,
         f"fast_tile={fast_tile}x{fast_tile},spec_tile={spec_tile}x"
         f"{spec_tile},fast_abort_pct={100*fast_aborts:.1f},"
         f"spec_abort_pct={100*spec_aborts:.1f}")


if __name__ == "__main__":
    run()
