"""Old-vs-new commit pipeline benchmark (PR 2) -> BENCH_engines.json.

Times every engine twice on the same workloads:

* ``scan``     — the preserved pre-refactor implementations
                 (repro.core.legacy_scan): per-round K-step commit scan
                 with an O(n_objects) bitmap probe + lax.cond write-back
                 per transaction;
* ``pipeline`` — the vectorized commit pipeline (protocol.py: batched
                 conflict analysis — K×K bitset-intersection matrix on
                 TPU, first-writer scatter-min elsewhere — + log-depth
                 prefix fixpoint + one fused write-back scatter).

Axes: K (batch size) × contention (low/med) × engine (pcc/occ/destm).
Emits txns/sec for both implementations plus the commit-phase
device-step model per round (scan: K sequential steps; pipeline:
⌈log₂K⌉ for the associative-scan fixpoint + a constant handful of
batched stages).

``--smoke`` (the CI stage, scripts/ci.sh --bench-smoke): tiny K, runs
both implementations and asserts their store fingerprints and commit
positions are identical — a perf refactor cannot silently diverge.

Usage:
  python benchmarks/engine_bench.py [--out BENCH_engines.json]
  python benchmarks/engine_bench.py --smoke
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import (RoundRobinSequencer, destm_execute, fingerprint,
                        legacy_scan, make_store, occ_execute, pcc_execute)
from repro.core import workloads as W


def _workload(k: int, contention: str, seed: int = 0) -> W.Workload:
    """Array-of-counters microbenchmark (§4.1.1) at a given contention.

    low: uniform addresses over a store much larger than the batch's
    total footprint — speculation almost always wins (the regime the
    paper's "ordering as a blessing" argument targets).
    med: zipf-skewed addresses over a K-sized store — real abort chains,
    several engine rounds.
    """
    n_lanes = min(8, k)
    if contention == "low":
        return W.counters(n_txns=k, n_objects=max(64, 8 * k), n_reads=2,
                          n_writes=2, n_lanes=n_lanes, skew=0.0, seed=seed)
    return W.counters(n_txns=k, n_objects=max(16, k), n_reads=2, n_writes=2,
                      n_lanes=n_lanes, skew=0.9, seed=seed)


def _seq_for(wl: W.Workload) -> jax.Array:
    seqr = RoundRobinSequencer(n_root_lanes=wl.n_lanes)
    return jnp.asarray(seqr.order_for(wl.lanes.tolist()), jnp.int32)


def _runners(wl: W.Workload):
    """{engine: {impl: zero-arg jitted callable -> (store, trace)}}."""
    store = make_store(wl.n_objects)
    seq = _seq_for(wl)
    arrival = jnp.argsort(seq)
    lanes = jnp.asarray(wl.lanes, jnp.int32)
    return store, {
        "pcc": {
            "scan": lambda: legacy_scan.pcc_execute_scan(store, wl.batch, seq),
            "pipeline": lambda: pcc_execute(store, wl.batch, seq),
        },
        "occ": {
            "scan": lambda: legacy_scan.occ_execute_scan(
                store, wl.batch, arrival),
            "pipeline": lambda: occ_execute(store, wl.batch, arrival),
        },
        "destm": {
            "scan": lambda: legacy_scan.destm_execute_scan(
                store, wl.batch, seq, lanes, wl.n_lanes),
            "pipeline": lambda: destm_execute(
                store, wl.batch, seq, lanes, wl.n_lanes),
        },
    }


def _commit_steps_model(impl: str, k: int) -> int:
    if impl == "scan":
        return k                                  # one scan step per txn
    return int(math.ceil(math.log2(max(k, 2)))) + 3   # matrix + reduce +
    #                                         assoc-scan depth + scatter


def run_bench(ks, contentions, iters: int) -> dict:
    results = []
    for k in ks:
        for cont in contentions:
            wl = _workload(k, cont)
            store, runners = _runners(wl)
            for engine, impls in runners.items():
                row_traces = {}
                for impl, fn in impls.items():
                    secs = timeit(fn, warmup=2, iters=iters)
                    out, trace = fn()
                    row_traces[impl] = (out, trace)
                    results.append(dict(
                        engine=engine, k=k, contention=cont, impl=impl,
                        seconds=round(secs, 6),
                        txns_per_sec=round(k / secs, 1),
                        rounds=int(trace.rounds),
                        commit_steps_per_round=_commit_steps_model(impl, k),
                    ))
                    print(f"{engine:6s} K={k:<5d} {cont:4s} {impl:8s} "
                          f"{secs * 1e3:9.2f} ms  {k / secs:12.1f} txn/s  "
                          f"rounds={int(trace.rounds)}")
                _assert_equal(engine, k, cont, *row_traces["scan"],
                              *row_traces["pipeline"])
    return dict(results=results)


def _assert_equal(engine, k, cont, out_old, t_old, out_new, t_new):
    fp_old, fp_new = int(fingerprint(out_old)), int(fingerprint(out_new))
    assert fp_old == fp_new, (
        f"{engine} K={k} {cont}: pipeline fingerprint {fp_new:#x} diverged "
        f"from scan {fp_old:#x}")
    for field in ("commit_pos", "retries"):
        a = np.asarray(getattr(t_old, field))
        b = np.asarray(getattr(t_new, field))
        assert np.array_equal(a, b), (
            f"{engine} K={k} {cont}: trace field {field!r} diverged")


def summarize(results) -> dict:
    speedups = {}
    for row in results:
        if row["impl"] != "pipeline":
            continue
        old = next(r for r in results
                   if r["impl"] == "scan" and r["engine"] == row["engine"]
                   and r["k"] == row["k"]
                   and r["contention"] == row["contention"])
        key = f'{row["engine"]}/K{row["k"]}/{row["contention"]}'
        speedups[key] = round(old["seconds"] / row["seconds"], 2)
    return speedups


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny K, equivalence assertions only (CI stage)")
    ap.add_argument(
        "--out",
        default=os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "..", "BENCH_engines.json"))
    ap.add_argument("--iters", type=int, default=5)
    args = ap.parse_args()

    if args.smoke:
        # equivalence gate: every engine, old-vs-new, must agree bitwise
        for k in (2, 8):
            for cont in ("low", "med"):
                wl = _workload(k, cont, seed=k)
                _, runners = _runners(wl)
                for engine, impls in runners.items():
                    out_old, t_old = impls["scan"]()
                    out_new, t_new = impls["pipeline"]()
                    _assert_equal(engine, k, cont, out_old, t_old,
                                  out_new, t_new)
        print("bench-smoke OK: scan and pipeline agree bitwise "
              "(engines: pcc, occ, destm; K in {2, 8}; low/med contention)")
        return

    ks = (64, 256, 1024)
    bench = run_bench(ks, ("low", "med"), args.iters)
    bench["meta"] = dict(
        backend=jax.default_backend(),
        devices=len(jax.devices()),
        note="scan = pre-PR2 legacy per-txn commit scans; pipeline = "
             "batched conflict analysis + prefix fixpoint + fused "
             "write-back.  OCC's wave rule is a fixpoint that iterates "
             "to the conflict-chain depth, so its pipeline cost grows "
             "with contention (it is the nondeterministic baseline the "
             "paper argues against, kept for comparison).",
        commit_steps_model="scan: K sequential device steps per round; "
                           "pipeline: ceil(log2 K) + 3 batched stages "
                           "(PCC/DeSTM; OCC: conflict-chain depth)",
    )
    bench["speedup_scan_to_pipeline"] = summarize(bench["results"])
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
