"""Engine-loop benchmark (PR 2 + PR 3 + PR 4) -> BENCH_engines.json.

Times every engine four ways on the same workloads:

* ``scan``        — the preserved pre-refactor implementations
                    (repro.core.legacy_scan): per-round K-step commit scan
                    with an O(n_objects) bitmap probe + lax.cond write-back
                    per transaction;
* ``rebuild``     — the PR 2 vectorized commit pipeline with a from-scratch
                    round: full-batch ``run_all`` + rebuilt conflict
                    analysis every round (``incremental=False``);
* ``incremental`` — the PR 3 RoundState loop: masked ``run_live`` over the
                    live transactions only, carried conflict table with
                    delta updates (``compact=False``);
* ``compact``     — the PR 4 gather-compacted cascade: once the live set
                    fits a compact-ladder rung, the read phase gathers it
                    into a (C, L) block and executes THAT — device work
                    scales with the live set, not K.

Axes: K (batch size) × contention (low/med) × engine (pcc/occ/destm),
plus sweeps over store slot width S, transaction length L and lane count
at fixed K.  Each row records wall-clock txns/sec AND the read-phase
device-work model: ``read_phase_slots`` = Σ rounds Σ live instruction
slots (the rebuild loop pays ``rounds × Σ n_ins``; the incremental loop
pays only the live rows) and ``walked_slots`` = Σ rounds executor width
× L — the slots the device actually walks (K·L masked, C·L compact).

Two PR 4 sections ride along:

* live-fraction sweep (axis="live_fraction"): the read-phase PRIMITIVE —
  masked ``run_live`` vs gather-compacted ``run_live_compact`` — timed at
  live/K in {1/64, 1/8, 1/2, 1} on one batch, with results asserted
  bitwise-equal.  The compacted executor's walked slots scale with C
  (next_pow2 of the live count), the masked one's with K.
* ragged-stream compile counts (axis="ragged_stream"): a 32-shape ragged
  stream through PotSession with and without shape bucketing —
  compile_count() must stay <= the bucket-ladder size when bucketing.

One PR 5 section:

* shard sweep (axis="shards"): every engine's compact cascade on a
  store partitioned into S in {1, 4, 8} contiguous range shards
  (per-shard conflict tables OR-reduced in rank space + S independent
  write-back scatters), asserted bit-identical to the dense S=1 run,
  plus the write-back PRIMITIVE (``protocol.fused_write_back``) timed
  per S on one full committing round.

One PR 6 section:

* ingress (axis="ingress"): the deterministic serve loop — arrival
  journal -> IngressPool admission -> priority drain -> PotSession —
  timed against direct submission of the same pre-formed batches (the
  delta is the host-side ingress overhead) and the drain-only former;
  plus the occupancy-driven bucket-ladder auto-selection vs a pinned
  pow2 ladder (compile counts + padding waste, fingerprints asserted
  bit-identical).

One PR 7 section:

* pipeline (axis="pipeline"): cross-batch speculative pipelining —
  a PotSession with ``pipeline_depth=D`` executes batch n+1 against
  the pre-state snapshot while batch n commits, then validates n+1's
  logged read sets against n's committed writes (``versions >
  snap_gv`` via the rectangular conflict-strip kernels) and
  re-executes only invalidated rows.  Serial (D=0) vs D in {1, 2}
  stream throughput per engine × K × contention, with speculation
  observables (spec_executed / spec_invalidated / spec_rounds) per
  row; every pipelined stream is asserted bit-identical to the serial
  one (fingerprints + replay logs + full traces).

One PR 10 section:

* destm wave retries (axis="destm_wave"): the serial token walk (one
  retry EVENT per while_loop trip, the frozen-oracle port) vs
  wave-speculative retries (all of a trip's conflicting members
  re-execute at once against the committed-so-far store and the
  maximal provably-serial token prefix commits), K × contention ×
  lane count plus a blind write-write best case.  Every pair is
  asserted bitwise identical on stores and every trace field except
  the wave observables; rows carry retry_events / retry_waves and
  their reduction (waves == events only on fully serial chains).

``--shard-smoke`` (scripts/ci.sh --shard-smoke): asserts sharded ==
dense store fingerprints and traces across engines at S in {1, 2, 8},
and — when the host exposes multiple devices
(XLA_FLAGS=--xla_force_host_platform_device_count=8) — the shard_map
per-device write-back path on a real mesh.

``--ingress-smoke`` (scripts/ci.sh --ingress-smoke): asserts two
IngressPool replicas fed the same arrival journal agree bitwise —
fingerprints + replay logs — across different drain budget schedules,
and that a full journal replay reproduces the formed batch stream.

``--pipeline-smoke`` (scripts/ci.sh --pipeline-smoke): replays one
ingress arrival journal through a serial session and pipelined
sessions (D in {1, 2}, engines pcc + occ) under different drain
budget schedules and asserts bitwise equality — fingerprints, replay
logs, and every pre-existing ExecTrace field (speculation cost may
only appear in the new spec_* observables).

``--destm-wave-smoke`` (scripts/ci.sh --destm-wave-smoke): asserts
wave-speculative DeSTM retries == the serial token walk bitwise
(stores + all non-wave trace fields) across K × contention × lanes,
with retry_waves <= retry events everywhere and a strict reduction on
the blind write-write best case.

``--smoke`` (scripts/ci.sh --bench-smoke): tiny K, asserts the four
implementations' store fingerprints and commit positions are bitwise
identical, and exercises the conflict-kernel delta path (skipped with a
message when the TPU kernel path is unavailable, so CPU-only CI still
runs the stage).

``--incremental-smoke`` (scripts/ci.sh --incremental-smoke): asserts
incremental == rebuild store fingerprints and traces across all three
engines.

``--compact-smoke`` (scripts/ci.sh --compact-smoke): asserts compact ==
masked (incremental) == rebuild store fingerprints and traces across all
three engines, plus run_live_compact == run_live at the primitive level.

Usage:
  python benchmarks/engine_bench.py [--out BENCH_engines.json]
  python benchmarks/engine_bench.py --smoke
  python benchmarks/engine_bench.py --incremental-smoke
  python benchmarks/engine_bench.py --compact-smoke
  python benchmarks/engine_bench.py --ingress-smoke
  python benchmarks/engine_bench.py --destm-wave-smoke
"""

from __future__ import annotations

import argparse
import functools
import json
import math
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import (RoundRobinSequencer, destm_execute, fingerprint,
                        legacy_scan, make_store, occ_execute, pcc_execute)
from repro.core import workloads as W


def _workload(k: int, contention: str, seed: int = 0, *,
              n_reads: int = 2, n_writes: int = 2,
              n_lanes: int | None = None) -> W.Workload:
    """Array-of-counters microbenchmark (§4.1.1) at a given contention.

    low: uniform addresses over a store much larger than the batch's
    total footprint — speculation almost always wins (the regime the
    paper's "ordering as a blessing" argument targets).
    med: zipf-skewed addresses over a K-sized store — real abort chains,
    several engine rounds.
    """
    n_lanes = n_lanes if n_lanes is not None else min(8, k)
    if contention == "low":
        return W.counters(n_txns=k, n_objects=max(64, 8 * k),
                          n_reads=n_reads, n_writes=n_writes,
                          n_lanes=n_lanes, skew=0.0, seed=seed)
    return W.counters(n_txns=k, n_objects=max(16, k), n_reads=n_reads,
                      n_writes=n_writes, n_lanes=n_lanes, skew=0.9,
                      seed=seed)


def _seq_for(wl: W.Workload) -> jax.Array:
    seqr = RoundRobinSequencer(n_root_lanes=wl.n_lanes)
    return jnp.asarray(seqr.order_for(wl.lanes.tolist()), jnp.int32)


def _runners(wl: W.Workload, slot: int = 1):
    """{engine: {impl: zero-arg jitted callable -> (store, trace)}}."""
    store = make_store(wl.n_objects, slot=slot)
    seq = _seq_for(wl)
    arrival = jnp.argsort(seq)
    lanes = jnp.asarray(wl.lanes, jnp.int32)
    return store, {
        "pcc": {
            "scan": lambda: legacy_scan.pcc_execute_scan(store, wl.batch, seq),
            "rebuild": lambda: pcc_execute(store, wl.batch, seq,
                                           incremental=False),
            "incremental": lambda: pcc_execute(store, wl.batch, seq,
                                               compact=False),
            "compact": lambda: pcc_execute(store, wl.batch, seq),
        },
        "occ": {
            "scan": lambda: legacy_scan.occ_execute_scan(
                store, wl.batch, arrival),
            "rebuild": lambda: occ_execute(store, wl.batch, arrival,
                                           incremental=False),
            "incremental": lambda: occ_execute(store, wl.batch, arrival,
                                               compact=False),
            "compact": lambda: occ_execute(store, wl.batch, arrival),
        },
        "destm": {
            "scan": lambda: legacy_scan.destm_execute_scan(
                store, wl.batch, seq, lanes, wl.n_lanes),
            "rebuild": lambda: destm_execute(
                store, wl.batch, seq, lanes, wl.n_lanes, incremental=False),
            "incremental": lambda: destm_execute(
                store, wl.batch, seq, lanes, wl.n_lanes, compact=False),
            "compact": lambda: destm_execute(
                store, wl.batch, seq, lanes, wl.n_lanes),
        },
    }


def _commit_steps_model(impl: str, k: int) -> int:
    if impl == "scan":
        return k                                  # one scan step per txn
    return int(math.ceil(math.log2(max(k, 2)))) + 3   # matrix + reduce +
    #                                         assoc-scan depth + scatter


def _read_phase_slots(impl: str, trace, wl: W.Workload) -> int:
    """Read-phase device-work model: instruction slots actually walked by
    the round loop's speculative executions.  For the compact cascade this
    is the WALKED width (C·L per round — it scales with the live set, not
    K); the masked loops report the live-slot model (TPU-relevant: dead
    lanes are inert but still walked)."""
    total = int(np.asarray(wl.batch.n_ins).sum())
    if impl == "scan":
        return int(trace.rounds) * total   # legacy run_all every round
    if impl == "compact":
        return int(trace.walked_slots)     # C·L per round, C from ladder
    return int(trace.live_slots)           # rebuild: rounds*total; incr: live


def _row(engine, wl, impl, secs, trace, *, slot=1, axis="k_x_contention",
         **extra):
    k = wl.batch.n_txns
    lc = trace.live_counts()
    return dict(
        engine=engine, k=k, impl=impl, axis=axis,
        L=wl.batch.max_ins, slot=slot, n_lanes=wl.n_lanes,
        seconds=round(secs, 6), txns_per_sec=round(k / secs, 1),
        rounds=int(trace.rounds),
        commit_steps_per_round=_commit_steps_model(impl, k),
        read_phase_slots=_read_phase_slots(impl, trace, wl),
        walked_slots=int(trace.walked_slots),
        live_txns=int(trace.live_txns),
        wave_trips=int(trace.wave_trips),
        live_per_round=[int(x) for x in lc[:64]],
        live_per_round_truncated=bool(len(lc) > 64),
        **extra)


def _assert_equal(engine, k, cont, out_old, t_old, out_new, t_new, pair):
    fp_old, fp_new = int(fingerprint(out_old)), int(fingerprint(out_new))
    assert fp_old == fp_new, (
        f"{engine} K={k} {cont}: {pair[1]} fingerprint {fp_new:#x} diverged "
        f"from {pair[0]} {fp_old:#x}")
    for field in ("commit_pos", "retries"):
        a = np.asarray(getattr(t_old, field))
        b = np.asarray(getattr(t_new, field))
        assert np.array_equal(a, b), (
            f"{engine} K={k} {cont}: trace field {field!r} diverged "
            f"({pair[0]} vs {pair[1]})")


def _bench_grid(wl, cont, iters, results, *, impls, slot=1, axis):
    store, runners = _runners(wl, slot=slot)
    k = wl.batch.n_txns
    for engine, all_impls in runners.items():
        row_traces = {}
        for impl in impls:
            fn = all_impls[impl]
            secs = timeit(fn, warmup=2, iters=iters)
            out, trace = fn()
            row_traces[impl] = (out, trace)
            results.append(_row(engine, wl, impl, secs, trace, slot=slot,
                                axis=axis, contention=cont))
            print(f"{engine:6s} K={k:<5d} {cont:4s} L={wl.batch.max_ins:<3d} "
                  f"S={slot} lanes={wl.n_lanes:<3d} {impl:11s} "
                  f"{secs * 1e3:9.2f} ms  {k / secs:12.1f} txn/s  "
                  f"rounds={int(trace.rounds)} "
                  f"read_slots={_read_phase_slots(impl, trace, wl)}")
        base = impls[0]
        for impl in impls[1:]:
            _assert_equal(engine, k, cont, *row_traces[base],
                          *row_traces[impl], pair=(base, impl))


def run_bench(ks, contentions, iters: int) -> dict:
    results = []
    # primary grid: K × contention, all four implementations
    for k in ks:
        for cont in contentions:
            _bench_grid(_workload(k, cont), cont, iters, results,
                        impls=("scan", "rebuild", "incremental", "compact"),
                        axis="k_x_contention")
    # axis sweeps at fixed K: slot width, txn length L, lane count
    # (new-pipeline impls only; the scan baseline is covered above)
    k = 256
    for slot in (4,):
        _bench_grid(_workload(k, "low"), "low", iters, results,
                    impls=("rebuild", "incremental", "compact"), slot=slot,
                    axis="slot_width")
    for n_rw in (8,):
        _bench_grid(_workload(k, "low", n_reads=n_rw, n_writes=n_rw),
                    "low", iters, results,
                    impls=("rebuild", "incremental", "compact"),
                    axis="txn_length")
    for n_lanes in (2, 32):
        _bench_grid(_workload(k, "med", n_lanes=n_lanes), "med", iters,
                    results, impls=("rebuild", "incremental", "compact"),
                    axis="lane_count")
    live_fraction_sweep(iters, results)
    ragged_stream_bench(results)
    shard_sweep(iters, results)
    ingress_bench(iters, results)
    pipeline_bench(iters, results)
    destm_wave_bench(iters, results)
    return dict(results=results)


# ------------------------------------------------- PR 4 bench sections
def live_fraction_sweep(iters: int, results: list, k: int = 512,
                        fractions=(64, 8, 2, 1)) -> None:
    """Read-phase primitive at controlled sparsity: masked ``run_live``
    vs gather-compacted ``run_live_compact`` with live/K in
    {1/64, 1/8, 1/2, 1}.  The compact width C is next_pow2(live count) —
    the rung such a live set would run at.  Results asserted bitwise
    equal; the compacted executor must beat the masked one at
    live/K <= 1/8 (it walks C·L slots instead of K·L)."""
    from repro.core.txn import next_pow2, run_live, run_live_compact

    wl = _workload(k, "low", seed=17)
    store = make_store(wl.n_objects)
    cache = jax.block_until_ready(
        jax.jit(lambda b, v: run_live(b, v, jnp.ones((k,), bool)))(
            wl.batch, store.values))
    masked_fn = jax.jit(run_live)
    rng = np.random.default_rng(23)
    rows = {}
    for denom in fractions:
        n_live = max(1, k // denom)
        live = np.zeros(k, bool)
        live[rng.choice(k, n_live, replace=False)] = True
        live = jnp.asarray(live)
        width = next_pow2(n_live)
        compact_fn = jax.jit(functools.partial(run_live_compact,
                                               width=width))
        t_masked = timeit(lambda: masked_fn(wl.batch, store.values, live,
                                            cache), warmup=2, iters=iters)
        t_compact = timeit(lambda: compact_fn(wl.batch, store.values, live,
                                              cache), warmup=2, iters=iters)
        ref = masked_fn(wl.batch, store.values, live, cache)
        got = compact_fn(wl.batch, store.values, live, cache)[0]
        for f in ("raddrs", "rn", "waddrs", "wvals", "wn"):
            assert np.array_equal(np.asarray(getattr(ref, f)),
                                  np.asarray(getattr(got, f))), (
                f"live-fraction sweep: run_live_compact diverged on {f} "
                f"at live/K=1/{denom}")
        length = wl.batch.max_ins
        for impl, secs, walked in (("masked", t_masked, k * length),
                                   ("compact", t_compact, width * length)):
            rows[(impl, denom)] = secs
            results.append(dict(
                engine="run_live", k=k, impl=impl, axis="live_fraction",
                L=length, slot=1, n_lanes=wl.n_lanes,
                contention="low", live_fraction=f"1/{denom}",
                n_live=n_live, compact_width=(width if impl == "compact"
                                              else k),
                seconds=round(secs, 6),
                txns_per_sec=round(k / secs, 1),
                read_phase_slots=walked, walked_slots=walked))
            print(f"run_live K={k} live=1/{denom:<3d} {impl:8s} "
                  f"{secs * 1e6:9.1f} us  walked_slots={walked}")
    for denom in fractions:
        if denom >= 8:
            assert rows[("compact", denom)] < rows[("masked", denom)], (
                f"compacted read phase slower than masked at live/K=1/"
                f"{denom}: {rows[('compact', denom)]:.6f}s vs "
                f"{rows[('masked', denom)]:.6f}s")


def ragged_stream_bench(results: list, n_shapes: int = 32) -> None:
    """Streaming compile-count benchmark: one N-shape ragged stream per
    engine through PotSession, bucketed vs exact shapes.  Bucketed
    streaming must compile at most ladder-size steps; outcomes are
    asserted bitwise identical."""
    from repro.core import PotSession

    rng = np.random.default_rng(31)
    batches, lanes = [], []
    for i in range(n_shapes):
        kk = int(rng.integers(1, 129))
        wl = W.counters(n_txns=kk, n_objects=256, n_reads=2, n_writes=2,
                        n_lanes=min(4, kk), skew=0.5, seed=1000 + i)
        batches.append(wl.batch)
        lanes.append(wl.lanes.tolist())
    for engine in ("pcc", "occ", "destm"):
        stats = {}
        for mode, bucket in (("bucketed", True), ("exact", False)):
            t0 = time.perf_counter()
            s = PotSession(256, engine=engine, n_lanes=4, bucket=bucket)
            s.run_stream(batches, lanes)
            jax.block_until_ready(s.store.values)
            secs = time.perf_counter() - t0
            stats[mode] = (s, secs)
            results.append(dict(
                engine=engine, impl=mode, axis="ragged_stream",
                n_shapes=n_shapes,
                distinct_shapes=len({(b.n_txns, b.max_ins)
                                     for b in batches}),
                compile_count=s.compile_count(),
                bucket_counts={str(kk): v
                               for kk, v in sorted(s.bucket_counts().items())},
                seconds=round(secs, 6)))
            print(f"{engine:6s} ragged x{n_shapes} {mode:9s} "
                  f"compiles={s.compile_count():<3d} {secs:8.2f} s")
        sb, se = stats["bucketed"][0], stats["exact"][0]
        assert sb.fingerprint() == se.fingerprint(), engine
        assert sb.replay_log() == se.replay_log(), engine
        # bucket ladder over K in [1, 128] has 8 pow2 rungs — the compile
        # count must stay within it no matter how ragged the stream is
        assert sb.compile_count() <= 8, (engine, sb.compile_count())


def shard_sweep(iters: int, results: list, k: int = 256,
                shard_counts=(1, 4, 8)) -> None:
    """PR 5 shards axis: every engine's compact cascade on a store
    partitioned into S contiguous range shards, asserted bit-identical
    to the dense S=1 run, plus per-shard write-back timing of the
    ``fused_write_back`` primitive on one full committing round (the
    stage that splits into S independent scatters — one per device
    under a mesh)."""
    from repro.core import protocol
    from repro.core.txn import run_all

    for cont in ("low", "med"):
        wl = _workload(k, cont, seed=29)
        seq = _seq_for(wl)
        arrival = jnp.argsort(seq)
        lanes = jnp.asarray(wl.lanes, jnp.int32)
        # shard-invariant write-back operands: one full committing round
        res = run_all(wl.batch, make_store(wl.n_objects).values)
        rank = jnp.arange(k, dtype=jnp.int32)
        committing = jnp.ones((k,), bool)
        baseline = {}
        for shards in shard_counts:
            store = make_store(wl.n_objects, shards=shards)
            runners = {
                "pcc": lambda: pcc_execute(store, wl.batch, seq),
                "occ": lambda: occ_execute(store, wl.batch, arrival),
                "destm": lambda: destm_execute(store, wl.batch, seq,
                                               lanes, wl.n_lanes),
            }
            for engine, fn in runners.items():
                secs = timeit(fn, warmup=2, iters=iters)
                out, trace = fn()
                if shards == 1:
                    baseline[engine] = (out, trace)
                else:
                    _assert_equal(engine, k, cont, *baseline[engine],
                                  out, trace, pair=("s1", f"s{shards}"))
                results.append(_row(engine, wl, "compact", secs, trace,
                                    axis="shards", contention=cont,
                                    shards=shards))
                print(f"{engine:6s} K={k:<5d} {cont:4s} S={shards} "
                      f"compact     {secs * 1e3:9.2f} ms  "
                      f"{k / secs:12.1f} txn/s")
            # write-back primitive at this S
            layout = store.layout
            wb = jax.jit(lambda v, ver: protocol.fused_write_back(
                v, ver, res.waddrs, res.wvals, res.wn, committing, rank,
                rank + 1, layout))
            secs = timeit(lambda: wb(store.values, store.versions),
                          warmup=2, iters=iters)
            results.append(dict(
                engine="fused_write_back", k=k, impl=f"s{shards}",
                axis="shards", L=wl.batch.max_ins, slot=1,
                n_lanes=wl.n_lanes, contention=cont, shards=shards,
                seconds=round(secs, 6),
                writes_per_sec=round(float(res.wn.sum()) / secs, 1)))
            print(f"write_back K={k} {cont:4s} S={shards}  "
                  f"{secs * 1e6:9.1f} us")


def _fill_pool(wl, fees, **pool_kwargs):
    """Admit a workload's transactions (with per-txn fees) into a fresh
    IngressPool — the arrival side of the PR 6 ingress axis."""
    from repro.core import IngressPool
    from repro.core.ingress import programs_from_batch

    pool = IngressPool(**pool_kwargs)
    for p, lane, fee in zip(programs_from_batch(wl.batch),
                            wl.lanes.tolist(), fees):
        pool.admit(p, lane=lane, fee=int(fee))
    return pool


def ingress_bench(iters: int, results: list, k: int = 256,
                  budget: int = 24) -> None:
    """PR 6 ingress axis: (a) the full serve loop — journal-fed
    admission + priority drain + batch forming + execution — against
    direct submission of the same pre-formed batches (the delta is the
    deterministic host-side ingress overhead) and against the drain-only
    former (its raw throughput); (b) the occupancy-driven bucket-ladder
    auto-selection against a pinned pow2 ladder on a mid-size drain
    tail: compile counts and padding waste, fingerprints asserted
    bit-identical (padding is vacant rows — the choice can never change
    committed state)."""
    from repro.core import IngressPool, PotSession

    wl = _workload(k, "low", seed=37)
    rng = np.random.default_rng(41)
    src = _fill_pool(wl, rng.integers(0, 8, k).tolist(),
                     capacity=4 * k)
    arrivals = src.arrival_journal()
    twin, _ = IngressPool.replay(arrivals)
    formed = twin.drain_all(budget)

    session = PotSession(wl.n_objects, engine="pcc", n_lanes=wl.n_lanes)

    def serve_path():
        pool, _ = IngressPool.replay(arrivals)
        return session.serve(pool, budget=budget)

    def direct_path():
        return [session._submit_seq(fb.batch, fb.seq, fb.lanes,
                                    ladder=fb.ladder) for fb in formed]

    def drain_only():
        pool, _ = IngressPool.replay(arrivals)
        return pool.drain_all(budget)

    direct_path()   # warm the step compile cache for both paths
    timings = {
        "serve": timeit(lambda: jax.block_until_ready(
            serve_path()[-1].commit_pos), warmup=1, iters=iters),
        "direct": timeit(lambda: jax.block_until_ready(
            direct_path()[-1].commit_pos), warmup=1, iters=iters),
        "drain_only": timeit(drain_only, warmup=1, iters=iters),
    }
    for impl, secs in timings.items():
        results.append(dict(
            engine="ingress", k=k, impl=impl, axis="ingress",
            L=wl.batch.max_ins, slot=1, n_lanes=wl.n_lanes,
            contention="low", budget=budget, n_batches=len(formed),
            seconds=round(secs, 6), txns_per_sec=round(k / secs, 1)))
        print(f"ingress K={k:<5d} budget={budget} {impl:11s} "
              f"{secs * 1e3:9.2f} ms  {k / secs:12.1f} txn/s")

    # (b) occupancy-driven ladder auto-selection vs pinned pow2
    fps = {}
    for mode, pin in (("auto", None), ("pow2", "pow2")):
        s = PotSession(wl.n_objects, engine="pcc", n_lanes=wl.n_lanes)
        pool, _ = IngressPool.replay(arrivals)
        s.serve(pool, budget=budget, ladder=pin)
        waste = sum(bk * c for (bk, _), c in
                    s.bucket_counts().items()) - k
        fps[mode] = s.fingerprint()
        results.append(dict(
            engine="ingress", k=k, impl=f"ladder_{mode}", axis="ingress",
            L=wl.batch.max_ins, slot=1, n_lanes=wl.n_lanes,
            contention="low", budget=budget,
            compile_count=s.compile_count(), padding_waste_rows=waste,
            bucket_counts={str(kk): v for kk, v in
                           sorted(s.bucket_counts().items())}))
        print(f"ingress K={k:<5d} budget={budget} ladder={mode:5s} "
              f"compiles={s.compile_count()} padding_waste={waste}")
    assert fps["auto"] == fps["pow2"], (
        "bucket-ladder choice changed committed state")


def _pipeline_stream(k: int, cont: str, n_batches: int = 8,
                     seed: int = 43):
    """A stream of same-contention batches sharing one hot set — the
    regime where cross-batch validation actually has conflicts to
    find.  Every batch gets the SAME n_objects (one store) but a
    distinct seed, so consecutive batches collide on the skewed head
    of the address space at med contention and are near-disjoint at
    low."""
    wls = [_workload(k, cont, seed=seed + i) for i in range(n_batches)]
    return (wls[0].n_objects, wls[0].n_lanes,
            [w.batch for w in wls], [w.lanes for w in wls])


def pipeline_bench(iters: int, results: list, ks=(64, 256),
                   depths=(1, 2)) -> None:
    """PR 7 pipeline axis: serial (D=0) vs speculative pipeline depth
    D in {1, 2} stream throughput for the seeded engines (pcc, occ),
    K × contention.  Every pipelined stream is asserted bit-identical
    to the serial one — fingerprints, replay logs, full traces — so
    the rows measure the cost/benefit of speculation, never a
    semantics change.  Rows carry the speculation observables: rows
    executed against the pre-state snapshot, rows invalidated by the
    cross-batch read-set check, and re-execution passes."""
    from repro.core import PotSession

    for k in ks:
        for cont in ("low", "med"):
            n_obj, n_lanes, batches, lanes = _pipeline_stream(k, cont)
            total = k * len(batches)
            base = {}
            for engine in ("pcc", "occ"):
                for depth in (0,) + tuple(depths):
                    def stream():
                        s = PotSession(n_obj, engine=engine,
                                       n_lanes=n_lanes,
                                       pipeline_depth=depth)
                        ts = s.run_stream(batches, lanes)
                        jax.block_until_ready(s.store.values)
                        return s, ts
                    secs = timeit(lambda: stream(), warmup=1,
                                  iters=iters)
                    s, traces = stream()
                    if depth == 0:
                        base[engine] = s
                    else:
                        sb = base[engine]
                        assert s.fingerprint() == sb.fingerprint(), (
                            f"pipeline {engine} K={k} {cont} D={depth}: "
                            "fingerprint diverged from serial")
                        assert s.replay_log() == sb.replay_log(), (
                            f"pipeline {engine} K={k} {cont} D={depth}: "
                            "replay log diverged from serial")
                    spec_exec = sum(int(t.spec_executed) for t in traces)
                    spec_inv = sum(int(t.spec_invalidated)
                                   for t in traces)
                    spec_rounds = sum(int(t.spec_rounds) for t in traces)
                    results.append(dict(
                        engine=engine, k=k, impl=f"depth{depth}",
                        axis="pipeline", L=batches[0].max_ins, slot=1,
                        n_lanes=n_lanes, contention=cont,
                        pipeline_depth=depth, n_batches=len(batches),
                        seconds=round(secs, 6),
                        txns_per_sec=round(total / secs, 1),
                        spec_executed=spec_exec,
                        spec_invalidated=spec_inv,
                        spec_rounds=spec_rounds))
                    print(f"{engine:6s} K={k:<5d} {cont:4s} pipeline "
                          f"D={depth}  {secs * 1e3:9.2f} ms  "
                          f"{total / secs:12.1f} txn/s  "
                          f"spec={spec_exec}/inv={spec_inv}")


def _assert_wave_equal(tag, out_s, t_s, out_w, t_w):
    """wave == serial-token-walk, bitwise, on everything but the wave
    observables (retry_waves / waves_per_round — the whole win)."""
    import dataclasses

    from repro.core.engine import ExecTrace
    assert int(fingerprint(out_s)) == int(fingerprint(out_w)), (
        f"{tag}: wave fingerprint diverged from serial walk")
    for f in dataclasses.fields(ExecTrace):
        if f.name in ("retry_waves", "waves_per_round"):
            continue
        assert np.array_equal(np.asarray(getattr(t_s, f.name)),
                              np.asarray(getattr(t_w, f.name))), (
            f"{tag}: trace field {f.name!r} diverged")
    events, waves = int(t_s.retry_waves), int(t_w.retry_waves)
    assert events == int(np.asarray(t_s.retries).sum()), tag
    assert waves <= events, f"{tag}: waves {waves} > events {events}"
    return events, waves


def _blind_ww_workload(k: int, n_lanes: int, width: int = 2) -> W.Workload:
    """Blind write-write contention: lane groups of ``width`` write the
    same object, no reads.  Every round is ``n_lanes/width`` independent
    WW conflicts — the serial walk pays one retry event per conflict,
    one wave clears them all (empty read sets are trivially
    serial-valid), so this is the wave mode's best case."""
    from repro.core import WRITE, make_batch
    progs = [[(WRITE, (i % n_lanes) // width, False, i + 1),
              (WRITE, n_lanes + i % n_lanes, False, i)]
             for i in range(k)]
    lanes = np.asarray([i % n_lanes for i in range(k)], np.int32)
    return W.Workload(name="blind_ww", batch=make_batch(progs),
                      lanes=lanes, n_lanes=n_lanes,
                      n_objects=2 * n_lanes + 8)


def destm_wave_bench(iters: int, results: list, ks=(64, 256),
                     lane_counts=(8, 32)) -> None:
    """PR 10 destm_wave axis: the serial token walk (one retry EVENT per
    while_loop trip — the frozen-oracle port) vs wave-speculative
    retries (all of a trip's conflicting members re-execute at once and
    the maximal provably-serial token prefix commits), K × contention ×
    lane count.  Every pair is asserted bitwise identical — store
    fingerprints and every trace field except the wave observables — so
    the rows measure pure retry-loop mechanics: ``retry_events`` (=
    serial trips = Σ retries), ``retry_waves`` (wave trips) and their
    reduction.  The ``blind_ww`` rows are the wave's best case (pure
    write-write conflicts, whole wave commits in one trip); the skewed
    counters rows show the realistic middle; fully serial RMW chains
    show no reduction by design (waves == events there)."""
    wave_wls = []
    for k in ks:
        for cont in ("low", "med"):
            for n_lanes in lane_counts:
                wave_wls.append((cont, _workload(k, cont, seed=31,
                                                 n_lanes=n_lanes)))
    for k in ks:
        wave_wls.append(("ww", _blind_ww_workload(k, n_lanes=16)))
    for cont, wl in wave_wls:
        k = wl.batch.n_txns
        store = make_store(wl.n_objects)
        seq = _seq_for(wl)
        lanes = jnp.asarray(wl.lanes, jnp.int32)
        fns = {
            "serial_walk": lambda: destm_execute(
                store, wl.batch, seq, lanes, wl.n_lanes, wave=False),
            "wave": lambda: destm_execute(
                store, wl.batch, seq, lanes, wl.n_lanes),
        }
        outs = {impl: fn() for impl, fn in fns.items()}
        events, waves = _assert_wave_equal(
            f"destm_wave {wl.name} K={k} {cont} lanes={wl.n_lanes}",
            *outs["serial_walk"], *outs["wave"])
        for impl, fn in fns.items():
            secs = timeit(fn, warmup=2, iters=iters)
            _, trace = outs[impl]
            results.append(dict(
                engine="destm", k=k, impl=impl, axis="destm_wave",
                L=wl.batch.max_ins, slot=1, n_lanes=wl.n_lanes,
                contention=cont, seconds=round(secs, 6),
                txns_per_sec=round(k / secs, 1),
                rounds=int(trace.rounds),
                retries=int(np.asarray(trace.retries).sum()),
                retry_events=events,
                retry_waves=int(trace.retry_waves),
                wave_reduction=events - waves,
                waves_per_round=[int(x) for x in trace.wave_counts()[:64]]))
            print(f"destm  K={k:<5d} {cont:4s} lanes={wl.n_lanes:<3d} "
                  f"{impl:11s} {secs * 1e3:9.2f} ms  "
                  f"{k / secs:12.1f} txn/s  rounds={int(trace.rounds)} "
                  f"events={events} waves={waves}")


def summarize(results) -> dict:
    speedups = {}
    for row in results:
        if row["impl"] != "compact" or row["axis"] == "live_fraction":
            continue
        for base in ("scan", "rebuild", "incremental"):
            old = next(
                (r for r in results
                 if r["impl"] == base and r["engine"] == row["engine"]
                 and r["k"] == row["k"] and r["axis"] == row["axis"]
                 and r["contention"] == row["contention"]
                 and r["L"] == row["L"] and r["slot"] == row["slot"]
                 and r["n_lanes"] == row["n_lanes"]), None)
            if old is None:
                continue
            key = f'{row["engine"]}/K{row["k"]}/{row["contention"]}'
            if row["axis"] != "k_x_contention":
                # sweep rows: disambiguate by the swept coordinate
                key += (f'/{row["axis"]}/L{row["L"]}S{row["slot"]}'
                        f'lanes{row["n_lanes"]}')
            key += f"/{base}_to_compact"
            speedups[key] = dict(
                time=round(old["seconds"] / row["seconds"], 2),
                read_phase_slots=round(
                    old["read_phase_slots"]
                    / max(row["read_phase_slots"], 1), 2))
    return speedups


# ------------------------------------------------------------- smoke gates
def _kernel_smoke() -> str:
    """Exercise the conflict-kernel delta path (interpret mode — the TPU
    kernel's reference semantics) with a PARTIAL live mask, so both the
    recompute branch and the stale-tile carry branch run.  Only kernel
    construction/lowering sits inside the try: CPU-only CI must run the
    smoke stage even where the Pallas kernel path is unavailable, but a
    kernel that lowers and answers WRONG must still fail the gate."""
    from repro.kernels import conflict as C
    from repro.kernels import ref
    rng = np.random.default_rng(0)
    k, w = max(C.BI, C.BJ), C.BW
    mk = lambda d: jnp.asarray((rng.random((k, w)) < d) *
                               rng.integers(0, 2**31, (k, w)), jnp.int32)
    old_write = mk(0.05)
    old_foot = mk(0.2) | old_write
    live = jnp.asarray(rng.random(k) < 0.3, jnp.int32)
    keep = live[:, None].astype(bool)
    new_write = jnp.where(keep, mk(0.05), old_write)
    new_foot = jnp.where(keep, mk(0.2) | new_write, old_foot)
    try:
        old = C.conflict_matrix_bits(old_foot, old_write, interpret=True)
        delta = C.conflict_matrix_bits_delta(
            new_foot, new_write, old.astype(jnp.int32), live,
            interpret=True)
        delta = np.asarray(delta)
    except Exception as e:  # pragma: no cover - depends on jax build
        return (f"SKIP conflict-kernel check: TPU kernel path unavailable "
                f"({type(e).__name__}: {e})")
    lv = np.asarray(live).astype(bool)
    exp = np.where(lv[:, None] | lv[None, :],
                   np.asarray(ref.conflict_matrix_bits_ref(
                       new_foot, new_write)),
                   np.asarray(old))
    assert np.array_equal(delta != 0, exp), (
        "conflict-kernel delta diverged from the pure-jnp reference")
    return "conflict-kernel delta path OK (interpret mode, partial live)"


def run_smoke() -> None:
    """Equivalence gate: every engine, all four implementations, must
    agree bitwise."""
    for k in (2, 8):
        for cont in ("low", "med"):
            wl = _workload(k, cont, seed=k)
            _, runners = _runners(wl)
            for engine, impls in runners.items():
                outs = {name: fn() for name, fn in impls.items()}
                for name in ("rebuild", "incremental", "compact"):
                    _assert_equal(engine, k, cont, *outs["scan"],
                                  *outs[name], pair=("scan", name))
    print("bench-smoke OK: scan, rebuild, incremental and compact agree "
          "bitwise (engines: pcc, occ, destm; K in {2, 8}; low/med "
          "contention)")
    print(_kernel_smoke())


def run_incremental_smoke() -> None:
    """CI gate: the RoundState incremental loop == the from-scratch
    rebuild, on store fingerprints and traces, across all engines."""
    for k in (2, 8, 64):
        for cont in ("low", "med"):
            wl = _workload(k, cont, seed=3 * k + 1)
            _, runners = _runners(wl)
            for engine, impls in runners.items():
                out_reb, t_reb = impls["rebuild"]()
                out_inc, t_inc = impls["incremental"]()
                _assert_equal(engine, k, cont, out_reb, t_reb,
                              out_inc, t_inc, pair=("rebuild", "incremental"))
                assert int(t_inc.live_txns) <= int(t_reb.live_txns), (
                    engine, k, cont)
    print("incremental-smoke OK: RoundState loop == per-round rebuild "
          "(engines: pcc, occ, destm; K in {2, 8, 64}; low/med contention)")


def run_compact_smoke() -> None:
    """CI gate (scripts/ci.sh --compact-smoke): the gather-compacted
    cascade == the masked incremental loop == the from-scratch rebuild,
    on store fingerprints and traces, across all engines — and the
    compact read-phase primitive == the masked one on partial live
    sets (including sizes 0 and 1)."""
    from repro.core.txn import next_pow2, run_all, run_live, run_live_compact

    for k in (2, 8, 64):
        for cont in ("low", "med"):
            wl = _workload(k, cont, seed=7 * k + 5)
            _, runners = _runners(wl)
            for engine, impls in runners.items():
                out_reb, t_reb = impls["rebuild"]()
                out_inc, t_inc = impls["incremental"]()
                out_cpt, t_cpt = impls["compact"]()
                _assert_equal(engine, k, cont, out_inc, t_inc,
                              out_cpt, t_cpt, pair=("incremental",
                                                    "compact"))
                _assert_equal(engine, k, cont, out_reb, t_reb,
                              out_cpt, t_cpt, pair=("rebuild", "compact"))
                assert int(t_cpt.walked_slots) <= int(t_inc.walked_slots), (
                    engine, k, cont)
    # primitive: gather-execute-scatter == masked, sparse live sets
    wl = _workload(64, "low", seed=2)
    store = make_store(wl.n_objects)
    cache = run_all(wl.batch, store.values)
    rng = np.random.default_rng(9)
    for n_live in (0, 1, 5, 64):
        live = np.zeros(64, bool)
        live[rng.choice(64, n_live, replace=False)] = True
        live = jnp.asarray(live)
        ref = run_live(wl.batch, store.values, live, cache)
        got = run_live_compact(wl.batch, store.values, live, cache,
                               max(1, next_pow2(n_live)))[0]
        for f in ("raddrs", "rn", "waddrs", "wvals", "wn"):
            assert np.array_equal(np.asarray(getattr(ref, f)),
                                  np.asarray(getattr(got, f))), (n_live, f)
    print("compact-smoke OK: compact == masked == rebuild (engines: pcc, "
          "occ, destm; K in {2, 8, 64}; low/med contention) and "
          "run_live_compact == run_live (live in {0, 1, 5, 64})")


def run_shard_smoke() -> None:
    """CI gate (scripts/ci.sh --shard-smoke): the sharded store ==
    the dense store, bit for bit, across engines and both code paths —
    store fingerprints, commit positions and retries at S in {1, 2, 8},
    K in {2, 8, 64}, low/med contention, compact cascade AND masked
    loop.  When the host exposes >= 2 devices (the CI stage sets
    XLA_FLAGS=--xla_force_host_platform_device_count=8), the per-shard
    write-back additionally runs one-shard-per-device under
    jax.shard_map on a real mesh and must stay bit-identical."""
    from repro.core import shard_store

    for k in (2, 8, 64):
        for cont in ("low", "med"):
            wl = _workload(k, cont, seed=11 * k + 3)
            seq = _seq_for(wl)
            arrival = jnp.argsort(seq)
            lanes = jnp.asarray(wl.lanes, jnp.int32)
            dense = make_store(wl.n_objects)
            runners = lambda store: {
                "pcc": {
                    "compact": lambda: pcc_execute(store, wl.batch, seq),
                    "masked": lambda: pcc_execute(store, wl.batch, seq,
                                                  compact=False),
                },
                "occ": {
                    "compact": lambda: occ_execute(store, wl.batch,
                                                   arrival),
                    "masked": lambda: occ_execute(store, wl.batch,
                                                  arrival, compact=False),
                },
                "destm": {
                    "compact": lambda: destm_execute(
                        store, wl.batch, seq, lanes, wl.n_lanes),
                    "masked": lambda: destm_execute(
                        store, wl.batch, seq, lanes, wl.n_lanes,
                        compact=False),
                },
            }
            base = {(e, i): fn() for e, impls in runners(dense).items()
                    for i, fn in impls.items()}
            for shards in (2, 8):
                sharded = runners(shard_store(dense, shards))
                for engine, impls in sharded.items():
                    for impl, fn in impls.items():
                        _assert_equal(engine, k, cont,
                                      *base[(engine, impl)], *fn(),
                                      pair=(f"dense/{impl}",
                                            f"s{shards}/{impl}"))
    n_dev = len(jax.devices())
    if n_dev >= 2:
        s = min(8, n_dev)
        mesh = jax.make_mesh((s,), ("shard",), devices=jax.devices()[:s])
        wl = _workload(32, "med", seed=19)
        seq = _seq_for(wl)
        dense = make_store(wl.n_objects)
        out_d, tr_d = pcc_execute(dense, wl.batch, seq)
        out_m, tr_m = pcc_execute(shard_store(dense, s, mesh=mesh),
                                  wl.batch, seq)
        _assert_equal("pcc", 32, "med", out_d, tr_d, out_m, tr_m,
                      pair=("dense", f"mesh_s{s}"))
        mesh_msg = (f"shard_map write-back validated on a {s}-device "
                    f"mesh")
    else:
        mesh_msg = ("single-device host: shard_map mesh path SKIPPED "
                    "(run under XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
    print("shard-smoke OK: sharded == dense (engines: pcc, occ, destm; "
          "S in {2, 8}; K in {2, 8, 64}; low/med contention; compact + "
          f"masked paths); {mesh_msg}")


def run_ingress_smoke() -> None:
    """CI gate (scripts/ci.sh --ingress-smoke): two IngressPool replicas
    fed the same arrival journal, drained under DIFFERENT budget
    schedules covering the same prefix, must produce bit-identical batch
    streams, store fingerprints and replay logs through PotSession — and
    a full journal replay must reproduce the exact FormedBatch stream
    (sequence numbers, txn ids, ladder choices)."""
    from repro.core import IngressPool, PotSession

    wl = _workload(48, "med", seed=13)
    rng = np.random.default_rng(7)
    src = _fill_pool(wl, rng.integers(0, 9, 48).tolist(), capacity=64)
    arrivals = src.arrival_journal()
    outs = []
    for budgets in ([48], [5, 9, 3, 31], [7] * 7):
        pool, _ = IngressPool.replay(arrivals)
        s = PotSession(wl.n_objects, engine="pcc", n_lanes=wl.n_lanes)
        for b in budgets:
            fb = pool.drain(b)
            if fb is None:
                break
            s._submit_seq(fb.batch, fb.seq, fb.lanes, ladder=fb.ladder)
        assert pool.depth == 0, "drain schedule left txns behind"
        outs.append((s.fingerprint(), s.replay_log()))
    assert outs[0] == outs[1] == outs[2], (
        "ingress replicas diverged across drain budget schedules")
    # journal replay reproduces the formed stream bit-exactly
    pool, _ = IngressPool.replay(arrivals)
    formed = pool.drain_all(11)
    _, replayed = IngressPool.replay(pool.journal())
    assert len(replayed) == len(formed)
    for a, b in zip(formed, replayed):
        assert np.array_equal(a.txn_ids, b.txn_ids), "txn_ids diverged"
        assert np.array_equal(a.seq, b.seq), "seq diverged"
        assert np.array_equal(a.lanes, b.lanes), "lanes diverged"
        assert a.ladder == b.ladder, "ladder choice diverged"
    print("ingress-smoke OK: replicas on one arrival journal agree "
          "bitwise across drain schedules ([48], [5,9,3,31], [7]*7) — "
          "fingerprints + replay logs — and journal replay reproduces "
          f"the {len(formed)}-batch formed stream exactly")


def run_pipeline_smoke() -> None:
    """CI gate (scripts/ci.sh --pipeline-smoke): one ingress arrival
    journal replayed through a serial session and pipelined sessions
    (D in {1, 2}) for both seeded engines, under different drain budget
    schedules, must agree bitwise — store fingerprints, replay logs,
    and every pre-existing ExecTrace field (the speculation cost may
    only surface in the new spec_* observables, which must be zero on
    the serial run).  Also covers the ragged direct-stream path and the
    blocked OCC wave solve (wave_trips must drop, decisions must not
    change)."""
    import dataclasses

    from repro.core import IngressPool, PotSession, occ_execute
    from repro.core.engine import ExecTrace

    wl = _workload(48, "med", seed=13)
    rng = np.random.default_rng(7)
    arrivals = _fill_pool(wl, rng.integers(0, 9, 48).tolist(),
                          capacity=64).arrival_journal()
    for engine in ("pcc", "occ"):
        per_budget = []
        for budget in (48, 13, 7):   # three drain partitions
            runs = {}
            for depth in (0, 1, 2):
                pool, _ = IngressPool.replay(arrivals)
                s = PotSession(wl.n_objects, engine=engine,
                               n_lanes=wl.n_lanes, pipeline_depth=depth)
                ts = s.serve(pool, budget=budget)
                assert pool.depth == 0, "serve left txns behind"
                runs[depth] = (s, ts)
            s0, t0 = runs[0]
            for depth in (1, 2):
                s, ts = runs[depth]
                assert s.fingerprint() == s0.fingerprint(), (
                    f"pipeline-smoke {engine} budget={budget} "
                    f"D={depth}: fingerprint diverged from serial")
                assert s.replay_log() == s0.replay_log(), (
                    f"pipeline-smoke {engine} budget={budget} "
                    f"D={depth}: replay log diverged from serial")
                assert len(ts) == len(t0)
                for i, (a, b) in enumerate(zip(t0, ts)):
                    for f in dataclasses.fields(ExecTrace):
                        if f.name.startswith("spec_"):
                            assert int(np.asarray(
                                getattr(a, f.name)).sum()) == 0, (
                                f"serial run charged {f.name}")
                            continue
                        assert np.array_equal(
                            np.asarray(getattr(a, f.name)),
                            np.asarray(getattr(b, f.name))), (
                            f"pipeline-smoke {engine} budget={budget} "
                            f"D={depth}: trace field {f.name!r} "
                            f"diverged on batch {i}")
            per_budget.append((s0.fingerprint(), s0.replay_log()))
        # Budget-partition invariance holds at any pipeline depth
        # because it holds serially and pipelined == serial above.
        # PCC-only (matching --ingress-smoke): OCC's retry waves are
        # batch-scoped — a conflicting txn re-runs in a later wave of
        # ITS batch — so the baseline's commit order legitimately
        # depends on how the drain prefix is partitioned.
        if engine == "pcc":
            assert per_budget[0] == per_budget[1] == per_budget[2], (
                f"pipeline-smoke {engine}: drain partitions diverged")
    # ragged direct stream (run_stream path) + blocked wave solve
    n_obj, n_lanes, batches, lanes = _pipeline_stream(32, "med",
                                                      n_batches=5)
    s0 = PotSession(n_obj, engine="pcc", n_lanes=n_lanes)
    s0.run_stream(batches, lanes)
    s2 = PotSession(n_obj, engine="pcc", n_lanes=n_lanes,
                    pipeline_depth=2)
    s2.run_stream(batches, lanes)
    assert s0.fingerprint() == s2.fingerprint()
    assert s0.replay_log() == s2.replay_log()
    wlc = _workload(64, "med", seed=23)
    arrival = jnp.argsort(_seq_for(wlc))
    store = make_store(wlc.n_objects)
    out1, tr1 = occ_execute(store, wlc.batch, arrival, wave_block=1)
    out8, tr8 = occ_execute(store, wlc.batch, arrival, wave_block=8)
    _assert_equal("occ", 64, "med", out1, tr1, out8, tr8,
                  pair=("block1", "block8"))
    assert int(tr8.wave_trips) <= int(tr1.wave_trips)
    print("pipeline-smoke OK: pipelined (D in {1, 2}) == serial on one "
          "arrival journal across drain budgets (48, 13, 7) — "
          "fingerprints + replay logs + full traces (engines: pcc, "
          "occ) — and the blocked OCC wave solve is decision-identical "
          f"(trips {int(tr1.wave_trips)} -> {int(tr8.wave_trips)})")


def run_destm_wave_smoke() -> None:
    """CI gate (scripts/ci.sh --destm-wave-smoke): wave-speculative
    DeSTM retries == the serial token walk, bitwise — store
    fingerprints and every trace field except the wave observables —
    across K × contention × lane count, with retry_waves <= retry
    events everywhere and a strict reduction on the blind-WW best
    case."""
    total_events = total_waves = 0
    cases = [(k, cont, n_lanes)
             for k in (16, 48) for cont in ("low", "med")
             for n_lanes in (1, 8)]
    for k, cont, n_lanes in cases:
        wl = _workload(k, cont, seed=41, n_lanes=n_lanes)
        store = make_store(wl.n_objects)
        seq = _seq_for(wl)
        lanes = jnp.asarray(wl.lanes, jnp.int32)
        out_s = destm_execute(store, wl.batch, seq, lanes, wl.n_lanes,
                              wave=False)
        out_w = destm_execute(store, wl.batch, seq, lanes, wl.n_lanes)
        ev, wv = _assert_wave_equal(
            f"destm-wave-smoke K={k} {cont} lanes={wl.n_lanes}",
            *out_s, *out_w)
        total_events += ev
        total_waves += wv
    wl = _blind_ww_workload(48, n_lanes=16)
    store = make_store(wl.n_objects)
    seq = _seq_for(wl)
    lanes = jnp.asarray(wl.lanes, jnp.int32)
    out_s = destm_execute(store, wl.batch, seq, lanes, wl.n_lanes,
                          wave=False)
    out_w = destm_execute(store, wl.batch, seq, lanes, wl.n_lanes)
    ev, wv = _assert_wave_equal("destm-wave-smoke blind_ww",
                                *out_s, *out_w)
    assert wv < ev, (
        f"destm-wave-smoke blind_ww: expected a strict wave reduction, "
        f"got events={ev} waves={wv}")
    total_events += ev
    total_waves += wv
    print(f"destm-wave-smoke OK: wave == serial token walk bitwise "
          f"(stores + traces) across K x contention x lanes; retry "
          f"events {total_events} -> waves {total_waves}")


def run() -> None:
    """benchmarks/run.py entry point: one incremental-vs-rebuild-vs-
    compact row per engine at K=256 low contention, a shards row
    (sharded-vs-dense step time + write-back split), plus a
    ragged-stream compile-count row (CSV: name,us_per_call,derived)."""
    from benchmarks.common import emit
    from repro.core import PotSession
    wl = _workload(256, "low")
    _, runners = _runners(wl)
    for engine, impls in runners.items():
        t_reb = timeit(impls["rebuild"], warmup=1, iters=3)
        t_inc = timeit(impls["incremental"], warmup=1, iters=3)
        t_cpt = timeit(impls["compact"], warmup=1, iters=3)
        _, trace = impls["compact"]()
        emit(f"engine_bench_{engine}_k256_low_compact", t_cpt * 1e6,
             f"rebuild_over_compact={t_reb / t_cpt:.2f}x;"
             f"incremental_over_compact={t_inc / t_cpt:.2f}x;"
             f"live_txns={int(trace.live_txns)};"
             f"walked_slots={int(trace.walked_slots)};"
             f"rounds={int(trace.rounds)}")
    # sharded store: step time at S=8 vs dense (must stay bit-identical;
    # the interesting number on CPU is the overhead of the OR-reduce,
    # on a real mesh the per-device write-back win)
    seq = _seq_for(wl)
    dense = make_store(wl.n_objects)
    sharded = make_store(wl.n_objects, shards=8)
    t_d = timeit(lambda: pcc_execute(dense, wl.batch, seq), warmup=1,
                 iters=3)
    t_s = timeit(lambda: pcc_execute(sharded, wl.batch, seq), warmup=1,
                 iters=3)
    out_d, _ = pcc_execute(dense, wl.batch, seq)
    out_s, _ = pcc_execute(sharded, wl.batch, seq)
    assert int(fingerprint(out_d)) == int(fingerprint(out_s))
    emit("engine_bench_pcc_k256_low_shards8", t_s * 1e6,
         f"dense_over_sharded={t_d / t_s:.2f}x;bitwise_equal=1")
    # ragged-stream compile counts: 8 shapes is enough for a CSV row
    rng = np.random.default_rng(3)
    batches = []
    for i in range(8):
        kk = int(rng.integers(1, 65))
        batches.append(W.counters(n_txns=kk, n_objects=128, n_lanes=1,
                                  skew=0.5, seed=i).batch)
    for mode, bucket in (("bucketed", True), ("exact", False)):
        t0 = time.perf_counter()
        s = PotSession(128, engine="pcc", bucket=bucket)
        s.run_stream(batches)
        jax.block_until_ready(s.store.values)
        emit(f"engine_bench_ragged8_{mode}",
             (time.perf_counter() - t0) * 1e6,
             f"compiles={s.compile_count()}")
    # ingress serve loop vs direct submit of the pre-formed batches
    from repro.core import IngressPool
    wl2 = _workload(128, "low", seed=6)
    rng2 = np.random.default_rng(5)
    arrivals = _fill_pool(wl2, rng2.integers(0, 8, 128).tolist(),
                          capacity=512).arrival_journal()
    twin, _ = IngressPool.replay(arrivals)
    formed = twin.drain_all(24)
    s = PotSession(wl2.n_objects, engine="pcc", n_lanes=wl2.n_lanes)
    direct = lambda: [s._submit_seq(fb.batch, fb.seq, fb.lanes,
                                    ladder=fb.ladder) for fb in formed]
    direct()   # warm the step compiles
    t_direct = timeit(lambda: jax.block_until_ready(
        direct()[-1].commit_pos), warmup=1, iters=3)
    t_serve = timeit(lambda: jax.block_until_ready(
        s.serve(IngressPool.replay(arrivals)[0],
                budget=24)[-1].commit_pos), warmup=1, iters=3)
    emit("engine_bench_ingress_serve_k128", t_serve * 1e6,
         f"direct_over_serve={t_direct / t_serve:.2f}x;"
         f"batches={len(formed)};budget=24;"
         f"ladder={formed[0].ladder}")
    # cross-batch speculative pipeline: serial vs D=2 on one stream
    n_obj, n_lanes, batches3, lanes3 = _pipeline_stream(
        128, "med", n_batches=6)

    def pipe_stream(depth):
        s = PotSession(n_obj, engine="pcc", n_lanes=n_lanes,
                       pipeline_depth=depth)
        ts = s.run_stream(batches3, lanes3)
        jax.block_until_ready(s.store.values)
        return s, ts

    s_ser, _ = pipe_stream(0)
    t_ser = timeit(lambda: pipe_stream(0), warmup=1, iters=3)
    t_pipe = timeit(lambda: pipe_stream(2), warmup=1, iters=3)
    s_pipe, traces = pipe_stream(2)
    assert s_pipe.fingerprint() == s_ser.fingerprint()
    emit("engine_bench_pipeline_k128_med_d2", t_pipe * 1e6,
         f"serial_over_pipelined={t_ser / t_pipe:.2f}x;"
         f"spec_executed={sum(int(t.spec_executed) for t in traces)};"
         f"spec_invalidated="
         f"{sum(int(t.spec_invalidated) for t in traces)};"
         f"bitwise_equal=1")
    # wave-speculative DeSTM retries: serial token walk vs wave mode on
    # a contended round structure (bitwise-asserted, the wave-count
    # reduction is the derived observable)
    wl4 = _workload(128, "med", seed=31, n_lanes=16)
    store4 = make_store(wl4.n_objects)
    seq4 = _seq_for(wl4)
    lanes4 = jnp.asarray(wl4.lanes, jnp.int32)
    serial4 = lambda: destm_execute(store4, wl4.batch, seq4, lanes4,
                                    wl4.n_lanes, wave=False)
    wave4 = lambda: destm_execute(store4, wl4.batch, seq4, lanes4,
                                  wl4.n_lanes)
    ev4, wv4 = _assert_wave_equal("run destm_wave", *serial4(), *wave4())
    t_serial4 = timeit(serial4, warmup=1, iters=3)
    t_wave4 = timeit(wave4, warmup=1, iters=3)
    emit("engine_bench_destm_wave_k128_med", t_wave4 * 1e6,
         f"serial_over_wave={t_serial4 / t_wave4:.2f}x;"
         f"retry_events={ev4};retry_waves={wv4};"
         f"wave_reduction={ev4 - wv4};bitwise_equal=1")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny K, equivalence assertions only (CI stage)")
    ap.add_argument("--incremental-smoke", action="store_true",
                    help="assert incremental == rebuild across engines")
    ap.add_argument("--compact-smoke", action="store_true",
                    help="assert compact == masked == rebuild across "
                         "engines (+ primitive equality)")
    ap.add_argument("--shard-smoke", action="store_true",
                    help="assert sharded store == dense store across "
                         "engines and paths (+ shard_map mesh when "
                         "multiple devices are exposed)")
    ap.add_argument("--ingress-smoke", action="store_true",
                    help="assert ingress replicas on one arrival journal "
                         "agree bitwise across drain budget schedules "
                         "and that journal replay reproduces the formed "
                         "batch stream")
    ap.add_argument("--pipeline-smoke", action="store_true",
                    help="assert pipelined sessions (D in {1, 2}) == "
                         "serial on one arrival journal across drain "
                         "budgets — fingerprints, replay logs and full "
                         "traces — plus the blocked OCC wave solve")
    ap.add_argument("--destm-wave-smoke", action="store_true",
                    help="assert wave-speculative DeSTM retries == the "
                         "serial token walk bitwise across K x "
                         "contention x lanes, retry_waves <= retry "
                         "events, strict reduction on the WW best case")
    ap.add_argument(
        "--out",
        default=os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "..", "BENCH_engines.json"))
    ap.add_argument("--iters", type=int, default=5)
    args = ap.parse_args()

    if args.smoke:
        run_smoke()
        return
    if args.incremental_smoke:
        run_incremental_smoke()
        return
    if args.compact_smoke:
        run_compact_smoke()
        return
    if args.shard_smoke:
        run_shard_smoke()
        return
    if args.ingress_smoke:
        run_ingress_smoke()
        return
    if args.pipeline_smoke:
        run_pipeline_smoke()
        return
    if args.destm_wave_smoke:
        run_destm_wave_smoke()
        return

    ks = (64, 256, 1024)
    bench = run_bench(ks, ("low", "med"), args.iters)
    bench["meta"] = dict(
        backend=jax.default_backend(),
        devices=len(jax.devices()),
        note="scan = pre-PR2 legacy per-txn commit scans; rebuild = PR2 "
             "batched pipeline with a from-scratch round (full run_all + "
             "rebuilt conflict analysis); incremental = PR3 RoundState "
             "loop (masked run_live over live txns, carried conflict "
             "table with delta updates, compact=False); compact = PR4 "
             "gather-compacted cascade (the live tail executes at ladder "
             "width C, device work scales with the live set).  "
             "read_phase_slots is the read-phase device-work model; "
             "walked_slots the slots the executor actually walks (K*L "
             "masked, C*L compact); live_per_round proves settled txns "
             "are skipped.  The masked executor walks the full (K, L) "
             "grid on every backend (static shapes) — the compact "
             "cascade is what turns the sparse-tail slot win into "
             "wall-clock (see axis=live_fraction for the primitive).  "
             "axis=ragged_stream: PotSession shape bucketing, compile "
             "counts bucketed vs exact.  axis=shards: the store "
             "partitioned into S contiguous range shards (per-shard "
             "conflict tables OR-reduced + S independent write-back "
             "scatters, decisions in rank space) — bit-identical to "
             "S=1 by assertion; fused_write_back rows time the "
             "primitive that runs one-scatter-per-device under a "
             "shard_map mesh.  axis=pipeline: cross-batch speculative "
             "pipelining — PotSession(pipeline_depth=D) executes batch "
             "n+1 against the pre-state snapshot while batch n "
             "commits, validates its logged read sets against "
             "committed writes (versions > snap_gv, rank-space strip "
             "kernels) and re-executes only invalidated rows; rows "
             "carry spec_executed / spec_invalidated / spec_rounds "
             "and every pipelined stream is asserted bit-identical "
             "to serial.",
        commit_steps_model="scan: K sequential device steps per round; "
                           "rebuild/incremental: ceil(log2 K) + 3 batched "
                           "stages (PCC/DeSTM; OCC: conflict-chain depth, "
                           "see wave_trips)",
    )
    bench["speedup_to_compact"] = summarize(bench["results"])
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
