"""Fig. 11/12 analog: scalability — speedup over a single-lane serial
baseline as lanes grow (higher is better; >1 = faster than 1 lane)."""

from __future__ import annotations

from benchmarks.common import emit, run_engines
from repro.core import workloads as W


def run() -> None:
    suites = dict(W.STAMP)
    suites["stmbench7-rw"] = lambda **kw: W.stmbench7_like("rw", **kw)
    for name, gen in suites.items():
        base_cp = None
        rows = []
        for n_lanes in (1, 2, 4, 8, 16):
            wl = gen(n_lanes=n_lanes, seed=21)
            reports = run_engines(wl, engines=("pot", "destm", "pogl"))
            if n_lanes == 1:
                base_cp = reports["pogl"].critical_path or 1.0
            rows.append((n_lanes,
                         base_cp / max(reports["pot"].critical_path, 1e-9),
                         base_cp / max(reports["destm"].critical_path,
                                       1e-9)))
        derived = ";".join(
            f"lanes{n}:pot={p:.2f}x,destm={d:.2f}x" for n, p, d in rows)
        emit(f"fig11_scalability[{name}]", rows[-1][1], derived)


if __name__ == "__main__":
    run()
