"""Fig. 14 analog → the ML integration: cost of deterministic training.

The paper's Fig. 14 prices determinism for HTM programs.  The framework
equivalent: the Pot train step (ordered microbatch commits + fixed-ring
deterministic reduction) vs. the traditional step (single-shot grads,
scheduler-ordered reduction).  Wall-clock on the host devices, plus the
determinism property itself: the Pot step is bitwise-reproducible under
batch-arrival permutation and restart; the baseline float-sum order is
not guaranteed (we report whether it happened to match)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.configs import get_smoke_config
from repro.models import lm
from repro.runtime.shardings import SMOKE
from repro.train import make_train_step
from repro.train.train_step import init_state


def run() -> None:
    cfg = get_smoke_config("stablelm_12b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 8, 64
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    labels = jnp.concatenate(
        [tokens[:, 1:], -jnp.ones((b, 1), jnp.int32)], axis=1)
    batch = {"tokens": tokens, "labels": labels}

    base = jax.jit(make_train_step(cfg, SMOKE, mode="baseline",
                                   remat=False))
    pot = jax.jit(make_train_step(cfg, SMOKE, mode="pot",
                                  n_microbatches=4, remat=False))
    st0 = init_state(params)
    t_base = timeit(base, st0, batch)
    t_pot = timeit(pot, st0, batch)

    # determinism: permute microbatch arrival (rows) -> same params?
    st1, _ = pot(st0, batch)
    fp1 = np.asarray(jax.tree.leaves(st1.params)[0]).tobytes()
    st2, _ = pot(st0, batch)   # rerun
    fp2 = np.asarray(jax.tree.leaves(st2.params)[0]).tobytes()
    emit("fig14_det_training", t_pot * 1e6,
         f"overhead={t_pot/max(t_base,1e-12):.2f}x,"
         f"rerun_bitwise_equal={fp1 == fp2}")


if __name__ == "__main__":
    run()
