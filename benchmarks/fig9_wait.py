"""Fig. 9 analog: time transactions spend waiting to enforce determinism,
DeSTM vs Pot (higher ratio = better for Pot).

The paper counts per-transaction wall time between finishing the read
phase and committing.  Our deterministic unit: wait-rounds (rounds spent
executed-but-not-committed for Pot; barrier-idle members for DeSTM)."""

from __future__ import annotations

from benchmarks.common import emit, run_engines
from repro.core import workloads as W


def run() -> None:
    for name, gen in W.STAMP.items():
        for n_lanes in (2, 4, 8, 16):
            wl = gen(n_lanes=n_lanes, seed=13)
            reports = run_engines(wl, engines=("pot", "destm"))
            pot_wait = reports["pot"].total_wait_rounds
            destm_wait = reports["destm"].total_wait_rounds
            ratio = destm_wait / max(pot_wait, 1)
            emit(f"fig9_wait[{name},lanes={n_lanes}]", pot_wait,
                 f"destm_wait={destm_wait},ratio={ratio:.2f}x")


if __name__ == "__main__":
    run()
