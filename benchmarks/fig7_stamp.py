"""Fig. 7 analog: cost of deterministic execution on the STAMP-analog
suite, normalized to the nondeterministic OCC baseline (lower is better).

Engines: DeSTM-analog, PoGL, Pot- (ordered commits only), Pot* (+ fast
head), Pot (+ simultaneous-fast prefix).  The Pot variants share one
engine run; they differ in which commits get the uninstrumented fast
cost, mirroring the paper's ablation (§4.1.2).  "Time" is the
deterministic critical-path op-slot count (DESIGN.md §2)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, run_engines
from repro.core import workloads as W
from repro.core import metrics as M


def pot_variants(wl):
    """critical-path for Pot- / Pot* / Pot from one engine trace."""
    import jax.numpy as jnp
    from repro.core import (MODE_FAST, MODE_PREFIX, RoundRobinSequencer,
                            make_store, pcc_execute, run_all)
    store = make_store(wl.n_objects)
    seq = jnp.asarray(RoundRobinSequencer(
        n_root_lanes=wl.n_lanes).order_for(wl.lanes.tolist()), jnp.int32)
    res = run_all(wl.batch, store.values)
    rn, wn = np.asarray(res.rn), np.asarray(res.wn)
    n_ins = np.asarray(wl.batch.n_ins)

    def cp(tr, fast_mask):
        cost = M._txn_cost(n_ins, rn, wn, fast=False)
        cost[fast_mask] = n_ins[fast_mask]
        commit_round = np.asarray(tr.commit_round)
        first_round = np.asarray(tr.first_round)
        total = 0.0
        for r in range(int(tr.rounds)):
            in_flight = (first_round <= r) & (commit_round >= r)
            if in_flight.any():
                total += float(np.max(cost[in_flight]))
        return total

    # the paper's three configurations, now run as REAL engine ablations:
    # Pot- = ordered commits only (no fast cost, no promotion);
    # Pot* = + fast/prefix modes (no promotion);
    # Pot  = + live promotion (§2.2.3).
    _, tr_np = pcc_execute(store, wl.batch, seq, live_promotion=False)
    mode_np = np.asarray(tr_np.mode)
    _, tr_lp = pcc_execute(store, wl.batch, seq)
    mode_lp = np.asarray(tr_lp.mode)
    none_fast = np.zeros(len(n_ins), bool)
    return {"pot-": cp(tr_np, none_fast),
            "pot*": cp(tr_np, (mode_np == MODE_FAST)
                       | (mode_np == MODE_PREFIX)),
            "pot": cp(tr_lp, (mode_lp == MODE_FAST)
                      | (mode_lp == MODE_PREFIX))}


def run() -> None:
    lanes_sweep = (2, 4, 8, 16)
    for name, gen in W.STAMP.items():
        for n_lanes in lanes_sweep:
            wl = gen(n_lanes=n_lanes, seed=42)
            reports = run_engines(wl)
            base = reports["occ"].critical_path or 1.0
            pv = pot_variants(wl)
            emit(f"fig7_stamp[{name},lanes={n_lanes}]",
                 reports["pot"].critical_path,
                 "slowdown_vs_occ:"
                 f"destm={reports['destm'].critical_path/base:.2f}x,"
                 f"pogl={reports['pogl'].critical_path/base:.2f}x,"
                 f"pot-={pv['pot-']/base:.2f}x,"
                 f"pot*={pv['pot*']/base:.2f}x,"
                 f"pot={pv['pot']/base:.2f}x")


if __name__ == "__main__":
    run()
