"""Fig. 6 analog: speedup of a Pot fast transaction over the baseline
(speculative/instrumented) transaction, vs. access count and r/w mix.

The paper measures per-access overhead of read-set tracking, write
buffering and commit-time validation (§4.1.1, array-of-counters
microbenchmark).  We report the same quantity in both units available to
us: (a) exact instrumented-op counts from the cost model (deterministic),
and (b) measured CPU wall-time of the jitted engine in all-fast mode
(single non-conflicting txn = fast) vs. forced-speculative mode (txn
behind a conflicting predecessor)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import (RMW, READ, WRITE, make_batch, make_store,
                        pcc_execute)
from repro.core.metrics import _txn_cost


def run() -> None:
    store_n = 512
    for n_access in (0, 1, 2, 4, 8, 16, 32):
        for frac_w in (0.0, 0.5, 1.0):
            n_w = int(n_access * frac_w)
            n_r = n_access - n_w
            ins = [(READ, i, False, 0) for i in range(n_r)]
            ins += [(RMW, 64 + i, False, 1) for i in range(n_w)]
            ins = ins or [(READ, 0, False, 0)]
            cost_fast = float(_txn_cost(
                np.asarray([len(ins)]), np.asarray([n_r + n_w]),
                np.asarray([n_w]), fast=True)[0])
            cost_spec = float(_txn_cost(
                np.asarray([len(ins)]), np.asarray([n_r + n_w]),
                np.asarray([n_w]), fast=False)[0])
            speedup = cost_spec / cost_fast

            # wall-clock: engine with 1 txn (fast path, no validation)
            batch = make_batch([ins])
            store = make_store(store_n)
            seq = jnp.asarray([1], jnp.int32)
            t_fast = timeit(lambda: pcc_execute(store, batch, seq))
            # forced speculative: same txn behind a conflicting writer
            ins2 = [(WRITE, a, False, 9) for (_, a, _, _) in ins[:1]] or \
                [(WRITE, 0, False, 9)]
            batch2 = make_batch([ins2, ins])
            seq2 = jnp.asarray([1, 2], jnp.int32)
            t_spec = timeit(lambda: pcc_execute(store, batch2, seq2))
            emit(f"fig6_fast_tx[acc={n_access},w={frac_w:.1f}]",
                 t_fast * 1e6,
                 f"op_speedup={speedup:.2f}x spec_us={t_spec*1e6:.1f}")


if __name__ == "__main__":
    run()
