"""Assemble the §Roofline table from results/dryrun/*.json.

Per (arch × shape), single-pod 16×16 mesh: the three roofline terms in
seconds (compute / HBM / collective), the dominant bottleneck, MODEL_
FLOPS = 6·N·D (train) or 2·N_active·tokens (serve), and the useful-FLOP
ratio.  Constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun")


def rows():
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if "analysis" not in rec:
            continue
        out.append(rec)
    return out


def run() -> None:
    from repro.launch.roofline_model import terms_from_record
    for rec in rows():
        r = terms_from_record(rec)
        emit(f"roofline[{rec['arch']},{rec['shape']}]",
             r["bound_s"] * 1e6,
             f"compute_s={r['compute_s']:.3e},"
             f"memory_s={r['memory_s']:.3e},"
             f"collective_s={r['collective_s']:.3e},"
             f"bottleneck={r['bottleneck']},"
             f"roofline_frac={r['roofline_fraction']:.3f},"
             f"useful_ratio={r['useful_ratio']:.3f},"
             f"flops_per_chip={r['flops_per_chip']:.3e}")


if __name__ == "__main__":
    run()
