#!/usr/bin/env bash
# One-command tier-1 verification (ROADMAP.md "Tier-1 verify").
# Usage: scripts/ci.sh [--bench-smoke] [extra pytest args]
#
# --bench-smoke additionally runs benchmarks/engine_bench.py --smoke after
# the test suite: it executes every engine through BOTH the preserved
# legacy commit scans and the vectorized commit pipeline and asserts the
# store fingerprints / commit positions agree bitwise, so perf refactors
# of the commit machinery cannot silently diverge.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

BENCH_SMOKE=0
PYTEST_ARGS=()
for arg in "$@"; do
  if [[ "$arg" == "--bench-smoke" ]]; then
    BENCH_SMOKE=1
  else
    PYTEST_ARGS+=("$arg")
  fi
done

python -m pytest -x -q "${PYTEST_ARGS[@]+"${PYTEST_ARGS[@]}"}"

if [[ "$BENCH_SMOKE" == "1" ]]; then
  python benchmarks/engine_bench.py --smoke
fi
