#!/usr/bin/env bash
# One-command tier-1 verification (ROADMAP.md "Tier-1 verify").
# Usage: scripts/ci.sh [--bench-smoke] [--incremental-smoke] [--compact-smoke] [--shard-smoke] [--ingress-smoke] [--pipeline-smoke] [--destm-wave-smoke] [--failover-smoke] [extra pytest args]
#
# --bench-smoke additionally runs benchmarks/engine_bench.py --smoke after
# the test suite: it executes every engine through the preserved legacy
# commit scans, the PR2 rebuild pipeline AND the PR3 incremental
# RoundState loop, asserting the store fingerprints / commit positions
# agree bitwise, so perf refactors of the commit machinery cannot
# silently diverge.
#
# --incremental-smoke runs benchmarks/engine_bench.py --incremental-smoke:
# incremental == rebuild store fingerprints and traces across all three
# engines (the RoundState equivalence gate).
#
# --compact-smoke runs benchmarks/engine_bench.py --compact-smoke:
# the PR4 gather-compacted cascade == the masked incremental loop ==
# rebuild, on store fingerprints and traces, across all three engines,
# plus run_live_compact == run_live at the primitive level (the
# compacted-execution equivalence gate).
#
# --shard-smoke runs benchmarks/engine_bench.py --shard-smoke under an
# 8-device host-platform mesh (XLA_FLAGS): the PR5 sharded store ==
# the dense store bitwise across engines and both code paths, including
# the per-shard write-back running one-shard-per-device via shard_map
# (the shard-decomposition equivalence gate).
#
# --ingress-smoke runs benchmarks/engine_bench.py --ingress-smoke: two
# PR6 IngressPool replicas fed the same arrival journal, drained under
# different budget schedules, agree bitwise through PotSession —
# fingerprints + replay logs — and a full journal replay reproduces the
# formed batch stream exactly (the deterministic-ingress gate).
#
# --pipeline-smoke runs benchmarks/engine_bench.py --pipeline-smoke: one
# PR7 arrival journal replayed through a serial session and pipelined
# sessions (pipeline_depth in {1, 2}, engines pcc + occ) under
# different drain budgets agrees bitwise — fingerprints, replay logs
# AND every pre-existing ExecTrace field (speculation cost may only
# appear in the new spec_* observables) — plus the blocked OCC wave
# solve is decision-identical with fewer while_loop trips (the
# cross-batch speculation equivalence gate).
#
# --destm-wave-smoke runs benchmarks/engine_bench.py --destm-wave-smoke:
# the PR10 wave-speculative DeSTM retry walk == the serial token walk
# bitwise — store fingerprints and every trace field except the wave
# observables (retry_waves / waves_per_round) — across K x contention x
# lane count, with retry_waves <= retry events everywhere and a strict
# wave-count reduction on the blind write-write best case (the
# wave-retry equivalence gate).
#
# --failover-smoke runs the FULL PR9 fault-injection matrix
# (REPRO_FAILOVER_FULL=1 tests/test_failover.py): replicas killed at
# deterministic (batch, phase) fault points — including real subprocess
# SIGKILLs and torn mid-snapshot tmp dirs — across engines {pcc, occ} x
# shards {1, 8} x pipeline_depth {0, 2} x two drain-budget schedules,
# each restored from its latest complete snapshot + the arrival-journal
# suffix and required to reconverge bitwise with the uninterrupted
# replica (the crash-consistent failover gate).  A persistent XLA
# compile cache is shared with the victim/recovery subprocesses so the
# matrix is not compile-bound.
#
# Stages do NOT short-circuit each other: every requested stage runs and
# the script exits non-zero if ANY stage failed (the last failing stage's
# exit code is propagated).
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

BENCH_SMOKE=0
INCREMENTAL_SMOKE=0
COMPACT_SMOKE=0
SHARD_SMOKE=0
INGRESS_SMOKE=0
PIPELINE_SMOKE=0
DESTM_WAVE_SMOKE=0
FAILOVER_SMOKE=0
PYTEST_ARGS=()
for arg in "$@"; do
  case "$arg" in
    --bench-smoke) BENCH_SMOKE=1 ;;
    --incremental-smoke) INCREMENTAL_SMOKE=1 ;;
    --compact-smoke) COMPACT_SMOKE=1 ;;
    --shard-smoke) SHARD_SMOKE=1 ;;
    --ingress-smoke) INGRESS_SMOKE=1 ;;
    --pipeline-smoke) PIPELINE_SMOKE=1 ;;
    --destm-wave-smoke) DESTM_WAVE_SMOKE=1 ;;
    --failover-smoke) FAILOVER_SMOKE=1 ;;
    *) PYTEST_ARGS+=("$arg") ;;
  esac
done

FAIL=0
run_stage() {
  local name="$1"
  shift
  echo "== ci stage: $name"
  "$@"
  local rc=$?
  if [[ $rc -ne 0 ]]; then
    echo "== ci stage FAILED: $name (exit $rc)" >&2
    FAIL=$rc
  fi
}

run_stage tier-1 python -m pytest -x -q "${PYTEST_ARGS[@]+"${PYTEST_ARGS[@]}"}"

if [[ "$BENCH_SMOKE" == "1" ]]; then
  run_stage bench-smoke python benchmarks/engine_bench.py --smoke
fi

if [[ "$INCREMENTAL_SMOKE" == "1" ]]; then
  run_stage incremental-smoke python benchmarks/engine_bench.py --incremental-smoke
fi

if [[ "$COMPACT_SMOKE" == "1" ]]; then
  run_stage compact-smoke python benchmarks/engine_bench.py --compact-smoke
fi

if [[ "$SHARD_SMOKE" == "1" ]]; then
  # run the equivalence suite on a real multi-device mesh: 8 host-platform
  # CPU devices, so the shard_map per-device write-back path is exercised
  run_stage shard-smoke env \
    XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
    python benchmarks/engine_bench.py --shard-smoke
fi

if [[ "$INGRESS_SMOKE" == "1" ]]; then
  run_stage ingress-smoke python benchmarks/engine_bench.py --ingress-smoke
fi

if [[ "$PIPELINE_SMOKE" == "1" ]]; then
  run_stage pipeline-smoke python benchmarks/engine_bench.py --pipeline-smoke
fi

if [[ "$DESTM_WAVE_SMOKE" == "1" ]]; then
  run_stage destm-wave-smoke python benchmarks/engine_bench.py --destm-wave-smoke
fi

if [[ "$FAILOVER_SMOKE" == "1" ]]; then
  run_stage failover-smoke env \
    REPRO_FAILOVER_FULL=1 \
    JAX_COMPILATION_CACHE_DIR="${JAX_COMPILATION_CACHE_DIR:-${TMPDIR:-/tmp}/repro_jax_pcache}" \
    JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS=0 \
    python -m pytest -x -q tests/test_failover.py
fi

exit "$FAIL"
