#!/usr/bin/env bash
# One-command tier-1 verification (ROADMAP.md "Tier-1 verify").
# Usage: scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
